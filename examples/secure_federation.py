"""Secure + private federation: pairwise-mask SecAgg and local DP, composed
as client mods (the Flower built-ins the paper's FLARE users gain, §1),
with seeds derived from FLARE provisioning.

    PYTHONPATH=src python examples/secure_federation.py
"""
import numpy as np

from repro.core import run_native
from repro.fl import (DPMod, FedAvg, SecAggFedAvg, SecAggMod, ServerApp,
                      ServerConfig)
from repro.fl.quickstart import make_client_app
from repro.runtime.provision import Provisioner

SITES = ["site-1", "site-2", "site-3"]


def main():
    prov = Provisioner("secure-fed-demo", secret=b"\x07" * 32)
    for s in SITES:
        prov.issue(s, "client")

    print("== plain FedAvg (server sees every update) ==")
    h_plain = run_native(
        ServerApp(config=ServerConfig(num_rounds=3), strategy=FedAvg()),
        lambda s: make_client_app(s, lr=0.02, skew=0.2), SITES)
    print("  losses:", [f"{l:.5f}" for _, l in h_plain.losses()])

    print("== SecAgg: server only ever sees masked shares ==")
    h_sec = run_native(
        ServerApp(config=ServerConfig(num_rounds=3), strategy=SecAggFedAvg()),
        lambda s: make_client_app(s, lr=0.02, skew=0.2, mods=[SecAggMod(
            site=s, peers=SITES, pairwise_seed_fn=prov.pairwise_seed)]),
        SITES)
    print("  losses:", [f"{l:.5f}" for _, l in h_sec.losses()])
    delta = max(float(np.abs(a.astype(np.float64) - b.astype(np.float64)).max())
                for a, b in zip(h_plain.final_parameters,
                                h_sec.final_parameters))
    print(f"  max param delta vs plain: {delta:.2e} "
          f"(fixed-point quantization only)")

    print("== SecAgg + local DP (clip 1.0, sigma 0.1) ==")
    h_dp = run_native(
        ServerApp(config=ServerConfig(num_rounds=3), strategy=SecAggFedAvg()),
        lambda s: make_client_app(s, lr=0.02, skew=0.2, mods=[
            DPMod(clip_norm=1.0, noise_multiplier=0.1,
                  site_id=int(s[-1]), seed=13),
            SecAggMod(site=s, peers=SITES,
                      pairwise_seed_fn=prov.pairwise_seed)]),
        SITES)
    print("  losses:", [f"{l:.5f}" for _, l in h_dp.losses()])


if __name__ == "__main__":
    main()

"""Paper §3.1: FLARE's multi-job system — two independent FL experiments
run CONCURRENTLY over the same server/clients, without extra ports, each in
its own Job Network.

    PYTHONPATH=src python examples/multi_job.py
"""
import threading
import time

from repro.core.interop import _FlowerClientJob, _FlowerServerJob
from repro.fl import FedAvg, FedAdam, ServerApp, ServerConfig
from repro.fl.quickstart import make_client_app
from repro.runtime import FlareRuntime, JobSpec

SITES = ["site-1", "site-2", "site-3"]


def flower_jobspec(name, strategy, lr):
    server_app = ServerApp(config=ServerConfig(num_rounds=2,
                                               round_timeout=300),
                           strategy=strategy)
    return JobSpec(
        name=name,
        server_app_fn=lambda: _FlowerServerJob(server_app, len(SITES)),
        client_app_fn=lambda s: _FlowerClientJob(
            s, make_client_app(s, lr=lr, skew=0.2)),
        min_sites=len(SITES),
        resources={"gpu": 0.5},      # two jobs fit concurrently
    )


def main():
    rt = FlareRuntime(request_timeout=300.0)
    for s in SITES:
        rt.provision_site(s)
    admin = rt.provisioner.issue("admin", "admin")

    t0 = time.time()
    j1 = rt.submit_job(flower_jobspec("fedavg-lr02", FedAvg(), 0.02), admin)
    j2 = rt.submit_job(flower_jobspec("fedadam-lr05", FedAdam(server_lr=0.1),
                                      0.05), admin)
    print(f"submitted jobs {j1} and {j2}; both RUNNING concurrently")
    r1 = rt.wait(j1, timeout=600)
    r2 = rt.wait(j2, timeout=600)
    dt = time.time() - t0
    print(f"\nboth done in {dt:.1f}s")
    for name, rec in (("fedavg ", r1), ("fedadam", r2)):
        print(f"  {name}: {rec.status.value:10s} "
              f"losses={[f'{l:.4f}' for _, l in rec.result.losses()]}")
    rt.shutdown()
    assert r1.status.value == "COMPLETED" and r2.status.value == "COMPLETED"


if __name__ == "__main__":
    main()

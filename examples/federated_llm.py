"""End-to-end driver (deliverable b): federated training of a transformer
LM across sites through the FLARE runtime.

Each site holds a non-IID synthetic corpus (its own Markov chain); clients
run real jitted train steps on the registry transformer; the server
aggregates with FedAvg through the six-hop bridged path.  At --scale full
the model is ~100M params and runs a few hundred local steps total; the
default is laptop-sized so the example finishes in ~a minute on 1 CPU.

    PYTHONPATH=src python examples/federated_llm.py            # small
    PYTHONPATH=src python examples/federated_llm.py --scale full
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig, get_model_config
from repro.core import run_in_flare
from repro.data.loader import FederatedDataLoader
from repro.fl import FedAvg, ServerApp, ServerConfig
from repro.fl.client import ClientApp, NumPyClient
from repro.fl.messages import arrays_to_params, params_to_arrays
from repro.models import build_model
from repro.runtime import FlareRuntime
from repro.train.steps import cross_entropy_loss, make_train_step

SITES = ["site-1", "site-2", "site-3", "site-4"]


class LMClient(NumPyClient):
    """A real JAX training client: local steps on the site's own corpus."""

    def __init__(self, site: str, cfg, tcfg, loader, local_steps: int):
        self.site = site
        self.site_idx = int(site.rsplit("-", 1)[-1]) - 1
        self.model = build_model(cfg)
        self.tcfg = tcfg
        self.loader = loader
        self.local_steps = local_steps
        self._step_fn = jax.jit(make_train_step(self.model, tcfg))
        self._like = self.model.init(jax.random.key(0))
        from repro.optim import make_optimizer

        self._opt = make_optimizer(tcfg)

    def get_parameters(self, config):
        return params_to_arrays(self._like)

    def fit(self, parameters, config):
        from repro.train.steps import TrainState

        params = arrays_to_params(parameters, self._like)
        state = TrainState(params, self._opt.init(params),
                           jnp.asarray(int(config.get("round", 0))
                                       * self.local_steps, jnp.int32))
        losses = []
        for _ in range(self.local_steps):
            batch = self.loader.next_batch(self.site_idx)
            state, m = self._step_fn(state, batch)
            losses.append(float(m["loss"]))
        n = self.local_steps * self.tcfg.global_batch * self.tcfg.seq_len
        return (params_to_arrays(state.params), n,
                {"train_loss": float(np.mean(losses))})

    def evaluate(self, parameters, config):
        params = arrays_to_params(parameters, self._like)
        batch = self.loader.next_batch(self.site_idx)
        logits, _, _ = self.model.apply(params, batch, mode="train")
        loss = float(cross_entropy_loss(logits, batch["labels"]))
        return loss, batch["tokens"].size, {}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "full"], default="small")
    ap.add_argument("--rounds", type=int, default=0)
    args = ap.parse_args()

    base = get_model_config("flower-quickstart")
    if args.scale == "full":
        cfg = base.replace(d_model=768, num_layers=12, d_ff=3072,
                           num_heads=12, num_kv_heads=12, vocab_size=8192)
        tcfg = TrainConfig(global_batch=8, seq_len=256, learning_rate=1e-3,
                           warmup_steps=20, total_steps=400)
        rounds, local_steps = args.rounds or 5, 20   # 400 steps total
    else:
        cfg = base.replace(d_model=256, num_layers=4, d_ff=1024,
                           vocab_size=2048, remat=False)
        tcfg = TrainConfig(global_batch=8, seq_len=128, learning_rate=2e-3,
                           warmup_steps=10, total_steps=120)
        rounds, local_steps = args.rounds or 3, 10

    model = build_model(cfg)
    print(f"federated LM: {model.param_count()/1e6:.1f}M params, "
          f"{len(SITES)} sites, {rounds} rounds x {local_steps} local steps")

    loader = FederatedDataLoader(cfg.vocab_size, tcfg.seq_len,
                                 num_sites=len(SITES),
                                 batch_per_site=tcfg.global_batch,
                                 seed=7, non_iid_alpha=0.5)

    def client_app_fn(site):
        return ClientApp(client_fn=lambda cid: LMClient(
            site, cfg, tcfg, loader, local_steps).to_client())

    rt = FlareRuntime(request_timeout=600.0)
    for s in SITES:
        rt.provision_site(s)
    server = ServerApp(config=ServerConfig(num_rounds=rounds,
                                           round_timeout=3600),
                       strategy=FedAvg())
    history = run_in_flare(rt, server, client_app_fn, SITES, timeout=7200)
    rt.shutdown()

    print("\nper-round federated eval loss:")
    for rnd, loss in history.losses():
        print(f"  round {rnd}: {loss:.4f}")
    first, last = history.losses()[0][1], history.losses()[-1][1]
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()

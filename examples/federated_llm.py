"""End-to-end driver (deliverable b): federated training of a transformer
LM across sites through the FLARE runtime.

Each site holds a non-IID synthetic corpus (its own Markov chain); clients
run real jitted, mesh-sharded train steps on the registry transformer
(fsdp "data"/"model" axes via ``launch.mesh.make_local_mesh`` — a (1,1)
mesh on a laptop, the same code path as a production mesh); the server
aggregates with FedAvg through the six-hop bridged path, and fit results
ship as structured-sparse 0xF5 TopK deltas by default (<<1% of the
full-weight wire bytes at --scale full).  At --scale full the model is
~100M params and runs a few hundred local steps total; the default is
laptop-sized so the example finishes in ~a minute on 1 CPU.

    PYTHONPATH=src python examples/federated_llm.py            # small
    PYTHONPATH=src python examples/federated_llm.py --scale full
    PYTHONPATH=src python examples/federated_llm.py --codec q8  # int8 wire

Two properties this file is careful about (pinned by
tests/test_federated_llm.py):

- the local optimizer state PERSISTS across rounds: ``fit`` replaces only
  the params in the running ``TrainState``, so Adam moments and the LR
  schedule's step counter stay continuous (re-initializing the moments
  every round while the step counter advanced silently destroyed the
  schedule/moment pairing);
- the compiled step is SHARED: every client with the same
  ``(cfg, tcfg, mesh)`` gets one jitted step from
  ``train.steps.get_train_step`` instead of tracing per client.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig, get_model_config
from repro.core import run_in_flare
from repro.data.loader import FederatedDataLoader
from repro.fl import FedAvg, ServerApp, ServerConfig
from repro.fl.client import ClientApp, NumPyClient
from repro.fl.messages import arrays_to_params, params_to_arrays
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.runtime import FlareRuntime
from repro.train.steps import (TrainState, cross_entropy_loss,
                               get_train_step)

SITES = ["site-1", "site-2", "site-3", "site-4"]


class LMClient(NumPyClient):
    """A real JAX training client: local steps on the site's own corpus."""

    def __init__(self, site: str, cfg, tcfg, loader, local_steps: int,
                 mesh=None):
        self.site = site
        self.site_idx = int(site.rsplit("-", 1)[-1]) - 1
        self.model = build_model(cfg)
        self.tcfg = tcfg
        self.loader = loader
        self.local_steps = local_steps
        self.mesh = mesh if mesh is not None else make_local_mesh()
        # one compiled mesh-sharded step per (cfg, tcfg, mesh) in the
        # whole process — sites share it
        self._step_fn = get_train_step(cfg, tcfg, mesh=self.mesh)
        self._like = self.model.init(jax.random.key(0))
        from repro.optim import make_optimizer

        self._opt = make_optimizer(tcfg)
        # persistent local TrainState: moments + step survive across
        # rounds; fit() only swaps in the aggregated params
        self._state = None

    def get_parameters(self, config):
        return params_to_arrays(self._like)

    def fit(self, parameters, config):
        params = arrays_to_params(parameters, self._like)
        if self._state is None:
            self._state = TrainState(params, self._opt.init(params),
                                     jnp.zeros((), jnp.int32))
        else:
            self._state = self._state._replace(params=params)
        losses = []
        for _ in range(self.local_steps):
            batch = self.loader.next_batch(self.site_idx)
            self._state, m = self._step_fn(self._state, batch)
            losses.append(float(m["loss"]))
        n = self.local_steps * self.tcfg.global_batch * self.tcfg.seq_len
        return (params_to_arrays(self._state.params), n,
                {"train_loss": float(np.mean(losses))})

    def evaluate(self, parameters, config):
        params = arrays_to_params(parameters, self._like)
        batch = self.loader.next_batch(self.site_idx)
        logits, _, _ = self.model.apply(params, batch, mode="train")
        loss = float(cross_entropy_loss(logits, batch["labels"]))
        return loss, batch["tokens"].size, {}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "full"], default="small")
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--codec", choices=["flat", "bf16", "q8", "sparse"],
                    default="sparse",
                    help="negotiated uplink codec (default: 0xF5 "
                         "structured-sparse TopK deltas)")
    ap.add_argument("--sparse-frac", type=float, default=0.05,
                    help="TopK fraction for --codec sparse")
    args = ap.parse_args()

    base = get_model_config("flower-quickstart")
    if args.scale == "full":
        cfg = base.replace(d_model=768, num_layers=12, d_ff=3072,
                           num_heads=12, num_kv_heads=12, vocab_size=8192)
        tcfg = TrainConfig(global_batch=8, seq_len=256, learning_rate=1e-3,
                           warmup_steps=20, total_steps=400)
        rounds, local_steps = args.rounds or 5, 20   # 400 steps total
    else:
        cfg = base.replace(d_model=256, num_layers=4, d_ff=1024,
                           vocab_size=2048, remat=False)
        tcfg = TrainConfig(global_batch=8, seq_len=128, learning_rate=2e-3,
                           warmup_steps=10, total_steps=120)
        rounds, local_steps = args.rounds or 3, 10

    model = build_model(cfg)
    mesh = make_local_mesh()
    print(f"federated LM: {model.param_count()/1e6:.1f}M params, "
          f"{len(SITES)} sites, {rounds} rounds x {local_steps} local "
          f"steps, mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"codec {args.codec}")

    loader = FederatedDataLoader(cfg.vocab_size, tcfg.seq_len,
                                 num_sites=len(SITES),
                                 batch_per_site=tcfg.global_batch,
                                 seed=7, non_iid_alpha=0.5)

    def client_app_fn(site):
        return ClientApp(client_fn=lambda cid: LMClient(
            site, cfg, tcfg, loader, local_steps, mesh=mesh).to_client())

    rt = FlareRuntime(request_timeout=600.0)
    for s in SITES:
        rt.provision_site(s)
    server = ServerApp(
        config=ServerConfig(
            num_rounds=rounds, round_timeout=3600,
            codec=None if args.codec == "flat" else args.codec,
            sparse_frac=args.sparse_frac),
        strategy=FedAvg())
    history = run_in_flare(rt, server, client_app_fn, SITES, timeout=7200)
    rt.shutdown()

    print("\nper-round federated eval loss:")
    for rec in history.rounds:
        extra = ""
        if "wire_codec" in rec.metrics:
            extra = f"  [wire={rec.metrics['wire_codec']}]"
        if rec.loss is not None:
            print(f"  round {rec.round}: {rec.loss:.4f}{extra}")
    first, last = history.losses()[0][1], history.losses()[-1][1]
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    if last >= first:
        raise SystemExit("federated loss did not improve")


if __name__ == "__main__":
    main()

"""Paper §5.2: hybrid integration — the Flower client uses FLARE's
experiment-tracking SummaryWriter (Listing 3); metrics from every client
stream to the server and are exported TensorBoard-style (Fig. 6).

    PYTHONPATH=src python examples/hybrid_tracking.py
"""
from repro.core import run_in_flare
from repro.fl import FedAvg, ServerApp, ServerConfig
from repro.fl.client import ClientApp
from repro.fl.quickstart import QuickstartClient
from repro.runtime import FlareRuntime

SITES = ["site-1", "site-2", "site-3"]


def client_app_fn(site):
    def with_ctx(ctx):
        writer = ctx.summary_writer()        # <- nvflare.client.tracking API
        return ClientApp(client_fn=lambda cid: QuickstartClient(
            site, writer=writer, lr=0.02, skew=0.2).to_client())
    return with_ctx


def main():
    rt = FlareRuntime()
    for s in SITES:
        rt.provision_site(s)
    run_in_flare(rt, ServerApp(config=ServerConfig(num_rounds=3),
                               strategy=FedAvg()), client_app_fn, SITES)
    mc = rt.metrics(next(iter(rt._jobs)))
    print("streamed tags:", mc.tags())
    for tag in mc.tags():
        print(f"  {tag}: {[(s, round(v, 4)) for s, v in mc.series(tag)]}")
    out = mc.export_tensorboard_json("metrics_fig6.json")
    print(f"\nexported {len(out)} bytes to metrics_fig6.json (Fig. 6 artifact)")
    rt.shutdown()


if __name__ == "__main__":
    main()

"""Paper §5.1: the quickstart app, run natively AND inside FLARE — no code
changes, identical results (Fig. 5).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import run_in_flare, run_native
from repro.fl import FedAdam, ServerApp, ServerConfig
from repro.fl.quickstart import make_client_app
from repro.runtime import FlareRuntime

SITES = ["site-1", "site-2", "site-3"]


def make_server_app():
    # paper Listing 1: strategy + ServerApp
    strategy = FedAdam(server_lr=0.1)
    return ServerApp(config=ServerConfig(num_rounds=3), strategy=strategy)


def main():
    print("== running the Flower app natively (SuperLink + SuperNodes) ==")
    h_native = run_native(make_server_app(),
                          lambda s: make_client_app(s, lr=0.02, skew=0.2),
                          SITES)
    for rnd, loss in h_native.losses():
        print(f"  round {rnd}: eval loss {loss:.5f}")

    print("== running the SAME app inside the FLARE runtime ==")
    rt = FlareRuntime()
    for s in SITES:
        rt.provision_site(s)
    h_flare = run_in_flare(rt, make_server_app(),
                           lambda s: make_client_app(s, lr=0.02, skew=0.2),
                           SITES)
    rt.shutdown()
    for rnd, loss in h_flare.losses():
        print(f"  round {rnd}: eval loss {loss:.5f}")

    same = h_native.losses() == h_flare.losses() and all(
        np.array_equal(a, b) for a, b in zip(h_native.final_parameters,
                                             h_flare.final_parameters))
    print(f"\nFig. 5 check — curves and final params bitwise identical: {same}")
    assert same


if __name__ == "__main__":
    main()

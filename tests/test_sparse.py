"""Structured-sparse 0xF5 delta codec (TopK / adapter-LoRA mode) tests.

Covers the contracts the federated-LLM wire path rests on:
- deterministic TopK selection (exactly k, lowest-index tie-breaking) —
  shared by the 0xF5 encoder and TopKCompressionMod;
- 0xF5 round-trip in both index modes (coo TopK / adapter ranges) and
  both value modes (q8 / f32): traveled coordinates within the int8
  bound of the true delta, untouched coordinates bitwise the base;
- zero-copy frozen decode (index/scale/value streams are read-only
  views into the transport buffer);
- hypothesis error bound for TopK-int8 deltas;
- UnsupportedCodec on every parameter-decoding path (a sparse delta is
  meaningless without the server-held base);
- sparse wire bytes << dense 0xF1/0xF3 bytes;
- fold correctness and bitwise invariance: the scatter fold matches the
  dense fp32 path within the quantization bound, is bitwise identical
  across arrival orders and shard counts, and the Pallas-backend device
  chain matches numpy bitwise;
- negotiation: sparse demotes to q8 (fleet lacks sparse but speaks q8,
  or the strategy needs dense rows) and to flat (fleet lacks both);
- the sharding salvage pass sizes leaves by their own itemsize;
- end-to-end: the quickstart grid converges under a sparse negotiation
  within tolerance of the lossless run.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

import repro.fl.agg_kernels as K
from repro.fl.flat import (FlatParams, QCHUNK, SparseDelta, layout_of,
                           topk_indices)
from repro.fl.messages import (FLAT_MAGIC, FitRes, UnsupportedCodec,
                               bytes_to_arrays, decode_fit_ins,
                               decode_fit_res, encode_fit_res)
from repro.fl.strategy import make_strategy

pytestmark = pytest.mark.sparse

RNG = np.random.default_rng(55)
SPARSE_MAGIC = 0xF5  # repro: allow[codec-literal] reason=wire-format pin, tests must not import the value they verify


def _f32_arrays(seed=0, shapes=((33, 17), (1500,), (2, 3, 5))):
    rng = np.random.default_rng(seed)
    return [rng.normal(0, 0.5, size=s).astype(np.float32) for s in shapes]


def _sparse_results(n_clients, seed, base, frac=0.05, scale=1e-3):
    """(dense FitRes, decoded sparse FitRes) pairs vs a shared base."""
    rng = np.random.default_rng(seed)
    dense, sparse = [], []
    for c in range(n_clients):
        arrays = [a + rng.normal(0, scale, size=a.shape).astype(np.float32)
                  for a in base.to_arrays()]
        w = 10 + 3 * c
        dense.append((f"site-{c}", FitRes(arrays, w, {})))
        dec = decode_fit_res(encode_fit_res(FitRes(arrays, w, {}),
                                            codec="sparse", base=base,
                                            sparse_frac=frac))
        dec.sparse.base = base
        sparse.append((f"site-{c}", dec))
    return dense, sparse


def _sparse_bound(sp: SparseDelta) -> float:
    """Per-coordinate reconstruction bound on traveled coordinates."""
    if sp.vmode != "q8":
        return 1e-12
    return float(sp.scales.max()) * 0.5 * (1 + 1e-5) + 1e-12


# ---------------------------------------------------------------------------
# deterministic TopK selection
# ---------------------------------------------------------------------------
def test_topk_indices_exactly_k_lowest_index_ties():
    mag = np.zeros(100, np.float32)
    mag[10:90] = 1.0                       # 80-way tie at the threshold
    mag[[7, 40, 93]] = 2.0                 # strictly above the tie level
    idx = topk_indices(mag, 10)
    assert idx.size == 10 and idx.dtype == np.int64
    # the 3 strict winners + the 7 LOWEST-index ties, sorted ascending
    np.testing.assert_array_equal(
        idx, sorted([7, 40, 93] + [10, 11, 12, 13, 14, 15, 16]))


def test_topk_indices_is_permutation_invariant_on_ties():
    """Equal-magnitude ties resolve by coordinate, not memory order."""
    mag = np.ones(64, np.float64)
    np.testing.assert_array_equal(topk_indices(mag, 5), np.arange(5))
    np.testing.assert_array_equal(topk_indices(mag[::-1], 5), np.arange(5))


def test_topk_indices_edge_cases():
    mag = np.abs(RNG.normal(size=17))
    assert topk_indices(mag, 0).size == 0
    np.testing.assert_array_equal(topk_indices(mag, 17), np.arange(17))
    np.testing.assert_array_equal(topk_indices(mag, 99), np.arange(17))
    one = topk_indices(mag, 1)
    assert one.size == 1 and mag[one[0]] == mag.max()


def test_topk_mod_kept_fraction_is_exact_under_ties():
    """TopKCompressionMod regression: an all-equal |delta| used to keep
    EVERY tie (kept_frac == 1.0); the deterministic selection keeps
    exactly ceil(fraction * n)."""
    from repro.fl.messages import (FitIns, TaskIns, decode_task_res,
                                   encode_fit_ins, encode_task_ins)
    from repro.fl.mods import TopKCompressionMod
    from repro.fl.client import ClientApp, NumPyClient

    base = [np.zeros((40, 25), np.float32)]

    class C(NumPyClient):
        def fit(self, parameters, config):
            return [p + np.float32(0.5) for p in parameters], 3, {}

    app = ClientApp(lambda cid: C().to_client(),
                    mods=[TopKCompressionMod(fraction=0.1)])
    t = TaskIns("fit", 0, encode_fit_ins(FitIns(base)), task_id="t")
    tr = decode_task_res(app.handle(encode_task_ins(t)))
    fit = decode_fit_res(tr.payload)
    assert fit.metrics["topk_kept_frac"] == pytest.approx(0.1)
    out = fit.materialize()[0]
    # deterministic tie-break: exactly the first 100 coordinates kept
    assert (out.ravel()[:100] == np.float32(0.5)).all()
    assert (out.ravel()[100:] == 0).all()


# ---------------------------------------------------------------------------
# 0xF5 round-trip
# ---------------------------------------------------------------------------
def test_sparse_roundtrip_coo_q8():
    base = FlatParams.from_arrays(_f32_arrays(seed=1))
    n = base.layout.total_size
    result = [a + RNG.normal(0, 1e-3, size=a.shape).astype(np.float32)
              for a in base.to_arrays()]
    b = encode_fit_res(FitRes(result, 7, {"loss": 0.5}), codec="sparse",
                       base=base, sparse_frac=0.1)
    assert b[0] == SPARSE_MAGIC
    dec = decode_fit_res(b)
    assert dec.parameters is None and dec.num_examples == 7
    sp = dec.sparse
    assert sp.imode == "coo" and sp.vmode == "q8"
    assert sp.nnz == max(1, int(np.ceil(0.1 * n)))
    sp.base = base
    got = sp.to_f64()
    want = FlatParams.from_arrays(result).to_f64()
    bwant = base.to_f64()
    kept = np.zeros(n, bool)
    kept[sp.indices] = True
    bound = _sparse_bound(sp)
    assert np.abs(got[kept] - want[kept]).max() <= bound
    # untouched coordinates are BITWISE the base
    np.testing.assert_array_equal(got[~kept], bwant[~kept])


def test_sparse_roundtrip_ranges_mode():
    base = FlatParams.from_arrays(_f32_arrays(seed=2))
    n = base.layout.total_size
    result = [a + RNG.normal(0, 1e-3, size=a.shape).astype(np.float32)
              for a in base.to_arrays()]
    ranges = np.array([[0, 100], [561, 561 + 800], [n - 64, n]], np.int64)
    b = encode_fit_res(FitRes(result, 7, {}), codec="sparse", base=base,
                       sparse_ranges=ranges)
    dec = decode_fit_res(b)
    sp = dec.sparse
    assert sp.imode == "ranges"
    np.testing.assert_array_equal(np.asarray(sp.indices), ranges)
    assert sp.nnz == int((ranges[:, 1] - ranges[:, 0]).sum())
    sp.base = base
    got, want, bwant = (sp.to_f64(),
                        FlatParams.from_arrays(result).to_f64(),
                        base.to_f64())
    kept = np.zeros(n, bool)
    for a, b_ in ranges:
        kept[a:b_] = True
    assert np.abs(got[kept] - want[kept]).max() <= _sparse_bound(sp)
    np.testing.assert_array_equal(got[~kept], bwant[~kept])


def test_sparse_decode_is_zero_copy_and_frozen():
    base = FlatParams.from_arrays(_f32_arrays(seed=3))
    result = [a + np.float32(1e-3) for a in base.to_arrays()]
    for kw in ({"sparse_frac": 0.05},
               {"sparse_ranges": np.array([[10, 900]], np.int64)}):
        sp = decode_fit_res(encode_fit_res(
            FitRes(result, 1, {}), codec="sparse", base=base, **kw)).sparse
        streams = [sp.indices, sp.values] + \
            ([sp.scales] if sp.scales is not None else [])
        for s in streams:
            assert not s.flags["OWNDATA"]
            assert not s.flags["WRITEABLE"]


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 3 * QCHUNK + 7), st.integers(0, 10_000),
       st.floats(1e-5, 10.0))
def test_sparse_topk_int8_error_bound(n, seed, scale):
    """Any length, any update magnitude: traveled coordinates reconstruct
    within the per-chunk int8 bound, untouched ones are bitwise base."""
    rng = np.random.default_rng(seed)
    base = FlatParams.from_arrays([rng.normal(size=n).astype(np.float32)])
    result = [base.to_arrays()[0]
              + rng.normal(0, scale, size=n).astype(np.float32)]
    dec = decode_fit_res(encode_fit_res(FitRes(result, 1, {}),
                                        codec="sparse", base=base,
                                        sparse_frac=0.25))
    sp = dec.sparse
    sp.base = base
    got = sp.to_f64()
    want = FlatParams.from_arrays(result).to_f64()
    kept = np.zeros(n, bool)
    kept[sp.indices] = True
    assert np.abs(got[kept] - want[kept]).max() <= _sparse_bound(sp)
    np.testing.assert_array_equal(got[~kept], base.to_f64()[~kept])


def test_sparse_wire_bytes_are_under_one_percent():
    """The headline claim at LLM scale: a 0.1% TopK delta ships at <1%
    of the dense fp32 frame (int64 index + int8 value + scale streams)."""
    arrays = [RNG.normal(size=(1 << 20,)).astype(np.float32)]
    base = FlatParams.from_arrays(
        [a + np.float32(1.0) for a in arrays])  # nonzero delta everywhere
    flat = encode_fit_res(FitRes(arrays, 1, {}), codec="flat")
    spb = encode_fit_res(FitRes(arrays, 1, {}), codec="sparse", base=base,
                         sparse_frac=0.001)
    assert len(spb) / len(flat) < 0.01, len(spb) / len(flat)


def test_sparse_without_base_demotes_to_flat():
    """No round base (e.g. a FitIns downlink, or a reshaped result) means
    no delta: the encoder falls back to lossless 0xF1."""
    res = FitRes(_f32_arrays(seed=4), 1, {})
    assert encode_fit_res(res, codec="sparse")[0] == FLAT_MAGIC
    wrong = FlatParams.from_arrays([np.ones((3, 3), np.float32)])
    assert encode_fit_res(res, codec="sparse", base=wrong)[0] == FLAT_MAGIC


def test_sparse_frame_raises_unsupported_on_parameter_paths():
    base = FlatParams.from_arrays(_f32_arrays(seed=5))
    result = [a + np.float32(1e-3) for a in base.to_arrays()]
    b = encode_fit_res(FitRes(result, 1, {}), codec="sparse", base=base,
                       sparse_frac=0.05)
    with pytest.raises(UnsupportedCodec, match="sparse"):
        bytes_to_arrays(b)
    with pytest.raises(UnsupportedCodec, match="sparse"):
        decode_fit_ins(b)
    with pytest.raises(UnsupportedCodec, match="sparse"):
        decode_fit_res(b).materialize()


def test_sparse_delta_validation_rejects_byzantine_indices():
    layout = layout_of([np.empty(100, np.float32)])
    vals = np.ones(3, np.float32)
    for bad in (np.array([5, 4, 9]), np.array([5, 5, 9]),
                np.array([5, 7, 100]), np.array([-1, 5, 9])):
        with pytest.raises(ValueError):
            SparseDelta(layout, "coo", bad.astype(np.int64), vals)
    for bad in (np.array([[10, 10]]), np.array([[50, 40]]),
                np.array([[0, 2], [1, 3]]), np.array([[90, 120]])):
        with pytest.raises(ValueError):
            SparseDelta(layout, "ranges", bad.astype(np.int64),
                        np.ones(int(np.maximum(
                            bad[:, 1] - bad[:, 0], 0).sum()), np.float32))


# ---------------------------------------------------------------------------
# aggregation folds
# ---------------------------------------------------------------------------
def _weighted_reference(results):
    """Reference weighted mean in f64: reconstruct every payload densely
    (sparse/quant via their own to_f64 chain) and fold by hand."""
    wsum = tw = None
    for _, r in results:
        if r.sparse is not None:
            x = r.sparse.to_f64()
        elif r.quant is not None:
            x = r.quant.to_f64()
        else:
            x = FlatParams.from_arrays(r.parameters).to_f64()
        w = float(r.num_examples)
        wsum = w * x if wsum is None else wsum + w * x
        tw = w if tw is None else tw + w
    return wsum / tw


@pytest.mark.parametrize("kw", [{}, {"low_memory": True}])
def test_fedavg_consumes_sparse_results(kw):
    """The scatter fold matches a hand-rolled dense reconstruction of the
    same sparsified payloads (fold math, base deferral, normalization)."""
    base = FlatParams.from_arrays(_f32_arrays(seed=31))
    _, sparse = _sparse_results(6, 32, base)
    current = base.to_arrays()
    got, m = make_strategy("fedavg", **kw).aggregate_fit(1, sparse, [],
                                                         current)
    assert m["num_clients"] == 6
    np.testing.assert_allclose(FlatParams.from_arrays(got).to_f64(),
                               _weighted_reference(sparse),
                               rtol=1e-6, atol=1e-7)


def test_sparse_fold_bitwise_invariant_across_arrival_orders():
    base = FlatParams.from_arrays(_f32_arrays(seed=33))
    _, sparse = _sparse_results(5, 34, base)
    strat = make_strategy("fedavg")
    outs = []
    for order in (sparse, sparse[::-1], sparse[2:] + sparse[:2]):
        acc = strat.fit_accumulator(1, base.to_arrays())
        for node, r in order:
            acc.add(node, r)
        got, _ = acc.finalize([])
        outs.append(got)
    for got in outs[1:]:
        for g, w in zip(got, outs[0]):
            np.testing.assert_array_equal(g, w)


@pytest.mark.shard
def test_sparse_fold_bitwise_invariant_across_shard_counts():
    base = FlatParams.from_arrays(_f32_arrays(seed=35))
    _, sparse = _sparse_results(4, 36, base)
    outs = []
    for shards in (None, 2, 5):
        s = K.StreamingWeightedSum(base.layout, backend="numpy",
                                   shards=shards)
        for _, r in sparse:
            s.add(r.sparse, float(r.num_examples))
        outs.append(s.finalize().math_view().copy())
    for got in outs[1:]:
        np.testing.assert_array_equal(got, outs[0])


def test_sparse_and_q8_results_fold_together():
    """A mixed fleet: some clients ship 0xF5, some 0xF3 deltas, some raw
    fp32 — one round, one accumulator, bounded error vs the dense fold."""
    base = FlatParams.from_arrays(_f32_arrays(seed=37))
    dense, sparse = _sparse_results(4, 38, base)
    mixed = []
    for i, ((node, d), (_, s)) in enumerate(zip(dense, sparse)):
        if i % 3 == 0:
            mixed.append((node, d))
        elif i % 3 == 1:
            q = decode_fit_res(encode_fit_res(d, codec="q8", base=base))
            q.quant.base = base
            mixed.append((node, q))
        else:
            mixed.append((node, s))
    strat = make_strategy("fedavg")
    got, _ = strat.aggregate_fit(1, mixed, [], base.to_arrays())
    np.testing.assert_allclose(FlatParams.from_arrays(got).to_f64(),
                               _weighted_reference(mixed),
                               rtol=1e-6, atol=1e-7)


def test_sparse_raw_sum_is_true_weighted_sum():
    """raw_sum() (the edge 0xF4 pre-reduce) must report Σ w·(base+delta),
    with the deferred bases folded at their summed weight."""
    base = FlatParams.from_arrays(_f32_arrays(seed=39))
    dense, sparse = _sparse_results(3, 40, base)
    s = K.StreamingWeightedSum(base.layout)
    for _, r in sparse:
        s.add(r.sparse, float(r.num_examples))
    got = s.raw_sum()
    want = np.zeros(base.layout.total_size, np.float64)
    for _, r in sparse:
        want += float(r.num_examples) * r.sparse.to_f64()
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-9)


def test_stacked_strategies_reject_sparse_results():
    """median/trim/Krum need dense per-client rows; a sparse result that
    reaches them (negotiation bypassed) is a loud per-round error, not a
    silent wrong answer."""
    base = FlatParams.from_arrays(_f32_arrays(seed=41))
    _, sparse = _sparse_results(3, 42, base)
    acc = make_strategy("fedmedian").fit_accumulator(1, base.to_arrays())
    with pytest.raises(ValueError, match="dense per-client"):
        for node, r in sparse:
            acc.add(node, r)


@pytest.mark.pallas
def test_sparse_fold_pallas_backend_matches_numpy_bitwise():
    """The jitted scatter chain (f64(f32(f64(int8)·f64(scale)))·w) must
    reproduce the numpy fold bit for bit — same contract as the dense
    Pallas lanes."""
    base = FlatParams.from_arrays(_f32_arrays(seed=43))
    _, sparse = _sparse_results(5, 44, base, frac=0.15)
    outs = {}
    for backend in ("numpy", "pallas"):
        s = K.StreamingWeightedSum(base.layout, backend=backend)
        for _, r in sparse:
            s.add(r.sparse, float(r.num_examples))
        outs[backend] = s.finalize().math_view().copy()
    np.testing.assert_array_equal(outs["pallas"], outs["numpy"])


@pytest.mark.pallas
def test_scatter_wsum_matches_host_dequant_chain():
    from repro.kernels.agg_reduce import scatter_wsum
    from repro.fl.flat import quantize_int8

    rng = np.random.default_rng(45)
    n, nnz = 8192, 700
    x = rng.normal(0, 1e-2, size=nnz).astype(np.float32)
    q, scales = quantize_int8(x)
    dest = np.sort(rng.choice(n, size=nnz, replace=False)).astype(np.int64)
    w = 3.5
    acc = np.zeros(n, np.float64)
    scatter_wsum(acc, dest, q, w, scales=scales)
    sp = SparseDelta(layout_of([np.empty(n, np.float32)]), "coo", dest, q,
                     scales=scales)
    want = np.zeros(n, np.float64)
    buf = np.empty(nnz, np.float64)
    want[dest] = sp.dequant_packed(0, nnz, buf) * w
    np.testing.assert_array_equal(acc, want)


# ---------------------------------------------------------------------------
# negotiation ladder
# ---------------------------------------------------------------------------
class _FakeDriver:
    def __init__(self, nodes, on_properties):
        self.nodes = nodes
        self.on_properties = on_properties

    def node_ids(self):
        return list(self.nodes)

    def send_and_receive_iter(self, tasks, timeout):
        from repro.fl.messages import (TaskRes, decode_task_ins,
                                       encode_task_res)
        for node, tb in sorted(tasks.items()):
            t = decode_task_ins(tb)
            payload, error = self.on_properties(node)
            yield node, encode_task_res(TaskRes(
                t.task_type, t.round, payload, task_id=t.task_id,
                error=error))


def _negotiate(on_properties, strategy=None):
    from repro.fl.server import ServerApp, ServerConfig
    from repro.fl.strategy import FedAvg

    app = ServerApp(ServerConfig(codec="sparse"), strategy or FedAvg())
    return app._negotiate_codec(_FakeDriver(["a", "b"], on_properties),
                                ["a", "b"])


def test_negotiation_picks_sparse_when_fleet_advertises():
    from repro.fl.messages import encode_properties_res
    ok = encode_properties_res({"codecs": ["flat", "q8", "sparse"]})
    assert _negotiate(lambda node: (ok, "")) == ("sparse", "")


def test_negotiation_demotes_sparse_to_q8_not_flat():
    """A node without sparse but with q8 keeps the int8-delta rung; the
    note names the culprit."""
    from repro.fl.messages import encode_properties_res
    new = encode_properties_res({"codecs": ["flat", "q8", "sparse"]})
    mid = encode_properties_res({"codecs": ["flat", "q8"]})
    codec, note = _negotiate(lambda n: (new if n == "a" else mid, ""))
    assert codec == "q8" and "b" in note and "sparse" in note


def test_negotiation_demotes_sparse_to_flat_when_no_q8():
    from repro.fl.messages import encode_properties_res
    new = encode_properties_res({"codecs": ["flat", "q8", "sparse"]})
    old = encode_properties_res({"codecs": ["flat", "legacy"]})
    codec, note = _negotiate(lambda n: (new if n == "a" else old, ""))
    assert codec == "flat" and "b" in note


def test_negotiation_pre_demotes_sparse_for_stacked_strategies():
    """FedMedian cannot fold scattered deltas — the server asks the fleet
    for q8 instead, before any fit round."""
    from repro.fl.messages import encode_properties_res
    ok = encode_properties_res({"codecs": ["flat", "q8", "sparse"]})
    codec, note = _negotiate(lambda node: (ok, ""),
                             strategy=make_strategy("fedmedian"))
    assert codec == "q8" and "strategy" in note


# ---------------------------------------------------------------------------
# sharding salvage (itemsize bugfix)
# ---------------------------------------------------------------------------
@pytest.mark.shard
@pytest.mark.skipif(len(__import__("jax").devices()) < 2,
                    reason="salvage needs a model>1 mesh")
def test_salvage_threshold_uses_leaf_itemsize():
    """A 10 MB fp32 leaf (size*4 >= 8 MB) whose rules all fell back must
    be salvage-sharded; the old hard-coded bf16 estimate (size*2 = 5 MB)
    skipped it.  The same element count in bf16 (5 MB) stays replicated."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.launch.shardings import params_shardings

    mesh = Mesh(np.array(jax.devices()[:2]).reshape(1, 2),
                ("data", "model"))

    class _M:
        class cfg:
            fsdp_hint = True

        @staticmethod
        def axes():
            return {"big32": (None, None), "big16": (None, None),
                    "small32": (None, None)}

        @staticmethod
        def abstract():
            return {
                "big32": jax.ShapeDtypeStruct((1_250_000, 2), jnp.float32),
                "big16": jax.ShapeDtypeStruct((1_250_000, 2), jnp.bfloat16),
                "small32": jax.ShapeDtypeStruct((999_999, 2), jnp.float32),
            }

    sh = params_shardings(_M(), mesh)
    assert tuple(sh["big32"].spec) == ("model", None)
    assert all(e is None for e in sh["big16"].spec)
    assert all(e is None for e in sh["small32"].spec)


# ---------------------------------------------------------------------------
# end to end
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_end_to_end_negotiated_sparse_converges_within_tolerance():
    from repro.core import run_native
    from repro.fl import FedAvg, ServerApp, ServerConfig
    from repro.fl.quickstart import make_client_app

    sites = ["site-1", "site-2", "site-3"]
    h_flat = run_native(ServerApp(ServerConfig(num_rounds=2), FedAvg()),
                        lambda s: make_client_app(s), sites)
    h_sp = run_native(ServerApp(ServerConfig(num_rounds=2, codec="sparse",
                                             sparse_frac=0.3), FedAvg()),
                      lambda s: make_client_app(s), sites)
    assert h_sp.rounds[-1].metrics["wire_codec"] == "sparse"
    for (_, lf), (_, ls) in zip(h_flat.losses(), h_sp.losses()):
        assert abs(lf - ls) < 0.1, (lf, ls)
    d = max(float(np.abs(a.astype(np.float64) - b.astype(np.float64)).max())
            for a, b in zip(h_flat.final_parameters, h_sp.final_parameters))
    assert d < 0.1


@pytest.mark.slow
def test_end_to_end_adapter_ranges_client():
    """A client that declares trainable_ranges ships ONLY those ranges:
    outside coordinates come back bitwise identical after aggregation."""
    from repro.core import run_native
    from repro.fl import ClientApp, FedAvg, ServerApp, ServerConfig
    from repro.fl.quickstart import QuickstartClient

    ranges = [(0, 50), (100, 260)]

    class AdapterClient(QuickstartClient):
        def trainable_ranges(self):
            return ranges

    sites = ["site-1", "site-2"]
    # round-0 params are pulled from the fleet via get_parameters, which
    # is deterministic for the quickstart client — recompute the base
    before = FlatParams.from_arrays(
        AdapterClient("site-1").get_parameters({})).to_f64()
    h = run_native(
        ServerApp(ServerConfig(num_rounds=1, codec="sparse"), FedAvg()),
        lambda s: ClientApp(lambda cid: AdapterClient(s).to_client()),
        sites)
    assert h.rounds[-1].metrics["wire_codec"] == "sparse"
    got = FlatParams.from_arrays(h.final_parameters).to_f64()
    mask = np.ones(got.size, bool)
    changed = np.zeros(got.size, bool)
    for a, b in ranges:
        mask[a:b] = False
        changed[a:b] = True
    np.testing.assert_array_equal(got[mask], before[mask])
    assert np.any(got[changed] != before[changed])

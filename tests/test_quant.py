"""Quantized wire codec (0xF2 bf16 / 0xF3 int8+per-chunk-scales) tests.

Covers the contracts the compressed hot path rests on:
- cross-version interop: every (encoder, decoder) pair across
  legacy/0xF1/0xF2/0xF3 round-trips (bitwise for the lossless pair,
  within the quantization bound for the lossy ones) or raises a clear
  ``UnsupportedCodec`` for reserved version bytes this build lacks;
- the int8 per-chunk quantization error bound (hypothesis property);
- zero-copy decode of compressed frames (data/scales are views);
- delta encoding: client and server agree bitwise on the round base,
  reconstruction error is bounded by the *update* magnitude;
- fused dequantize+accumulate kernels: aggregating compressed results
  (deferred and streaming accumulators, robust strategies) matches the
  fp32 path within the quantization bound;
- SecAgg mask cancellation in the quantized integer domain (hypothesis);
- codec negotiation end to end: ServerApp picks the advertised codec,
  demotes to lossless flat for fleets that don't advertise it, and
  SecAgg composes (masked uint64 shares fall back to 0xF1).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.fl.flat import (FlatParams, QCHUNK, QuantParams, quantizable,
                           quantize_int8, layout_of)
from repro.fl.messages import (FLAT_MAGIC, BF16_MAGIC, Q8_MAGIC, FitIns,
                               FitRes, TaskIns, UnsupportedCodec,
                               WIRE_CODECS, arrays_to_bytes, bytes_to_arrays,
                               decode_fit_ins, decode_fit_res,
                               decode_properties_res, decode_task_res,
                               encode_fit_ins, encode_fit_res,
                               encode_task_ins, peek_config, peek_params)
from repro.fl.strategy import make_strategy

RNG = np.random.default_rng(21)


def _f32_arrays(seed=0, shapes=((33, 17), (1500,), (2, 3, 5))):
    rng = np.random.default_rng(seed)
    return [rng.normal(0, 0.5, size=s).astype(np.float32) for s in shapes]


def _q8_bound(q: QuantParams) -> float:
    """Per-coordinate reconstruction bound: half the largest chunk scale
    (plus fp32 rounding slack)."""
    return float(q.scales.max()) * 0.5 * (1 + 1e-5) + 1e-12


# ---------------------------------------------------------------------------
# int8 quantization primitive
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3 * QCHUNK + 7), st.integers(0, 10_000),
       st.floats(1e-6, 1e3))
def test_int8_quantization_error_bound(n, seed, magnitude):
    """|x - scale*q| <= scale/2 per coordinate, any length (ragged tails
    included), any dynamic range."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(0, magnitude, size=n)).astype(np.float32)
    q, scales = quantize_int8(x)
    assert q.dtype == np.int8 and scales.dtype == np.float32
    assert scales.size == -(-n // QCHUNK) and (scales > 0).all()
    sv = np.repeat(scales.astype(np.float64), QCHUNK)[:n]
    err = np.abs(q.astype(np.float64) * sv - x.astype(np.float64))
    bound = sv * 0.5 * (1 + 1e-5) + 1e-12
    assert (err <= bound).all(), float((err - bound).max())


def test_int8_all_zero_chunks_use_unit_scale():
    q, scales = quantize_int8(np.zeros(2 * QCHUNK + 5, np.float32))
    assert (scales == 1.0).all() and (q == 0).all()


# ---------------------------------------------------------------------------
# cross-version interop matrix
# ---------------------------------------------------------------------------
ENCODERS = ["legacy", "flat", "bf16", "q8"]
LOSSLESS = {"legacy", "flat"}


@pytest.mark.parametrize("codec", ENCODERS)
def test_fit_res_interop_matrix(codec):
    """One decoder, four frame versions: auto-detect + round-trip."""
    arrays = _f32_arrays(seed=3)
    res = FitRes(arrays, 11, {"loss": 0.25})
    dec = decode_fit_res(encode_fit_res(res, codec=codec))
    assert dec.num_examples == 11 and dec.metrics["loss"] == 0.25
    got = dec.materialize()
    assert [g.shape for g in got] == [a.shape for a in arrays]
    for g, a in zip(got, arrays):
        if codec in LOSSLESS:
            assert g.tobytes() == a.tobytes()
        elif codec == "bf16":
            np.testing.assert_allclose(g, a, atol=0, rtol=2 ** -8)
        else:
            assert np.abs(g.astype(np.float64) - a.astype(np.float64)).max() \
                <= _q8_bound(dec.quant)


@pytest.mark.parametrize("codec", ENCODERS)
def test_fit_ins_and_arrays_interop_matrix(codec):
    arrays = _f32_arrays(seed=4)
    tol = {"legacy": 0.0, "flat": 0.0}.get(codec)
    dec = decode_fit_ins(encode_fit_ins(FitIns(arrays, {"round": 2}),
                                        codec=codec))
    assert dec.config["round"] == 2
    back = bytes_to_arrays(arrays_to_bytes(arrays, codec=codec))
    for path in (dec.parameters, back):
        for g, a in zip(path, arrays):
            if tol == 0.0:
                assert g.tobytes() == a.tobytes()
            else:
                np.testing.assert_allclose(
                    g.astype(np.float64), a.astype(np.float64), atol=0.02)
    # client-facing decodes must be writable even for compressed frames
    dec.parameters[0] += 1.0


# repro: allow[codec-literal] reason=deliberately-unregistered bytes probing the UnsupportedCodec path
@pytest.mark.parametrize("magic", [0xF0, 0xF6, 0xFF])
def test_reserved_version_bytes_raise_unsupported_codec(magic):
    frame = encode_fit_res(FitRes(_f32_arrays(), 1, {}), codec="flat")
    doctored = bytes([magic]) + frame[1:]
    for decoder in (decode_fit_res, decode_fit_ins, bytes_to_arrays):
        with pytest.raises(UnsupportedCodec):
            decoder(doctored)


def test_lossy_request_falls_back_to_flat_for_non_fp32():
    """Ineligible payloads (mixed dtype / uint64 SecAgg shares) silently
    ship on the lossless 0xF1 frame — negotiation is advisory."""
    mixed = [np.ones((4, 4), np.float32), np.arange(6, dtype=np.int32)]
    u64 = [RNG.integers(0, 2 ** 63, size=100, dtype=np.uint64)]
    for arrays in (mixed, u64):
        for codec in ("bf16", "q8"):
            b = encode_fit_res(FitRes(arrays, 1, {}), codec=codec)
            assert b[0] == FLAT_MAGIC
            got = decode_fit_res(b).materialize()
            for g, a in zip(got, arrays):
                assert g.tobytes() == a.tobytes()
    assert not quantizable(layout_of(mixed))


def test_quantized_decode_is_zero_copy():
    arrays = [RNG.normal(size=(256, 64)).astype(np.float32)]
    for codec, magic in (("bf16", BF16_MAGIC), ("q8", Q8_MAGIC)):
        b = encode_fit_res(FitRes(arrays, 1, {}), codec=codec)
        assert b[0] == magic
        q = decode_fit_res(b).quant
        assert not q.data.flags["OWNDATA"]
        if q.scales is not None:
            assert not q.scales.flags["OWNDATA"]


def test_q8_wire_size_is_4x_smaller():
    arrays = [RNG.normal(size=(1 << 20,)).astype(np.float32)]
    flat = encode_fit_res(FitRes(arrays, 1, {}), codec="flat")
    q8 = encode_fit_res(FitRes(arrays, 1, {}), codec="q8")
    assert len(flat) / len(q8) > 3.5


# ---------------------------------------------------------------------------
# delta encoding
# ---------------------------------------------------------------------------
def test_delta_roundtrip_bounded_by_update_magnitude():
    base_arrays = _f32_arrays(seed=7)
    delta_scale = 1e-3                     # update << weights
    result = [a + RNG.normal(0, delta_scale, size=a.shape).astype(np.float32)
              for a in base_arrays]
    base = FlatParams.from_arrays(base_arrays)
    b = encode_fit_res(FitRes(result, 5, {}), codec="q8", base=base)
    dec = decode_fit_res(b)
    assert dec.quant.is_delta
    dec.quant.base = base
    got = dec.materialize()
    bound = _q8_bound(dec.quant)
    assert bound < delta_scale             # bound scales with the UPDATE
    for g, r in zip(got, result):
        assert np.abs(g.astype(np.float64) - r.astype(np.float64)).max() \
            <= bound


def test_delta_without_base_raises_clearly():
    base = FlatParams.from_arrays(_f32_arrays(seed=8))
    b = encode_fit_res(FitRes(_f32_arrays(seed=9), 5, {}), codec="q8",
                       base=base)
    dec = decode_fit_res(b)
    with pytest.raises(ValueError, match="base"):
        dec.materialize()
    # a delta frame must never be decodable as plain client-facing params
    with pytest.raises(ValueError, match="delta"):
        decode_fit_ins(b)


def test_delta_base_layout_mismatch_falls_back_lossless():
    base = FlatParams.from_arrays([np.ones((3, 3), np.float32)])
    result = _f32_arrays(seed=10)
    b = encode_fit_res(FitRes(result, 5, {}), codec="q8", base=base)
    assert b[0] == FLAT_MAGIC


# ---------------------------------------------------------------------------
# fused dequantize+accumulate kernels
# ---------------------------------------------------------------------------
def _quantized_results(n_clients, seed, base):
    rng = np.random.default_rng(seed)
    results_f32, results_q = [], []
    for c in range(n_clients):
        arrays = [a + rng.normal(0, 1e-3, size=a.shape).astype(np.float32)
                  for a in base.to_arrays()]
        w = 10 + 3 * c
        results_f32.append((f"site-{c}", FitRes(arrays, w, {})))
        dec = decode_fit_res(encode_fit_res(FitRes(arrays, w, {}),
                                            codec="q8", base=base))
        dec.quant.base = base
        results_q.append((f"site-{c}", dec))
    return results_f32, results_q


@pytest.mark.parametrize("name,kw", [
    ("fedavg", {}), ("fedavg", {"low_memory": True}),
    ("fedmedian", {}), ("fedtrimmedmean", {"beta": 0.25}),
    ("krum", {"num_byzantine": 1, "num_selected": 2}),
])
def test_strategies_consume_compressed_results(name, kw):
    """Accumulators stream QuantParams through the fused kernels; output
    matches the fp32 path within the quantization bound."""
    base = FlatParams.from_arrays(_f32_arrays(seed=31))
    results_f32, results_q = _quantized_results(6, 32, base)
    current = base.to_arrays()
    want, _ = make_strategy(name, **kw).aggregate_fit(
        1, results_f32, [], current)
    got, _ = make_strategy(name, **kw).aggregate_fit(
        1, results_q, [], current)
    bound = max(_q8_bound(r.quant) for _, r in results_q)
    for g, w in zip(got, want):
        assert np.abs(g.astype(np.float64) - w.astype(np.float64)).max() \
            <= 2 * bound + 1e-9


def test_batch_only_strategy_sees_materialized_parameters():
    """A FedAvg subclass overriding only the batch aggregate_fit predates
    the compressed wire format and reads res.parameters directly; the base
    accumulator must materialize quantized results before deferring."""
    from repro.fl.strategy import FedAvg

    seen = []

    class BatchOnly(FedAvg):
        def aggregate_fit(self, rnd, results, failures, current):
            for _, r in results:
                assert r.parameters is not None
                seen.append(len(r.parameters))
            return current, {"n": len(results)}

    base = FlatParams.from_arrays(_f32_arrays(seed=51))
    _, results_q = _quantized_results(3, 52, base)
    strat = BatchOnly()
    acc = strat.fit_accumulator(1, base.to_arrays())
    assert type(acc).__name__ == "FitAccumulator"   # routed to the base
    for node, r in results_q:
        acc.add(node, r)
    _, m = acc.finalize([])
    assert m["n"] == 3 and seen == [3, 3, 3]


def test_incremental_accumulator_matches_batch_on_compressed():
    base = FlatParams.from_arrays(_f32_arrays(seed=41))
    _, results_q = _quantized_results(5, 42, base)
    strat = make_strategy("fedavg")
    acc = strat.fit_accumulator(1, base.to_arrays())
    for node, r in results_q:
        acc.add(node, r)
    got, m = acc.finalize([])
    want, _ = strat.aggregate_fit(1, results_q, [], base.to_arrays())
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    assert m["num_clients"] == 5


# ---------------------------------------------------------------------------
# SecAgg: mask cancellation in the quantized integer domain
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 50), st.integers(2, 5),
       st.integers(1, 100))
def test_secagg_masks_cancel_in_integer_domain(seed, n, n_sites, round_):
    """Pairwise masks over the fixed-point uint64 flat buffer cancel
    EXACTLY (mod 2^64) in the server's wrapping sum, whatever the values,
    fleet size, or round."""
    from repro.fl.mods import _prg_mask_flat, quantize

    rng = np.random.default_rng(seed)
    layout = layout_of([np.empty(n, np.float32)])
    xs = [rng.normal(0, 100, size=n) for _ in range(n_sites)]
    qs = [quantize(x) for x in xs]
    masked = []
    for i in range(n_sites):
        share = qs[i].copy()
        for j in range(n_sites):
            if i == j:
                continue
            pair_seed = 7_000_003 * min(i, j) + max(i, j)
            share += _prg_mask_flat(pair_seed, round_, layout,
                                    positive=i < j)
        masked.append(share)
    got = np.zeros(n, np.uint64)
    for m in masked:
        got += m
    want = np.zeros(n, np.uint64)
    for q in qs:
        want += q
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# negotiation (unit + end-to-end)
# ---------------------------------------------------------------------------
def test_client_app_advertises_codecs():
    from repro.fl.client import ClientApp, NumPyClient

    class C(NumPyClient):
        def get_properties(self, config):
            return {"gpu": 1}

    app = ClientApp(lambda cid: C().to_client())
    t = TaskIns("get_properties", 0, b"", task_id="t")
    tr = decode_task_res(app.handle(encode_task_ins(t)))
    props = decode_properties_res(tr.payload)
    assert props["gpu"] == 1
    assert set(WIRE_CODECS) <= set(props["codecs"])


class _FakeDriver:
    """Scripted driver: maps task_type -> node -> TaskRes payload/error."""

    def __init__(self, nodes, on_properties):
        self.nodes = nodes
        self.on_properties = on_properties

    def node_ids(self):
        return list(self.nodes)

    def send_and_receive_iter(self, tasks, timeout):
        from repro.fl.messages import (TaskRes, decode_task_ins,
                                       encode_task_res)
        for node, tb in sorted(tasks.items()):
            t = decode_task_ins(tb)
            assert t.task_type == "get_properties"
            payload, error = self.on_properties(node)
            yield node, encode_task_res(TaskRes(
                t.task_type, t.round, payload, task_id=t.task_id,
                error=error))


def _negotiate(on_properties, codec="q8"):
    from repro.fl.server import ServerApp, ServerConfig
    from repro.fl.strategy import FedAvg

    app = ServerApp(ServerConfig(codec=codec), FedAvg())
    return app._negotiate_codec(_FakeDriver(["a", "b"], on_properties),
                                ["a", "b"])


def test_negotiation_picks_advertised_codec():
    from repro.fl.messages import encode_properties_res
    ok = encode_properties_res({"codecs": ["flat", "q8", "bf16"]})
    assert _negotiate(lambda node: (ok, "")) == ("q8", "")


def test_negotiation_demotes_when_any_node_lacks_codec():
    """Demotion is never silent: the note names the culprit node."""
    from repro.fl.messages import encode_properties_res
    full = encode_properties_res({"codecs": ["flat", "q8"]})
    old = encode_properties_res({"codecs": ["flat", "legacy"]})
    codec, note = _negotiate(lambda node: (full if node == "a" else old, ""))
    assert codec == "flat" and "b" in note and "q8" in note


def test_negotiation_demotes_when_node_errors_on_unknown_task():
    """Seed-era peers error on get_properties — the fleet stays lossless."""
    from repro.fl.messages import encode_properties_res
    full = encode_properties_res({"codecs": ["flat", "q8"]})
    codec, note = _negotiate(
        lambda node: (full, "") if node == "a"
        else (b"", "unknown task type"))
    assert codec == "flat" and "b" in note


def test_end_to_end_negotiated_q8_converges_within_tolerance():
    from repro.core import run_native
    from repro.fl import FedAvg, ServerApp, ServerConfig
    from repro.fl.quickstart import make_client_app

    sites = ["site-1", "site-2", "site-3"]
    h_flat = run_native(ServerApp(ServerConfig(num_rounds=2), FedAvg()),
                        lambda s: make_client_app(s), sites)
    h_q8 = run_native(ServerApp(ServerConfig(num_rounds=2, codec="q8"),
                                FedAvg()),
                      lambda s: make_client_app(s), sites)
    assert h_q8.rounds[-1].metrics["wire_codec"] == "q8"
    assert "wire_codec" not in h_flat.rounds[-1].metrics
    for (_, lf), (_, lq) in zip(h_flat.losses(), h_q8.losses()):
        assert abs(lf - lq) < 0.05, (lf, lq)
    d = max(float(np.abs(a.astype(np.float64) - b.astype(np.float64)).max())
            for a, b in zip(h_flat.final_parameters, h_q8.final_parameters))
    assert d < 0.05


def test_demoted_run_reports_wire_codec_flat_with_note():
    """ServerConfig requests q8 but one node only speaks flat/legacy: the
    run demotes AND says so in every round's metrics."""
    from repro.core import run_native
    from repro.fl import ClientApp, FedAvg, ServerApp, ServerConfig
    from repro.fl.quickstart import QuickstartClient

    class OldClient(QuickstartClient):
        def get_properties(self, config):
            return {"codecs": ["flat", "legacy"]}

    sites = ["site-1", "site-2", "site-3"]

    def app_fn(site):
        cls = OldClient if site == "site-2" else QuickstartClient
        return ClientApp(lambda cid: cls(site).to_client())

    h = run_native(ServerApp(ServerConfig(num_rounds=1, codec="q8"),
                             FedAvg()), app_fn, sites)
    m = h.rounds[-1].metrics
    assert m["wire_codec"] == "flat"
    assert "site-2" in m["wire_codec_demotion"]


def test_end_to_end_secagg_composes_with_q8_negotiation():
    """SecAgg's uint64 masked shares ship losslessly (0xF1) under a q8
    negotiation: masks still cancel exactly, the run matches the plain
    FedAvg q8 run up to the lossless-vs-lossy uplink difference."""
    import zlib
    from repro.core import run_native
    from repro.fl import (FedAvg, SecAggFedAvg, SecAggMod, ServerApp,
                          ServerConfig)
    from repro.fl.quickstart import make_client_app

    sites = ["site-1", "site-2", "site-3"]

    def seed_fn(a, b):
        lo, hi = sorted([a, b])
        return zlib.crc32(f"{lo}|{hi}".encode())

    plain = run_native(ServerApp(ServerConfig(num_rounds=2, codec="q8"),
                                 FedAvg()),
                       lambda s: make_client_app(s), sites)
    sec = run_native(ServerApp(ServerConfig(num_rounds=2, codec="q8"),
                               SecAggFedAvg()),
                     lambda s: make_client_app(s, mods=[SecAggMod(
                         site=s, peers=sites, pairwise_seed_fn=seed_fn)]),
                     sites)
    d = max(float(np.abs(a.astype(np.float64) - b.astype(np.float64)).max())
            for a, b in zip(plain.final_parameters, sec.final_parameters))
    assert d < 0.02, d

"""End-to-end quickstart scenario grid: codec x strategy x fault.

Every cell runs the full stack — codec negotiation (get_properties),
quantized downlink / int8-delta uplink where negotiated, arrival-order
streaming aggregation, shared-deadline fault handling — over a real
SuperLink fleet, and asserts:

- the run completes every round (faults demote to recorded failures);
- convergence within tolerance of the lossless fault-free baseline;
- ``RoundRecord.failures`` names exactly the faulted nodes (and nothing
  else), and quorum knobs abort via ``QuorumNotMet`` when violated;
- a negotiated lossy codec is reported in ``RoundRecord.metrics``.
"""
import os
import threading
import time
from contextlib import contextmanager

import numpy as np
import pytest

from repro.core.superlink import (NativeConnection, SuperLink,
                                  SuperLinkDriver, SuperNode)
from repro.fl import (ClientApp, QuorumNotMet, ServerApp, ServerConfig,
                      make_strategy)
from repro.fl.quickstart import QuickstartClient, init_mlp

pytestmark = pytest.mark.slow

import jax  # noqa: E402  (after the marker so collection stays cheap)

CODECS = ("flat", "bf16", "q8")
STRATEGIES = {
    "fedavg": {},
    "fedtrimmedmean": {"beta": 0.25},
    "krum": {"num_byzantine": 0, "num_selected": 1},
}
FAULTS = ("none", "straggler", "dead")

N_SITES = 4
ROUNDS = 2
DIM, CLASSES, HIDDEN = 16, 4, 64
STRAGGLER_DELAY = 0.25
DEAD_TIMEOUT = 0.9
CLIENT_KW = dict(dim=DIM, classes=CLASSES, n_train=128, n_test=64,
                 epochs=1, lr=0.05)


class FaultyQuickstart(QuickstartClient):
    """Quickstart client with an injectable fault: ``delay`` sleeps before
    training (straggler), ``dead`` blocks on an event the fixture releases
    at teardown (node never answers inside the deadline)."""

    def __init__(self, site, *, delay=0.0, dead=None, **kw):
        super().__init__(site, **kw)
        self._delay = delay
        self._dead = dead

    def fit(self, parameters, config):
        if self._dead is not None:
            self._dead.wait()
        if self._delay:
            time.sleep(self._delay)
        return super().fit(parameters, config)

    def evaluate(self, parameters, config):
        if self._dead is not None:
            self._dead.wait()
        return super().evaluate(parameters, config)


@contextmanager
def quickstart_fleet(fault: str):
    """SuperLink + N quickstart SuperNodes; the last site carries the
    fault.  Yields (driver, faulted_site_or_None).

    ``REPRO_TRANSPORT=tcp`` swaps the in-process connections for a
    :class:`~repro.core.transport.TcpSuperLink` listener plus one real
    socket per node — the CI ``tcp-mp`` lane re-runs the scenario grid
    over it to prove the apps are transport-agnostic."""
    use_tcp = os.environ.get("REPRO_TRANSPORT") == "tcp"
    sites = [f"site-{i}" for i in range(1, N_SITES + 1)]
    dead_ev = threading.Event() if fault == "dead" else None
    faulted = sites[-1] if fault != "none" else None
    if use_tcp:
        from repro.core.transport import TcpFleetConnection, TcpSuperLink
        link = TcpSuperLink("127.0.0.1", 0)
        host, port = link.address
        conn_for = lambda s: TcpFleetConnection(host, port, s)  # noqa: E731
    else:
        link = SuperLink()
        conn_for = lambda s: NativeConnection(link)  # noqa: E731
    nodes = []
    for s in sites:
        kw = dict(CLIENT_KW)
        if s == faulted and fault == "straggler":
            kw["delay"] = STRAGGLER_DELAY
        if s == faulted and fault == "dead":
            kw["dead"] = dead_ev
        client = FaultyQuickstart(s, **kw)
        nodes.append(SuperNode(
            s, ClientApp(lambda cid, c=client: c.to_client()),
            conn_for(s)))
    for n in nodes:
        n.start()
    try:
        yield SuperLinkDriver(link, expected_nodes=N_SITES), faulted
    finally:
        if dead_ev is not None:
            dead_ev.set()
        for n in nodes:
            n.stop()
        if use_tcp:
            link.close()


def run_scenario(codec: str, strategy: str, fault: str, *, rounds=ROUNDS,
                 **strategy_kw):
    kw = dict(STRATEGIES.get(strategy, {}))
    kw.update(strategy_kw)
    initial = init_mlp(jax.random.key(0), DIM, HIDDEN, CLASSES)
    strat = make_strategy(strategy, initial_parameters=initial, **kw)
    timeout = DEAD_TIMEOUT if fault == "dead" else 30.0
    app = ServerApp(
        ServerConfig(num_rounds=rounds, round_timeout=timeout,
                     codec=None if codec == "flat" else codec), strat)
    with quickstart_fleet(fault) as (driver, faulted):
        return app.run(driver), faulted


@pytest.fixture(scope="module")
def baseline_loss():
    """Lossless fault-free FedAvg: the reference every cell must stay
    within tolerance of."""
    h, _ = run_scenario("flat", "fedavg", "none")
    loss = h.losses()[-1][1]
    assert np.isfinite(loss)
    return loss


@pytest.mark.parametrize("fault", FAULTS)
@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
@pytest.mark.parametrize("codec", CODECS)
def test_scenario_grid(codec, strategy, fault, baseline_loss):
    h, faulted = run_scenario(codec, strategy, fault)

    # every round completed and evaluated
    assert len(h.rounds) == ROUNDS
    losses = h.losses()
    assert len(losses) == ROUNDS
    assert all(np.isfinite(loss) for _, loss in losses)
    assert h.final_parameters is not None

    # convergence within tolerance of the lossless fault-free baseline
    # (krum aggregates a single client; a generous but finite bound still
    # proves training happened and nothing diverged)
    assert losses[-1][1] <= baseline_loss + 0.35

    for rec in h.rounds:
        failed = {n for n, _ in rec.failures}
        if fault == "dead":
            # the dead node misses the shared deadline in BOTH phases and
            # is recorded, never round-aborting; nobody else fails
            assert failed == {faulted}
            assert all(reason == "timeout" for _, reason in rec.failures)
        else:
            # a straggler inside the deadline is not a failure
            assert failed == set()
        expect_clients = N_SITES - (1 if fault == "dead" else 0)
        if "num_clients" in rec.metrics:       # fedavg / trimmed-mean
            assert rec.metrics["num_clients"] == expect_clients
        if strategy == "krum":
            picked = rec.metrics["krum_selected"]
            assert len(picked) == 1
            assert picked[0] != faulted or fault != "dead"
        if codec != "flat":
            # the lossy codec actually negotiated (quickstart clients
            # advertise every codec), not silently demoted
            assert rec.metrics["wire_codec"] == codec
            assert "wire_codec_demotion" not in rec.metrics


def test_quorum_not_met_aborts_run_with_dead_node():
    """min_available above the surviving population: the round must abort
    loudly (QuorumNotMet) instead of aggregating a silent minority."""
    with pytest.raises(QuorumNotMet):
        run_scenario("flat", "fedavg", "dead", min_available=N_SITES)


def test_krum_byzantine_floor_enforced_as_quorum():
    """Krum's n >= 2f+3 population floor: f=1 needs 5 results but the
    fleet only has 4 — QuorumNotMet even with zero faults."""
    with pytest.raises(QuorumNotMet):
        run_scenario("flat", "krum", "none", num_byzantine=1)


def test_straggler_round_does_not_wait_for_deadline():
    """With one straggler the round ends ~max(client time), not at the
    shared deadline — the arrival-order driver overlaps decode+accumulate
    with the straggler's compute."""
    t0 = time.monotonic()
    h, _ = run_scenario("flat", "fedavg", "straggler")
    elapsed = time.monotonic() - t0
    assert len(h.rounds) == ROUNDS
    assert not h.rounds[-1].failures
    # 30s deadline; generous bound proves nobody waited it out
    assert elapsed < 15.0


@pytest.mark.pallas
def test_pallas_backend_scenario_bitwise_vs_numpy():
    """The tentpole end-to-end: the same faulted quantized run on the
    Pallas aggregation backend must reproduce the numpy run bitwise
    (both are deterministic given the canonicalized client order)."""
    h_np, _ = run_scenario("q8", "fedavg", "straggler",
                           backend="numpy")
    h_pl, _ = run_scenario("q8", "fedavg", "straggler",
                           backend="pallas")
    assert h_np.losses() == h_pl.losses()
    for a, b in zip(h_np.final_parameters, h_pl.final_parameters):
        np.testing.assert_array_equal(a, b)

"""benchmarks/compare.py — the benchmark-trajectory CI gate.

Drives the comparator with doctored snapshots: a >15% drop in any gated
throughput/speedup row, a broken equivalence flag, a missing gated row, or
a wire-format reduction below the 3.5x floor must all fail; noise within
the threshold must pass.  Also round-trips the snapshot writer
(``rows_from_csv``) so the gate consumes exactly what ``run --json``
emits.
"""
import json
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.compare import compare_rows, load_rows, main  # noqa: E402
from benchmarks.run import rows_from_csv  # noqa: E402


def _row(us=1000.0, **derived):
    return {"us": us, "raw": "", "derived": derived}


def _baseline():
    return {
        "agg_throughput_50M_16clients": _row(
            mbps=4500.0, speedup_vs_legacy=7.8, match=True),
        "agg_throughput_1M_4clients": _row(
            mbps=900.0, speedup_vs_legacy=3.0, match=True),
        "quantized_agg_50M_16clients": _row(mbps=400.0),
        "wire_bytes_50M_16clients": _row(reduction=3.98, match_tol=True),
        "agg_throughput_500M_4clients": _row(us=0, skipped="oom"),
        "pallas_agg_50M_16clients": _row(
            interp_mbps=66.0, match=True, interpret_mode=True),
        "pallas_agg_1M_4clients": _row(
            interp_mbps=25.0, match=True, q8_match=True,
            interpret_mode=True),
        "fig5_flare_round": _row(bitwise_match=True),
        "straggler_overlap_4clients": _row(round_over_delta=1.06),
    }


def test_identical_snapshots_pass():
    assert compare_rows(_baseline(), _baseline(), 0.15) == []


def test_noise_within_threshold_passes():
    new = _baseline()
    new["agg_throughput_50M_16clients"]["derived"]["mbps"] = 4500.0 * 0.90
    new["agg_throughput_1M_4clients"]["derived"]["speedup_vs_legacy"] = 2.9
    assert compare_rows(_baseline(), new, 0.15) == []


def test_doctored_mbps_regression_fails():
    new = _baseline()
    new["agg_throughput_50M_16clients"]["derived"]["mbps"] = 4500.0 * 0.80
    problems = compare_rows(_baseline(), new, 0.15)
    assert len(problems) == 1 and "mbps regressed 20.0%" in problems[0]


def test_doctored_speedup_regression_fails():
    new = _baseline()
    new["agg_throughput_50M_16clients"]["derived"]["speedup_vs_legacy"] = 5.0
    assert any("speedup_vs_legacy" in p
               for p in compare_rows(_baseline(), new, 0.15))


def test_quantized_agg_rows_are_gated_too():
    new = _baseline()
    new["quantized_agg_50M_16clients"]["derived"]["mbps"] = 400.0 * 0.5
    assert any("quantized_agg_50M_16clients" in p
               for p in compare_rows(_baseline(), new, 0.15))


def test_missing_gated_row_fails_but_skipped_rows_dont():
    new = _baseline()
    del new["agg_throughput_1M_4clients"]
    del new["agg_throughput_500M_4clients"]     # skipped in baseline: fine
    problems = compare_rows(_baseline(), new, 0.15)
    assert len(problems) == 1 and "agg_throughput_1M_4clients" in problems[0]


def test_broken_equivalence_flag_fails_even_if_fast():
    new = _baseline()
    new["agg_throughput_50M_16clients"]["derived"].update(
        mbps=9000.0, match=False)
    assert any("match=False" in p for p in compare_rows(_baseline(), new,
                                                        0.15))
    new2 = _baseline()
    new2["fig5_flare_round"]["derived"]["bitwise_match"] = False
    assert any("bitwise_match" in p
               for p in compare_rows(_baseline(), new2, 0.15))


def test_wire_reduction_floor_enforced():
    new = _baseline()
    new["wire_bytes_50M_16clients"]["derived"]["reduction"] = 3.0
    assert any("3.5" in p for p in compare_rows(_baseline(), new, 0.15))


def test_missing_or_skipped_wire_rows_fail():
    """Losing the wire_bytes_* / wire_codec_convergence rows would retire
    the 3.5x-reduction and convergence checks with them — gated."""
    base = _baseline()
    base["wire_codec_convergence"] = _row(within_tol=True)
    gone = dict(base)
    del gone["wire_bytes_50M_16clients"]
    assert any("wire_bytes_50M_16clients" in p
               for p in compare_rows(base, gone, 0.15))
    skipped = json.loads(json.dumps(base))
    skipped["wire_codec_convergence"] = _row(us=0, skipped="crash")
    assert any("wire_codec_convergence" in p
               for p in compare_rows(base, skipped, 0.15))


def test_pallas_rows_gate_presence_and_match_not_timing():
    """pallas_agg_* rows: a missing row or a broken match/q8_match flag
    fails; their interp_mbps (interpret-mode, trace-overhead-bound) may
    move freely."""
    gone = _baseline()
    del gone["pallas_agg_50M_16clients"]
    assert any("pallas_agg_50M_16clients" in p
               for p in compare_rows(_baseline(), gone, 0.15))
    broken = _baseline()
    broken["pallas_agg_50M_16clients"]["derived"]["match"] = False
    assert any("pallas_agg_50M_16clients: match=False" in p
               for p in compare_rows(_baseline(), broken, 0.15))
    broken_q8 = _baseline()
    broken_q8["pallas_agg_1M_4clients"]["derived"]["q8_match"] = False
    assert any("q8_match=False" in p
               for p in compare_rows(_baseline(), broken_q8, 0.15))
    slow = _baseline()
    slow["pallas_agg_50M_16clients"]["derived"]["interp_mbps"] = 1.0
    assert compare_rows(_baseline(), slow, 0.15) == []


def test_committed_baseline_carries_pallas_rows():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BENCH_baseline.json")
    if not os.path.exists(path):
        pytest.skip("baseline not generated yet")
    rows = load_rows(path)
    assert rows["pallas_agg_50M_16clients"]["derived"]["match"] is True
    assert rows["pallas_agg_1M_4clients"]["derived"]["q8_match"] is True


def test_ungated_timing_rows_never_flag():
    new = _baseline()
    new["straggler_overlap_4clients"]["derived"]["round_over_delta"] = 9.9
    assert compare_rows(_baseline(), new, 0.15) == []


def test_rows_from_csv_roundtrip():
    csv = (
        "name,us_per_call,derived\n"
        "some log line\n"
        "agg_throughput_50M_16clients,123456,"
        "mbps=4500;speedup_vs_legacy=7.80x;match=True\n"
        "wire_bytes_50M_16clients,1000,reduction=3.98x;match_tol=True\n"
        "kernel_flash_attention,42,interpret_mode;flops=1.34e+08\n")
    rows = rows_from_csv(csv)
    assert rows["agg_throughput_50M_16clients"]["derived"] == {
        "mbps": 4500.0, "speedup_vs_legacy": 7.8, "match": True}
    assert rows["wire_bytes_50M_16clients"]["derived"]["reduction"] == 3.98
    assert rows["kernel_flash_attention"]["derived"]["interpret_mode"] is True
    assert "name" not in rows and "some log line" not in rows


def test_cli_end_to_end(tmp_path):
    base_p = tmp_path / "BENCH_baseline.json"
    good_p = tmp_path / "BENCH_good.json"
    bad_p = tmp_path / "BENCH_doctored.json"
    base_p.write_text(json.dumps({"schema": 1, "rows": _baseline()}))
    good_p.write_text(json.dumps({"schema": 1, "rows": _baseline()}))
    doctored = _baseline()
    doctored["agg_throughput_50M_16clients"]["derived"]["mbps"] = 3000.0
    bad_p.write_text(json.dumps({"schema": 1, "rows": doctored}))
    assert main([str(good_p), "--baseline", str(base_p)]) == 0
    assert main([str(bad_p), "--baseline", str(base_p)]) == 1


def test_committed_baseline_loads_and_gates_itself():
    """The repo's own baseline must parse and pass against itself."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BENCH_baseline.json")
    if not os.path.exists(path):
        pytest.skip("baseline not generated yet")
    rows = load_rows(path)
    assert any(n.startswith("agg_throughput_") for n in rows)
    assert "wire_bytes_50M_16clients" in rows
    assert rows["wire_bytes_50M_16clients"]["derived"]["reduction"] >= 3.5
    assert compare_rows(rows, rows, 0.15) == []

"""Static-analysis suite tests (src/repro/analysis/).

Three layers of assurance:

- **fixture corpus**: every rule fires on its seeded-bad fixture and
  stays silent on the clean twin (tests/_analysis_fixtures/);
- **self-run**: the checkers report zero findings on the real tree —
  src/ and tests/ obey the invariants they enforce;
- **suppression discipline**: a bare ``# repro: allow[...]`` (no
  reason=) is itself a gating finding and can never be suppressed.

The decode-freeze test at the bottom exercises the runtime behaviour the
``alias-writeable`` rule guards: every wire decode view is read-only
even when the transport hands us a writable bytearray.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import ALL_RULES, main, run_analysis
from repro.analysis.core import ADVISORY_RULES, META_RULES

TESTS_DIR = Path(__file__).resolve().parent
REPO = TESTS_DIR.parent
SRC = REPO / "src"
FIX = TESTS_DIR / "_analysis_fixtures"
CODEC_REGISTRY = FIX / "codec" / "fl" / "flat.py"


def _rules(paths):
    return {f.rule for f in run_analysis([str(p) for p in paths])}


# ---------------------------------------------------------------------------
# fixture corpus: each bad fixture fires exactly its rule(s); the clean
# twin next to it fires nothing
# ---------------------------------------------------------------------------

BAD_CASES = [
    (["locks/bad_lock_order.py"], {"lock-order"}),
    (["locks/bad_self_deadlock.py"], {"lock-order"}),
    (["locks/bad_guarded.py"], {"guarded-by"}),
    (["locks/bad_guard_annot.py"], {"guarded-by"}),
    (["locks/bad_guard_call.py"], {"guarded-by"}),
    (["locks/bad_credit_ledger.py"], {"guarded-by"}),
    (["determinism/fl/bad_set_iter.py"], {"det-set-iter"}),
    (["determinism/fl/bad_entropy.py"], {"det-entropy"}),
    (["determinism/kernels/bad_float_accum.py"], {"det-float-accum"}),
    (["determinism/kernels/bad_fori.py"], {"det-fori-trip"}),
    (["aliasing/bad_frombuffer.py"], {"alias-writeable"}),
    (["aliasing/bad_mutation.py"], {"alias-mutation"}),
    (["codec/fl/flat.py", "codec/bad_literal.py"], {"codec-literal"}),
    (["codec/fl/flat.py", "codec/bad_dispatch.py"], {"codec-dispatch"}),
    (["clocks/repro/bad_wallclock.py"], {"monotonic-clock"}),
    (["clocks/repro/bad_transport_ttl.py"], {"monotonic-clock"}),
    (["deadname/repro/bad_unused.py"], {"dead-name"}),
    (["allows/bad_bare.py"], {"bare-allow", "unknown-rule"}),
    (["parse/bad_syntax.py"], {"parse-error"}),
]

GOOD_CASES = [
    ["locks/good_lock_order.py"],
    ["locks/good_guarded.py"],
    ["locks/good_credit_ledger.py"],
    ["determinism/fl/good_set_iter.py"],
    ["determinism/fl/good_entropy.py"],
    ["determinism/kernels/good_float_accum.py"],
    ["determinism/kernels/good_fori.py"],
    ["aliasing/good_frombuffer.py"],
    ["aliasing/good_mutation.py"],
    ["codec/fl/flat.py", "codec/good_literal.py"],
    ["codec/fl/flat.py", "codec/good_dispatch.py"],
    ["clocks/repro/good_wallclock.py"],
    ["clocks/repro/good_transport_ttl.py"],
    ["deadname/repro/good_unused.py"],
    ["allows/good_allow.py"],
]


@pytest.mark.parametrize("paths,expected", BAD_CASES,
                         ids=[c[0][-1] for c in BAD_CASES])
def test_bad_fixture_fires(paths, expected):
    assert _rules(FIX / p for p in paths) == expected


@pytest.mark.parametrize("paths", GOOD_CASES,
                         ids=[c[-1] for c in GOOD_CASES])
def test_good_fixture_clean(paths):
    assert _rules(FIX / p for p in paths) == set()


def test_every_rule_covered_by_corpus():
    fired = set().union(*(exp for _, exp in BAD_CASES))
    assert fired == set(ALL_RULES), \
        "corpus must exercise every registered rule"


# ---------------------------------------------------------------------------
# self-run: the real tree is clean (this is the CI gate, in-process)
# ---------------------------------------------------------------------------

def test_self_run_zero_findings():
    findings = run_analysis([str(SRC), str(TESTS_DIR)])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_fixture_corpus_skipped_by_walker():
    # the seeded violations must never leak into a directory-level run
    findings = run_analysis([str(TESTS_DIR)])
    assert not any("_analysis_fixtures" in f.path for f in findings)


# ---------------------------------------------------------------------------
# suppression discipline
# ---------------------------------------------------------------------------

def test_bare_allow_is_rejected(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import numpy as np\n"
        "def decode(buf):\n"
        "    arr = np.frombuffer(buf)  # repro: allow[alias-writeable]\n"
        "    return arr\n")
    rules = {x.rule for x in run_analysis([str(f)])}
    # the bare pragma suppresses the underlying finding but is itself a
    # gating finding, so the net effect is still a red build
    assert rules == {"bare-allow"}


def test_reasoned_allow_suppresses(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import numpy as np\n"
        "def decode(buf):\n"
        "    # repro: allow[alias-writeable] reason=caller owns buf\n"
        "    arr = np.frombuffer(buf)\n"
        "    return arr\n")
    assert run_analysis([str(f)]) == []


def test_meta_rules_never_suppressible(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("x = 1  # repro: allow[bare-allow, unknown-rule]\n")
    rules = {x.rule for x in run_analysis([str(f)])}
    assert "bare-allow" in rules


# ---------------------------------------------------------------------------
# CLI contract (exit codes, --only, --strict)
# ---------------------------------------------------------------------------

def test_cli_exit_codes(capsys):
    assert main([str(FIX / "locks")]) == 1
    capsys.readouterr()
    assert main([str(FIX / "locks" / "good_guarded.py")]) == 0
    capsys.readouterr()
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert set(out.split()) == set(ALL_RULES)
    assert main(["--only", "no-such-rule", "."]) == 2
    assert main([str(FIX / "does-not-exist")]) == 2


def test_cli_advisory_vs_strict(capsys):
    bad = str(FIX / "deadname" / "repro" / "bad_unused.py")
    assert main([bad]) == 0          # dead-name is advisory by default
    capsys.readouterr()
    assert main(["--strict", bad]) == 1
    capsys.readouterr()
    assert ADVISORY_RULES == {"dead-name"}
    assert META_RULES == {"bare-allow", "unknown-rule", "parse-error"}


def test_cli_only_filter():
    bad = str(FIX / "clocks" / "repro" / "bad_wallclock.py")
    rules = {f.rule for f in run_analysis([bad], only=["monotonic-clock"])}
    assert rules == {"monotonic-clock"}
    assert run_analysis([bad], only=["det-set-iter"]) == []


def test_module_entrypoint_runs():
    # `python -m repro.analysis` is what CI invokes; smoke it end to end
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         str(FIX / "codec" / "fl" / "flat.py"),
         str(FIX / "codec" / "bad_dispatch.py"), "--format", "json"],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1
    assert '"codec-dispatch"' in proc.stdout


# ---------------------------------------------------------------------------
# the invariants themselves, exercised at runtime
# ---------------------------------------------------------------------------

def test_registry_is_single_source_of_truth():
    from repro.fl.flat import (PAYLOAD_CODEC_MAGICS, WIRE_MAGIC_HI,
                               WIRE_MAGIC_LO, WIRE_MAGICS)
    from repro.fl.messages import (BF16_MAGIC, FLAT_MAGIC, PARTIAL_MAGIC,
                                   Q8_MAGIC, SPARSE_MAGIC)
    assert FLAT_MAGIC == WIRE_MAGICS["flat"]
    assert BF16_MAGIC == WIRE_MAGICS["bf16"]
    assert Q8_MAGIC == WIRE_MAGICS["q8"]
    assert PARTIAL_MAGIC == WIRE_MAGICS["partial"]
    assert SPARSE_MAGIC == WIRE_MAGICS["sparse"]
    assert set(PAYLOAD_CODEC_MAGICS) <= set(WIRE_MAGICS)
    vals = list(WIRE_MAGICS.values())
    assert len(vals) == len(set(vals)), "duplicate wire byte claimed"
    assert all(WIRE_MAGIC_LO <= v <= WIRE_MAGIC_HI for v in vals)


@pytest.mark.parametrize("codec", ["flat", "bf16", "q8"])
def test_decode_views_frozen_even_from_bytearray(codec):
    # bytes-backed frombuffer views are born read-only; bytearray-backed
    # ones (real receive buffers) are writable unless explicitly frozen —
    # this is the hazard alias-writeable exists to catch
    from repro.fl import messages as M
    arrs = [np.arange(12, dtype=np.float32).reshape(3, 4),
            np.linspace(-1, 1, 7, dtype=np.float32)]
    wire = bytearray(M.arrays_to_bytes(arrs, codec=codec))
    p = M.peek_params(wire)
    views = [p.buf] if hasattr(p, "buf") else \
        [v for v in (p.data, getattr(p, "scales", None)) if v is not None]
    assert views
    for v in views:
        assert v.flags.writeable is False
        with pytest.raises((ValueError, RuntimeError)):
            v[0] = 0

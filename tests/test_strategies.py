"""FL strategy unit tests + robustness properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.fl.messages import FitRes
from repro.fl.strategy import (FedAdam, FedAvg, FedAvgM, FedMedian, FedProx,
                               FedTrimmedMean, FedYogi, Krum, make_strategy,
                               weighted_average)


def _res(arrays, n):
    return FitRes([np.asarray(a, np.float32) for a in arrays], n, {})


def test_weighted_average_exact():
    out = weighted_average([([np.array([1.0, 2.0])], 1),
                            ([np.array([3.0, 4.0])], 3)])
    np.testing.assert_allclose(out[0], [2.5, 3.5])


def test_fedavg_weighted_by_examples():
    st_ = FedAvg()
    cur = [np.zeros(2, np.float32)]
    agg, m = st_.aggregate_fit(1, [("a", _res([[2.0, 2.0]], 100)),
                                   ("b", _res([[0.0, 0.0]], 300))], [], cur)
    np.testing.assert_allclose(agg[0], [0.5, 0.5])
    assert m["num_clients"] == 2


def test_fedavgm_momentum_accumulates():
    st_ = FedAvgM(server_lr=1.0, momentum=0.5)
    cur = [np.zeros(1, np.float32)]
    a1, _ = st_.aggregate_fit(1, [("a", _res([[1.0]], 1))], [], cur)
    np.testing.assert_allclose(a1[0], [1.0])
    a2, _ = st_.aggregate_fit(2, [("a", _res([[2.0]], 1))], [], a1)
    # delta=1, velocity = 0.5*1 + 1 = 1.5 -> 1 + 1.5
    np.testing.assert_allclose(a2[0], [2.5])


def test_fedadam_matches_manual_step():
    st_ = FedAdam(server_lr=0.1, beta1=0.9, beta2=0.99, tau=1e-3)
    cur = [np.zeros(1, np.float64)]
    agg, _ = st_.aggregate_fit(1, [("a", _res([[1.0]], 1))], [], cur)
    m = 0.1 * 1.0
    v = (1e-3) ** 2 * 0.99 + 0.01 * 1.0
    want = 0.0 + 0.1 * m / (np.sqrt(v) + 1e-3)
    np.testing.assert_allclose(agg[0], [want], rtol=1e-6)


def test_fedyogi_sign_update():
    st_ = FedYogi(server_lr=0.1)
    cur = [np.zeros(1, np.float64)]
    agg, _ = st_.aggregate_fit(1, [("a", _res([[1.0]], 1))], [], cur)
    assert agg[0][0] > 0


def test_fedprox_passes_mu():
    st_ = FedProx(proximal_mu=0.05)
    cfg = st_.configure_fit(1, [np.zeros(1, np.float32)], ["a", "b"])
    assert cfg["a"].config["proximal_mu"] == 0.05


def test_median_robust_to_outlier():
    st_ = FedMedian()
    cur = [np.zeros(1, np.float32)]
    agg, _ = st_.aggregate_fit(1, [
        ("a", _res([[1.0]], 1)), ("b", _res([[1.1]], 1)),
        ("evil", _res([[1e9]], 1))], [], cur)
    assert agg[0][0] < 2.0


def test_trimmed_mean_drops_extremes():
    st_ = FedTrimmedMean(beta=0.34)
    cur = [np.zeros(1, np.float32)]
    agg, m = st_.aggregate_fit(1, [
        ("a", _res([[-1e9]], 1)), ("b", _res([[1.0]], 1)),
        ("c", _res([[1e9]], 1))], [], cur)
    np.testing.assert_allclose(agg[0], [1.0])


def test_krum_selects_inlier_cluster():
    st_ = Krum(num_byzantine=1, num_selected=1)
    cur = [np.zeros(2, np.float32)]
    inliers = [[1.0, 1.0], [1.05, 0.95], [0.95, 1.05], [1.02, 1.0]]
    results = [(f"s{i}", _res([v], 1)) for i, v in enumerate(inliers)]
    results.append(("evil", _res([[50.0, -50.0]], 1)))
    agg, m = st_.aggregate_fit(1, results, [], cur)
    assert np.linalg.norm(np.asarray(agg[0]) - 1.0) < 0.2
    assert "evil" not in m["krum_selected"] or len(m["krum_selected"]) > 1


def test_make_strategy_registry():
    for name in ("fedavg", "fedavgm", "fedadam", "fedyogi", "fedprox",
                 "fedmedian", "fedtrimmedmean", "krum"):
        assert make_strategy(name) is not None
    with pytest.raises(KeyError):
        make_strategy("nope")


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100), min_size=2, max_size=8),
       st.lists(st.integers(1, 1000), min_size=2, max_size=8))
def test_fedavg_bounded_by_extremes(vals, weights):
    n = min(len(vals), len(weights))
    vals, weights = vals[:n], weights[:n]
    results = [(f"s{i}", _res([[v]], w)) for i, (v, w) in
               enumerate(zip(vals, weights))]
    agg, _ = FedAvg().aggregate_fit(1, results, [],
                                    [np.zeros(1, np.float32)])
    assert min(vals) - 1e-3 <= agg[0][0] <= max(vals) + 1e-3


def test_aggregate_evaluate_weighted():
    st_ = FedAvg()
    from repro.fl.messages import EvaluateRes

    loss, metrics = st_.aggregate_evaluate(1, [
        ("a", EvaluateRes(1.0, 100, {"accuracy": 1.0})),
        ("b", EvaluateRes(3.0, 300, {"accuracy": 0.0}))], [])
    assert abs(loss - 2.5) < 1e-9
    assert abs(metrics["accuracy"] - 0.25) < 1e-9

"""Socket transport suite: framing, flow control, and the TCP Fleet path.

Unit layers (no sockets / loopback socketpairs) run in tier-1; the
``tcp`` marker covers the real-network integration tests the CI
``tcp-mp`` lane re-runs, including the 16-process round and the
kill -9 fault drill.
"""
import multiprocessing as mp
import os
import socket
import ssl
import subprocess
import threading
import time

import numpy as np
import pytest

from repro.core.flowcontrol import CreditGate, CreditLedger
from repro.core.framing import (FT_BYE, FT_HELLO, FT_PING, FT_PONG, FT_REQ,
                                FT_RES, FT_WELCOME, FrameError, FrameReader,
                                control_frame, data_frame_parts, frame_nbytes,
                                pack_unary, parse_control, send_parts,
                                split_data, unpack_unary)
from repro.core.superlink import SuperLinkDriver, SuperNode
from repro.core.transport import (TcpFleetConnection, TcpSuperLink,
                                  run_supernode)
from repro.fl import ClientApp, NumPyClient, ServerApp, ServerConfig
from repro.fl.strategy import make_strategy
from repro.runtime.reliable import RequestTimeout


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------
def test_control_frame_roundtrip_byte_at_a_time():
    frame = control_frame(FT_HELLO, {"node": "site-1", "proto": 1})
    r = FrameReader()
    got = []
    for i in range(len(frame)):
        got.extend(r.feed(frame[i:i + 1]))      # worst-case chunking
    assert len(got) == 1
    ftype, payload = got[0]
    assert ftype == FT_HELLO
    assert parse_control(payload) == {"node": "site-1", "proto": 1}


def test_many_frames_in_one_chunk():
    blob = b"".join(control_frame(FT_PING, {"t": float(i)})
                    for i in range(7))
    got = FrameReader().feed(blob)
    assert [parse_control(p)["t"] for _, p in got] == [float(i)
                                                       for i in range(7)]


def test_data_frame_zero_copy_body():
    body = np.arange(1024, dtype=np.float32).tobytes()
    parts = data_frame_parts(FT_REQ, {"i": "n:0", "m": "push_task_res"},
                             body)
    assert frame_nbytes(parts) == sum(len(p) for p in parts)
    r = FrameReader()
    (ftype, payload), = r.feed(b"".join(parts))
    assert ftype == FT_REQ
    header, view = split_data(payload)
    assert header == {"i": "n:0", "m": "push_task_res"}
    assert isinstance(view, memoryview) and view.readonly
    # the zero-copy decode the transport relies on: frombuffer straight
    # off the frame view, bitwise intact
    # repro: allow[alias-writeable] reason=view is readonly; write asserted to raise below
    arr = np.frombuffer(view, dtype=np.float32)
    assert arr.tobytes() == body
    with pytest.raises((TypeError, ValueError)):
        # repro: allow[alias-mutation] reason=asserting the frozen view rejects writes
        arr[0] = 1.0


def test_empty_body_data_frame():
    parts = data_frame_parts(FT_RES, {"i": "n:1"}, b"")
    assert len(parts) == 1                       # no zero-length send part
    (_, payload), = FrameReader().feed(b"".join(parts))
    header, view = split_data(payload)
    assert header == {"i": "n:1"} and view.nbytes == 0


def test_frame_length_limits():
    r = FrameReader(max_frame=64)
    with pytest.raises(FrameError):
        r.feed(b"\xff\xff\xff\xff")              # absurd length prefix
    r = FrameReader()
    with pytest.raises(FrameError):
        r.feed(b"\x00\x00\x00\x00")              # zero-length frame


def test_split_data_rejects_header_overrun():
    import struct
    payload = struct.pack("<I", 255)             # hlen=255, nothing follows
    frame = struct.pack("<I", 1 + len(payload)) + bytes((FT_REQ,)) + payload
    (_, view), = FrameReader().feed(frame)
    with pytest.raises(FrameError):
        split_data(view)


def test_unary_envelope_roundtrip():
    b = pack_unary("push_task_res", b"\x00\xf1payload")
    assert unpack_unary(b) == ("push_task_res", b"\x00\xf1payload")


def test_socketpair_partial_reads_and_short_writes():
    """A model-sized frame through deliberately tiny kernel buffers: the
    sender's short-write loop and the reader's incremental recv_into must
    reassemble it bitwise."""
    a, b = socket.socketpair()
    try:
        a.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 4096)
        b.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4096)
        body = os.urandom(1 << 20)
        parts = data_frame_parts(FT_REQ, {"i": "x:1", "m": "m"}, body)
        t = threading.Thread(target=send_parts, args=(a, *parts))
        t.start()
        r = FrameReader()
        frames = []
        while not frames:
            got = r.read_from(b)
            assert got is not None
            frames = got
        t.join()
        header, view = split_data(frames[0][1])
        assert header["i"] == "x:1" and bytes(view) == body
    finally:
        a.close()
        b.close()


def test_read_from_timeout_preserves_partial_frame():
    a, b = socket.socketpair()
    try:
        b.settimeout(0.05)
        frame = control_frame(FT_PONG, {"t": 1.0})
        a.sendall(frame[:3])                     # prefix cut short
        r = FrameReader()
        with pytest.raises(socket.timeout):
            while True:
                r.read_from(b)
        a.sendall(frame[3:])                     # resume the same frame
        frames = []
        while not frames:
            frames = r.read_from(b)
        assert frames[0][0] == FT_PONG
    finally:
        a.close()
        b.close()


def test_read_from_eof_mid_frame_raises():
    a, b = socket.socketpair()
    try:
        a.sendall(control_frame(FT_BYE, {"reason": "x"})[:6])
        a.close()
        r = FrameReader()
        with pytest.raises(ConnectionError):
            while True:
                if r.read_from(b) is None:
                    raise AssertionError("clean EOF despite partial frame")
    finally:
        b.close()


def test_read_from_clean_eof_returns_none():
    a, b = socket.socketpair()
    try:
        a.sendall(control_frame(FT_BYE, {"reason": "x"}))
        a.close()
        r = FrameReader()
        seen = []
        while True:
            got = r.read_from(b)
            if got is None:
                break
            seen.extend(got)
        assert [f[0] for f in seen] == [FT_BYE]
    finally:
        b.close()


# ---------------------------------------------------------------------------
# flow control
# ---------------------------------------------------------------------------
def test_credit_gate_blocks_until_grant():
    gate = CreditGate()
    gate.reset(100, 1000)
    assert gate.acquire(100, time.monotonic() + 1)
    done = threading.Event()

    def blocked():
        assert gate.acquire(50, time.monotonic() + 5)
        done.set()

    t = threading.Thread(target=blocked)
    t.start()
    time.sleep(0.05)
    assert not done.is_set()                     # sender is stalled
    gate.grant(50)
    t.join(timeout=5)
    assert done.is_set()


def test_credit_gate_deadline_and_close():
    gate = CreditGate()
    gate.reset(0, 100)
    assert not gate.acquire(10, time.monotonic() + 0.05)
    gate.close()
    with pytest.raises(ConnectionError):
        gate.acquire(10, time.monotonic() + 1)


def test_credit_gate_oversized_frame_overshoots_once():
    gate = CreditGate()
    gate.reset(100, 100)
    # a frame bigger than the whole window only needs the window: the
    # balance goes negative and the next acquire stalls until repaid
    assert gate.acquire(250, time.monotonic() + 1)
    assert gate.balance() == -150
    assert not gate.acquire(1, time.monotonic() + 0.05)
    gate.grant(10 ** 9)                          # capped at the limit
    assert gate.balance() == 100


def test_credit_ledger_accounting():
    led = CreditLedger(800)
    assert led.debit(700) and led.outstanding() == 700
    assert led.release(10) == 0                  # below limit//8 threshold
    assert led.release(95) == 105                # coalesced flush
    assert led.debit(800)                        # within 2x overshoot
    assert not led.debit(800)                    # protocol violation
    led2 = CreditLedger(1000)
    led2.debit(400)
    led2.release(50)                             # pending, unsent
    assert led2.snapshot_for_welcome() == 650    # pending folded, zeroed
    assert led2.release(75) == 0                 # 125 would double-count


# ---------------------------------------------------------------------------
# TCP integration (the CI tcp-mp lane re-runs these over real processes)
# ---------------------------------------------------------------------------
def _drain_frames(reader, sock, want=1, deadline=10.0):
    frames = []
    end = time.monotonic() + deadline
    while len(frames) < want and time.monotonic() < end:
        try:
            got = reader.read_from(sock)
        except socket.timeout:
            continue
        if got is None:
            break
        frames.extend(got)
    return frames


@pytest.mark.tcp
def test_tcp_register_pull_push_multiplexed():
    """Two peers interleave pulls and pushes over their multiplexed
    sockets; the driver sees every result exactly once."""
    with TcpSuperLink("127.0.0.1", 0, poll_wait=2.0) as link:
        host, port = link.address
        conns = {s: TcpFleetConnection(host, port, s)
                 for s in ("site-a", "site-b")}
        try:
            for s, c in conns.items():
                c.register(s)
            assert sorted(link.node_ids()) == ["site-a", "site-b"]
            tids = {}
            for s in conns:
                for k in range(3):
                    tids[link.push_task_ins(s, f"task-{s}-{k}".encode())] = s

            def worker(site):
                c = conns[site]
                while True:
                    tid, task = c.pull_task(site)
                    if not tid:
                        return
                    c.push_result(tid, b"done:" + bytes(task))

            ts = [threading.Thread(target=worker, args=(s,)) for s in conns]
            for t in ts:
                t.start()
            got = {}
            deadline = time.monotonic() + 20
            while len(got) < 6:
                item = link.pull_any(list(tids), deadline)
                assert item is not None, "round lost a result"
                got[item[0]] = bytes(item[1])
            for t in ts:
                t.join(timeout=10)
            assert set(got) == set(tids)
            for tid, site in tids.items():
                assert got[tid].startswith(b"done:task-" + site.encode())
        finally:
            for c in conns.values():
                c.close()


@pytest.mark.tcp
def test_tcp_credit_exhaustion_blocks_sender_not_server():
    """A pusher that outruns the server's consumption stalls client-side
    on the credit gate; an unrelated peer's traffic is unaffected, and
    consuming the buffered result un-stalls the pusher."""
    with TcpSuperLink("127.0.0.1", 0, credits_per_peer=8192,
                      poll_wait=0.1) as link:
        host, port = link.address
        fast = TcpFleetConnection(host, port, "fast", request_timeout=30.0)
        other = TcpFleetConnection(host, port, "other")
        try:
            fast.register("fast")
            other.register("other")
            payload = bytes(5000)
            fast.push_result("t-1", payload)     # fits the window
            stalled_done = threading.Event()

            def stalled_push():
                fast.push_result("t-2", payload)
                stalled_done.set()

            t = threading.Thread(target=stalled_push)
            t.start()
            time.sleep(0.3)
            # the second window's worth is stalled in the SENDER...
            assert not stalled_done.is_set()
            assert fast._gate.balance() < len(payload)
            # ...while the server keeps serving the other peer
            other.register("other")
            tid = link.push_task_ins("other", b"ping")
            assert other.pull_task("other") == (tid, b"ping")
            # consuming the buffered result releases credits -> un-stall
            got = link.pull_any(["t-1"], time.monotonic() + 5)
            assert got is not None and bytes(got[1]) == payload
            t.join(timeout=10)
            assert stalled_done.is_set()
            got = link.pull_any(["t-2"], time.monotonic() + 5)
            assert got is not None and bytes(got[1]) == payload
        finally:
            fast.close()
            other.close()


def _raw_hello(host, port, node):
    sock = socket.create_connection((host, port), timeout=5)
    sock.settimeout(0.2)
    send_parts(sock, control_frame(FT_HELLO, {"node": node, "proto": 1}))
    reader = FrameReader()
    (ftype, payload), = _drain_frames(reader, sock)
    assert ftype == FT_WELCOME
    return sock, reader, parse_control(payload)


@pytest.mark.tcp
def test_tcp_reconnect_resume_dedup():
    """A resent REQ (same msg_id, new connection) replays the cached
    response instead of re-executing: the resumed pull returns the SAME
    task even though the queue is now empty, and the duplicate's bytes
    are not double-held against the credit window."""
    with TcpSuperLink("127.0.0.1", 0, poll_wait=2.0) as link:
        host, port = link.address
        tid = link.push_task_ins("raw-1", b"the-one-task")
        sock, reader, welcome = _raw_hello(host, port, "raw-1")
        pull = b"".join(data_frame_parts(
            FT_REQ, {"i": "raw-1:0", "m": "pull_task_ins"}, b""))
        sock.sendall(pull)
        (ftype, payload), = _drain_frames(reader, sock)
        header, body = split_data(payload)
        assert ftype == FT_RES and header["id"] == tid
        assert bytes(body) == b"the-one-task"

        sock.close()                             # network blip
        sock2, reader2, welcome2 = _raw_hello(host, port, "raw-1")
        assert welcome2["credits"] == welcome["credits"]  # dup not held
        sock2.sendall(pull)                      # resume: same msg_id
        (_, payload), = _drain_frames(reader2, sock2)
        header, body = split_data(payload)
        assert header["id"] == tid               # replayed, not re-run
        assert bytes(body) == b"the-one-task"
        # a FRESH pull really does re-execute (and finds the queue empty)
        fresh = b"".join(data_frame_parts(
            FT_REQ, {"i": "raw-1:1", "m": "pull_task_ins"}, b""))
        sock2.sendall(fresh)
        (_, payload), = _drain_frames(reader2, sock2, deadline=15.0)
        header, _ = split_data(payload)
        assert header["i"] == "raw-1:1" and header["id"] == ""
        sock2.close()


@pytest.mark.tcp
def test_tcp_push_resend_does_not_double_apply():
    with TcpSuperLink("127.0.0.1", 0) as link:
        host, port = link.address
        sock, reader, _ = _raw_hello(host, port, "raw-2")
        push = b"".join(data_frame_parts(
            FT_REQ, {"i": "raw-2:0", "m": "push_task_res", "id": "tid-1"},
            b"result-bytes"))
        sock.sendall(push)
        (_, payload), = _drain_frames(reader, sock)
        assert split_data(payload)[0]["s"] == "OK"
        sock.sendall(push)                       # retry after a lost RES
        (_, payload), = _drain_frames(reader, sock)
        assert split_data(payload)[0]["s"] == "OK"   # replayed verdict
        got = link.pull_any(["tid-1"], time.monotonic() + 5)
        assert got is not None and bytes(got[1]) == b"result-bytes"
        assert link.stats["late_dropped"] == 0
        # consuming the single held copy returns the window to full
        deadline = time.monotonic() + 5
        while link._peers["raw-2"].ledger.outstanding() > 0:
            assert time.monotonic() < deadline, "credits never released"
            time.sleep(0.01)
        sock.close()


@pytest.mark.tcp
def test_tcp_heartbeat_expiry_drops_peer():
    with TcpSuperLink("127.0.0.1", 0, heartbeat_timeout=0.4) as link:
        host, port = link.address
        sock, _, _ = _raw_hello(host, port, "quiet")   # never PINGs
        assert "quiet" in link.node_ids()
        deadline = time.monotonic() + 5
        while "quiet" in link.node_ids():
            assert time.monotonic() < deadline, "reaper never fired"
            time.sleep(0.05)
        sock.close()


# --------------------------------------------------------- process fleet
class DeterministicClient(NumPyClient):
    """Pure-deterministic update: fit adds a site-derived constant, so
    tcp-vs-inproc aggregation can be compared bitwise."""

    def __init__(self, cid: str):
        self.cid = cid
        self.idx = int(cid.rsplit("-", 1)[-1])

    def fit(self, parameters, config):
        out = [np.asarray(p, dtype=np.float32) + np.float32(self.idx + 1)
               for p in parameters]
        return out, 10 + self.idx, {}

    def evaluate(self, parameters, config):
        loss = float(sum(np.abs(np.asarray(p)).sum() for p in parameters))
        return loss, 10 + self.idx, {}


class BlockingClient(NumPyClient):
    """Never answers: stands in for a client that will be SIGKILLed."""

    def fit(self, parameters, config):
        time.sleep(600)
        return parameters, 1, {}

    def evaluate(self, parameters, config):
        time.sleep(600)
        return 0.0, 1, {}


def _det_app(node_id: str) -> ClientApp:
    return ClientApp(lambda cid, n=node_id: DeterministicClient(n)
                     .to_client())


def _blocking_app(node_id: str) -> ClientApp:
    return ClientApp(lambda cid: BlockingClient().to_client())


def _det_server_app(rounds: int, timeout: float) -> ServerApp:
    initial = [np.linspace(-1.0, 1.0, 32, dtype=np.float32).reshape(8, 4),
               np.zeros(8, dtype=np.float32)]
    strat = make_strategy("fedavg", initial_parameters=initial)
    return ServerApp(ServerConfig(num_rounds=rounds, round_timeout=timeout),
                     strat)


N_PROCS = 16


@pytest.mark.tcp
@pytest.mark.slow
def test_tcp_16proc_round_bitwise_vs_inproc(tmp_path):
    """The acceptance bar: a 16-process quickstart-shaped round over real
    sockets lands bitwise-identical aggregates to the in-proc fold."""
    from repro.core.superlink import NativeConnection, SuperLink
    sites = [f"proc-{i}" for i in range(N_PROCS)]

    ref_link = SuperLink()
    ref_nodes = [SuperNode(s, _det_app(s), NativeConnection(ref_link))
                 for s in sites]
    for n in ref_nodes:
        n.start()
    try:
        drv = SuperLinkDriver(ref_link, expected_nodes=N_PROCS)
        h_ref = _det_server_app(2, 60.0).run(drv)
    finally:
        for n in ref_nodes:
            n.stop()

    ctx = mp.get_context("spawn")                # JAX threads do not fork
    with TcpSuperLink("127.0.0.1", 0, poll_wait=1.0,
                      heartbeat_timeout=60.0) as link:
        host, port = link.address
        procs = [ctx.Process(target=run_supernode,
                             args=(host, port, s, _det_app),
                             kwargs=dict(run_seconds=600.0,
                                         max_disconnected=10.0),
                             daemon=True)
                 for s in sites]
        for p in procs:
            p.start()
        try:
            deadline = time.monotonic() + 300
            while len(link.node_ids()) < N_PROCS:
                assert time.monotonic() < deadline, \
                    f"only {len(link.node_ids())}/{N_PROCS} joined"
                time.sleep(0.2)
            drv = SuperLinkDriver(link, expected_nodes=N_PROCS)
            h_tcp = _det_server_app(2, 120.0).run(drv)
        finally:
            link.close()                         # BYE -> children drain
            for p in procs:
                p.join(timeout=30)
                if p.is_alive():
                    p.kill()

    assert h_tcp.losses() == h_ref.losses()      # bitwise, not approx
    assert all(not r.failures for r in h_tcp.rounds)


@pytest.mark.tcp
def test_tcp_kill9_client_mid_round_records_timeout():
    """SIGKILL a SuperNode process mid-fit: the heartbeat reaper drops it
    from the roster and the round completes with the established
    ``(node, "timeout")`` failure record — the server never hangs."""
    ctx = mp.get_context("spawn")
    with TcpSuperLink("127.0.0.1", 0, poll_wait=0.2,
                      heartbeat_timeout=1.0) as link:
        host, port = link.address
        victim = ctx.Process(target=run_supernode,
                             args=(host, port, "victim", _blocking_app),
                             kwargs=dict(run_seconds=600.0,
                                         heartbeat_interval=0.2,
                                         max_disconnected=5.0),
                             daemon=True)
        victim.start()
        good = SuperNode("good", _det_app("good-0"),
                         TcpFleetConnection(host, port, "good"))
        good.start()
        try:
            deadline = time.monotonic() + 120
            while len(link.node_ids()) < 2:
                assert time.monotonic() < deadline, "fleet never formed"
                time.sleep(0.1)

            killer = threading.Timer(1.0, victim.kill)
            killer.start()
            try:
                h = _det_server_app(1, 8.0).run(
                    SuperLinkDriver(link, expected_nodes=2))
            finally:
                killer.cancel()
            assert len(h.rounds) == 1
            assert ("victim", "timeout") in h.rounds[0].failures
            assert all(n == "victim" for n, _ in h.rounds[0].failures)
            assert np.isfinite(h.losses()[-1][1])
            assert "victim" not in link.node_ids()   # reaped from roster
        finally:
            good.stop()
            victim.join(timeout=10)
            if victim.is_alive():
                victim.kill()


# ------------------------------------------------------------------- TLS
@pytest.fixture(scope="module")
def tls_contexts(tmp_path_factory):
    d = tmp_path_factory.mktemp("tls")
    cert, key = d / "cert.pem", d / "key.pem"
    r = subprocess.run(
        ["openssl", "req", "-x509", "-newkey", "rsa:2048", "-nodes",
         "-keyout", str(key), "-out", str(cert), "-days", "1",
         "-subj", "/CN=127.0.0.1",
         "-addext", "subjectAltName=IP:127.0.0.1"],
        capture_output=True)
    if r.returncode != 0:
        pytest.skip(f"openssl unavailable: {r.stderr.decode()[:200]}")
    server = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
    server.load_cert_chain(str(cert), str(key))
    client = ssl.SSLContext(ssl.PROTOCOL_TLS_CLIENT)
    client.load_verify_locations(str(cert))
    return server, client


@pytest.mark.tcp
def test_tcp_tls_loopback_roundtrip(tls_contexts):
    server_ctx, client_ctx = tls_contexts
    with TcpSuperLink("127.0.0.1", 0, ssl_context=server_ctx,
                      poll_wait=2.0) as link:
        host, port = link.address
        conn = TcpFleetConnection(host, port, "tls-1",
                                  ssl_context=client_ctx,
                                  server_hostname="127.0.0.1")
        try:
            conn.register("tls-1")
            tid = link.push_task_ins("tls-1", b"secure-task")
            assert conn.pull_task("tls-1") == (tid, b"secure-task")
            conn.push_result(tid, b"secure-res")
            got = link.pull_any([tid], time.monotonic() + 5)
            assert got is not None and bytes(got[1]) == b"secure-res"
        finally:
            conn.close()


# ------------------------------------------------- full-app equivalence
@pytest.mark.tcp
@pytest.mark.slow
def test_tcp_quickstart_scenario_bitwise_vs_inproc(monkeypatch):
    """The ServerApp/strategy stack is transport-agnostic: the quickstart
    scenario over sockets reproduces the in-proc run bit-for-bit."""
    import test_scenarios as ts
    monkeypatch.delenv("REPRO_TRANSPORT", raising=False)
    h_ref, _ = ts.run_scenario("flat", "fedavg", "none")
    monkeypatch.setenv("REPRO_TRANSPORT", "tcp")
    h_tcp, _ = ts.run_scenario("flat", "fedavg", "none")
    assert h_tcp.losses() == h_ref.losses()


@pytest.mark.tcp
def test_tcp_client_timeout_surfaces_as_request_timeout():
    conn = TcpFleetConnection("127.0.0.1", 1, "nobody",  # closed port
                              request_timeout=0.3, connect_timeout=0.2)
    try:
        with pytest.raises((RequestTimeout, ConnectionError)):
            conn.register("nobody")
    finally:
        conn.close()

"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps + properties.

All kernels run in interpret mode (CPU container); BlockSpecs/grids are the
TPU configuration under test.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # B, S, H, KV, hd, window, causal, dtype
    (2, 64, 4, 2, 16, 0, True, jnp.float32),
    (1, 128, 8, 8, 32, 0, True, jnp.float32),
    (2, 96, 4, 1, 16, 24, True, jnp.float32),    # MQA + sliding window
    (1, 64, 4, 4, 16, 0, False, jnp.float32),    # bidirectional (encoder)
    (1, 64, 8, 2, 64, 0, True, jnp.bfloat16),
    (2, 80, 2, 2, 8, 16, True, jnp.float32),     # non-pow2 seq
]


@pytest.mark.parametrize("B,S,H,KV,hd,window,causal,dtype", FLASH_CASES)
def test_flash_attention_matches_ref(B, S, H, KV, hd, window, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype) / np.sqrt(hd)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=32, block_kv=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("S,causal", [(509, True), (509, False), (127, True),
                                      (33, True)])
def test_flash_attention_prime_seq_len(S, causal):
    """Regression: prime/odd S must not degrade the block size to 1 (the
    old `while S % block_q: block_q -= 1` loop); the kernel now pads the
    sequence to a multiple of an aligned block and masks the tail."""
    from repro.kernels.flash_attention import _choose_block

    assert _choose_block(509, 64) == 64        # pads, never collapses to 1
    assert _choose_block(80, 32) == 16         # largest aligned divisor
    assert _choose_block(128, 512) == 128      # short seqs use one block
    B, H, KV, hd = 1, 2, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32) / 4
    k = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bkv", [(16, 16), (32, 64), (64, 32), (128, 128)])
def test_flash_attention_block_shape_invariance(bq, bkv):
    """Output must not depend on the tiling choice."""
    B, S, H, KV, hd = 1, 128, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), jnp.float32)
    a = ops.flash_attention(q, k, v, block_q=bq, block_kv=bkv)
    b = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 1),
       st.sampled_from([16, 32, 48]), st.integers(0, 20))
def test_flash_attention_property(b, kv_groups, mqa, seq_mult, window):
    """Property: any (B, group-structure, S, window) agrees with the oracle."""
    KV = 1 if mqa else 2
    H = KV * kv_groups
    S = 16 * seq_mult
    hd = 8
    rng = np.random.default_rng(b * 1000 + H * 10 + S + window)
    q = jnp.asarray(rng.normal(size=(b, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, S, KV, hd)), jnp.float32)
    got = ops.flash_attention(q, k, v, window=window, block_q=16, block_kv=16)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# secagg quantize+mask
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("N,P,block", [(1000, 3, 128), (4096, 1, 4096),
                                       (513, 5, 64), (64, 0, 64)])
def test_secagg_mask_matches_ref(N, P, block):
    x = jnp.asarray(RNG.normal(size=(N,)), jnp.float32)
    masks = jnp.asarray(
        RNG.integers(-2 ** 31, 2 ** 31 - 1, size=(max(P, 1), N)), jnp.int32)
    if P == 0:
        masks = masks[:0]
    got = ops.secagg_mask(x, masks, 3.0, block=block)
    want = ref.secagg_mask_ref(x, masks, 3.0)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_secagg_masks_cancel():
    """Pairwise +m / -m masks cancel exactly in the int32 field."""
    N = 256
    x1 = jnp.asarray(RNG.normal(size=(N,)), jnp.float32)
    x2 = jnp.asarray(RNG.normal(size=(N,)), jnp.float32)
    m = jnp.asarray(RNG.integers(-2 ** 31, 2 ** 31 - 1, size=(1, N)), jnp.int32)
    a = ops.secagg_mask(x1, m, 1.0, block=64)
    b = ops.secagg_mask(x2, -m, 1.0, block=64)
    plain = (ref.secagg_mask_ref(x1, m[:0], 1.0)
             + ref.secagg_mask_ref(x2, m[:0], 1.0))
    assert np.array_equal(np.asarray(a + b), np.asarray(plain))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(0, 4),
       st.floats(0.25, 1000.0, allow_nan=False))
def test_secagg_property(nmult, P, weight):
    N = 16 * nmult
    rng = np.random.default_rng(N + P)
    x = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    masks = jnp.asarray(rng.integers(-2 ** 31, 2 ** 31 - 1, size=(max(P, 1), N)),
                        jnp.int32)[: P]
    got = ops.secagg_mask(x, masks, weight, block=16)
    want = ref.secagg_mask_ref(x, masks, weight)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,W,bs,bw", [(2, 64, 96, 16, 32),
                                         (1, 128, 64, 128, 64),
                                         (3, 48, 32, 8, 32),
                                         (2, 96, 128, 24, 64)])
def test_rglru_scan_matches_ref(B, S, W, bs, bw):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, size=(B, S, W)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(B, S, W)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(B, W)), jnp.float32)
    ys, hf = ops.rglru_scan(a, b, h0, block_s=bs, block_w=bw)
    ys_r, hf_r = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ys_r), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_r), rtol=1e-5,
                               atol=1e-5)


def test_rglru_carry_across_seq_blocks():
    """Final state from chunked kernel == running the chain in one block."""
    B, S, W = 1, 64, 32
    a = jnp.asarray(RNG.uniform(0.9, 0.999, size=(B, S, W)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(B, S, W)), jnp.float32)
    h0 = jnp.zeros((B, W), jnp.float32)
    _, hf_chunked = ops.rglru_scan(a, b, h0, block_s=8, block_w=32)
    _, hf_single = ops.rglru_scan(a, b, h0, block_s=64, block_w=32)
    np.testing.assert_allclose(np.asarray(hf_chunked), np.asarray(hf_single),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.integers(1, 8), st.integers(1, 4))
def test_rglru_property(B, smult, wmult):
    S, W = 8 * smult, 8 * wmult
    rng = np.random.default_rng(S * 100 + W)
    a = jnp.asarray(rng.uniform(0.5, 1.0, size=(B, S, W)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, W)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, W)), jnp.float32)
    ys, hf = ops.rglru_scan(a, b, h0, block_s=8, block_w=8)
    ys_r, hf_r = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ys_r), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# kernel <-> model-path agreement
# ---------------------------------------------------------------------------
def test_pallas_path_matches_xla_path_in_model():
    from repro.models import attention_impl

    B, S, H, KV, hd = 1, 64, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32) / 4
    k = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), jnp.float32)
    a = attention_impl.causal_attention(q, k, v, impl="xla")
    b = attention_impl.causal_attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)

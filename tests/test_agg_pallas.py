"""Differential harness: Pallas aggregation kernels vs the numpy reference.

Every (kernel, codec) pair must agree to <=1 ULP of the output leaf dtype
(bitwise in practice) across layouts (odd sizes, mixed shapes/dtypes),
codecs (0xF1 raw / 0xF2 bf16 / 0xF3 int8, including int8 *deltas* against
both raw and quantized bases) and client counts — the same cross-check
pattern ``tests/test_kernels.py`` applies to ``secagg_mask``.  The Pallas
kernels run in interpret mode (CPU container); the BlockSpecs/grids are
the TPU configuration under test.

Krum is the one exception by design: its Gram matmul reduction order is
hardware-defined, so the *distances* carry a tight relative tolerance
while the selection and the final aggregate stay exact.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.fl import agg_kernels as K
from repro.fl.flat import (FlatParams, QuantParams, layout_for, np_dtype,
                           quantize_int8)

pytestmark = pytest.mark.pallas

RNG = np.random.default_rng(0xA66)

LAYOUTS = {
    # odd / prime sizes, mixed shapes — nothing aligns with any block
    "odd_f32": [("float32", (17,)), ("float32", (3, 5)), ("float32", (1,)),
                ("float32", (127,))],
    "scalar_leaf": [("float32", ()), ("float32", (2,))],
    "big_unaligned": [("float32", (1000,)), ("float32", (537,))],
    "uniform_f64": [("float64", (33,)), ("float64", (2, 9))],
    "uniform_f16": [("float16", (21, 4))],
    "uniform_bf16": [("bfloat16", (31,))],
    "mixed_dtypes": [("float64", (5,)), ("float32", (3, 3)),
                     ("float16", (9,))],
}
#: lossy wire codecs only exist for uniform-fp32 layouts
F32_LAYOUTS = [k for k, sig in LAYOUTS.items()
               if all(d == "float32" for d, _ in sig)]
CODECS = ("flat", "bf16", "q8", "q8_delta_flat", "q8_delta_quant",
          "bf16_delta")


# ---------------------------------------------------------------------------
# harness plumbing
# ---------------------------------------------------------------------------
def ulp_diff(a: np.ndarray, b: np.ndarray) -> int:
    """Max ULP distance between two same-dtype float arrays (0 for
    bitwise-equal; +-0 and exact-equal values count as 0)."""
    a, b = np.ravel(a), np.ravel(b)
    assert a.dtype == b.dtype, (a.dtype, b.dtype)
    if a.size == 0:
        return 0
    si = np.dtype(f"i{a.dtype.itemsize}")
    ai = a.view(si).astype(np.int64)
    bi = b.view(si).astype(np.int64)
    mask = (1 << (8 * a.dtype.itemsize - 1)) - 1
    ka = np.where(ai >= 0, ai, -(ai & mask))   # monotonic int mapping
    kb = np.where(bi >= 0, bi, -(bi & mask))
    return int(np.abs(ka - kb).max())


def assert_flat_ulp(got: FlatParams, want: FlatParams, maxulp: int = 1):
    assert got.layout is want.layout
    for g, w in zip(got.to_arrays(), want.to_arrays()):
        d = ulp_diff(g, w)
        assert d <= maxulp, f"{d} ULP > {maxulp} (dtype {g.dtype})"


def _vec_of(layout, rng, scale=1.0):
    return (rng.normal(0, scale, layout.total_size)).astype(np.float32)


def make_payloads(layout_key: str, codec: str, n_clients: int, seed: int,
                  spread: float = 1.0):
    """Client payloads exactly as the wire would hand them to the server:
    FlatParams for raw frames, still-compressed QuantParams for lossy
    ones; delta codecs share one base object like a real round does."""
    layout = layout_for(LAYOUTS[layout_key])
    rng = np.random.default_rng(seed)
    if codec == "flat":
        out = []
        for i in range(n_clients):
            arrays = [np.asarray(
                rng.normal(0, spread * (1 + i), spec.shape),
                np_dtype(spec.dtype)).reshape(spec.shape)
                for spec in layout.leaves]
            out.append(FlatParams.from_arrays(arrays, layout))
        return layout, out
    assert layout.uniform_dtype == "float32", \
        "lossy codecs only apply to uniform-fp32 layouts"
    base_fp = FlatParams.from_arrays(
        [np.asarray(rng.normal(0, 0.5, s.shape), np.float32)
         for s in layout.leaves], layout)
    if codec == "q8_delta_quant":
        qb, sb = quantize_int8(base_fp.math_view())
        base = QuantParams(layout, "q8", qb, sb)
    else:
        base = base_fp
    out = []
    for i in range(n_clients):
        vec = _vec_of(layout, rng, spread * (1 + 0.25 * i))
        if codec == "bf16":
            out.append(QuantParams(layout, "bf16",
                                   vec.astype(np_dtype("bfloat16"))))
        elif codec == "bf16_delta":
            out.append(QuantParams(layout, "bf16",
                                   vec.astype(np_dtype("bfloat16")),
                                   is_delta=True, base=base))
        elif codec == "q8":
            q, s = quantize_int8(vec)
            out.append(QuantParams(layout, "q8", q, s))
        else:                                    # int8 deltas
            q, s = quantize_int8(vec * 1e-3)
            out.append(QuantParams(layout, "q8", q, s,
                                   is_delta=True, base=base))
    return layout, out


def both_backends(fn, *args, **kw):
    return (fn(*args, backend="pallas", **kw),
            fn(*args, backend="numpy", **kw))


# ---------------------------------------------------------------------------
# weighted mean (FedAvg) — full codec x layout matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout_key", sorted(LAYOUTS))
def test_weighted_mean_matches_numpy_raw(layout_key):
    layout, flats = make_payloads(layout_key, "flat", 5, seed=1)
    pairs = [(fp, 10.0 + 3 * i) for i, fp in enumerate(flats)]
    got, want = both_backends(K.weighted_mean, pairs, layout)
    assert_flat_ulp(got, want)


@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("n_clients", [1, 2, 5])
def test_weighted_mean_matches_numpy_codecs(codec, n_clients):
    layout, flats = make_payloads("big_unaligned", codec, n_clients, seed=2)
    pairs = [(fp, 7.0 + i) for i, fp in enumerate(flats)]
    got, want = both_backends(K.weighted_mean, pairs, layout)
    assert_flat_ulp(got, want)


@pytest.mark.parametrize("block", [1024, 4096, 1 << 20])
def test_weighted_mean_block_size_invariance(block):
    """The tiling choice must not change a single bit of the output."""
    layout, flats = make_payloads("big_unaligned", "q8_delta_flat", 4, seed=3)
    pairs = [(fp, 5.0 + i) for i, fp in enumerate(flats)]
    got = K.weighted_mean(pairs, layout, backend="pallas", block=block)
    want = K.weighted_mean(pairs, layout, backend="numpy")
    assert_flat_ulp(got, want)


def test_weighted_mean_cancellation_heavy():
    """Near-zero sums are where FMA contraction / reassociation would
    show up (the regression this harness exists to catch — see the
    agg_reduce module docstring)."""
    layout = layout_for([("float64", (4096,))])
    rng = np.random.default_rng(7)
    base = rng.normal(0, 1, layout.total_size)
    flats, weights = [], []
    for i in range(6):
        sign = 1.0 if i % 2 else -1.0
        flats.append(FlatParams.from_arrays(
            [np.asarray(sign * base + rng.normal(0, 1e-9, base.shape))],
            layout))
        weights.append(1.0 + 1e-6 * i)
    pairs = list(zip(flats, weights))
    got, want = both_backends(K.weighted_mean, pairs, layout)
    assert_flat_ulp(got, want)


# ---------------------------------------------------------------------------
# streaming arrival-order fold
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["flat", "bf16", "q8", "q8_delta_quant"])
def test_streaming_fold_matches_numpy(codec):
    layout_key = "odd_f32" if codec == "flat" else "big_unaligned"
    layout, flats = make_payloads(layout_key, codec, 5, seed=4)
    s_np = K.StreamingWeightedSum(layout, backend="numpy")
    s_pl = K.StreamingWeightedSum(layout, backend="pallas")
    for i, fp in enumerate(flats):
        s_np.add(fp, 3.0 + i)
        s_pl.add(fp, 3.0 + i)
    assert s_pl.count == s_np.count == len(flats)
    assert_flat_ulp(s_pl.finalize(), s_np.finalize())


def test_streaming_fold_mixed_backends_is_exact():
    """A round may fold some payloads through Pallas and odd ones through
    the numpy fallback; the per-arrival arithmetic is identical, so the
    mix must equal the pure-numpy fold bitwise."""
    layout, flats = make_payloads("odd_f32", "flat", 4, seed=5)
    s_np = K.StreamingWeightedSum(layout, backend="numpy")
    s_mix = K.StreamingWeightedSum(layout, backend="pallas")
    for i, fp in enumerate(flats):
        s_np.add(fp, 2.0 + i)
        if i % 2:
            s_mix.backend = "numpy"        # simulate a fallback arrival
        else:
            s_mix.backend = "pallas"
        s_mix.add(fp, 2.0 + i)
    assert_flat_ulp(s_mix.finalize(), s_np.finalize())


# ---------------------------------------------------------------------------
# robust reductions: median / trimmed mean
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["flat", "bf16", "q8", "q8_delta_flat",
                                   "q8_delta_quant"])
@pytest.mark.parametrize("n_clients", [2, 3, 6])
def test_median_matches_numpy(codec, n_clients):
    key = "odd_f32" if codec == "flat" else "big_unaligned"
    layout, flats = make_payloads(key, codec, n_clients, seed=6)
    got, want = both_backends(K.median, flats, layout)
    assert_flat_ulp(got, want)


@pytest.mark.parametrize("layout_key", ["uniform_f64", "mixed_dtypes",
                                        "uniform_f16"])
def test_median_matches_numpy_dtypes(layout_key):
    layout, flats = make_payloads(layout_key, "flat", 5, seed=7)
    got, want = both_backends(K.median, flats, layout)
    assert_flat_ulp(got, want)


@pytest.mark.parametrize("codec", ["flat", "q8", "q8_delta_flat"])
@pytest.mark.parametrize("n_clients,k", [(5, 1), (6, 2), (3, 1), (4, 2)])
def test_trimmed_mean_matches_numpy(codec, n_clients, k):
    # (4, 2) exercises n <= 2k: numpy falls back to the untrimmed mean
    key = "odd_f32" if codec == "flat" else "big_unaligned"
    layout, flats = make_payloads(key, codec, n_clients, seed=8)
    got, want = both_backends(K.trimmed_mean, flats, layout, k)
    assert_flat_ulp(got, want)


# ---------------------------------------------------------------------------
# Krum: distances ~tight-tolerance, selection + aggregate exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["flat", "bf16", "q8", "q8_delta_quant"])
def test_krum_distances_and_selection(codec):
    key = "odd_f32" if codec == "flat" else "big_unaligned"
    # spread > 0 gives each client a distinct magnitude => well-separated
    # scores, so selection equality is meaningful, not a tie-break fluke
    layout, flats = make_payloads(key, codec, 6, seed=9, spread=1.0)
    Dp = K.krum_distances(flats, layout, backend="pallas")
    Dn = K.krum_distances(flats, layout, backend="numpy")
    np.testing.assert_allclose(Dp, Dn, rtol=1e-9, atol=1e-9)
    for f in (0, 1):
        sp = K.krum_scores(Dp, f)
        sn = K.krum_scores(Dn, f)
        assert np.argsort(sp).tolist() == np.argsort(sn).tolist()
        chosen = np.argsort(sp)[:2]
        sel = [(flats[i], 4.0 + i) for i in chosen]
        got, want = both_backends(K.weighted_mean, sel, layout)
        assert_flat_ulp(got, want)


def test_krum_large_common_offset():
    """Late-round regime: client updates nearly identical with a huge
    common component — the centered Gram must not cancel catastrophically
    on either backend."""
    layout = layout_for([("float32", (2048,))])
    rng = np.random.default_rng(10)
    common = rng.normal(0, 1, layout.total_size).astype(np.float32) * 1e4
    flats = [FlatParams.from_arrays(
        [common + rng.normal(0, 1e-2, common.shape).astype(np.float32)],
        layout) for _ in range(5)]
    Dp = K.krum_distances(flats, layout, backend="pallas")
    Dn = K.krum_distances(flats, layout, backend="numpy")
    np.testing.assert_allclose(Dp, Dn, rtol=1e-6, atol=1e-4)
    assert (Dp >= 0).all()


# ---------------------------------------------------------------------------
# hypothesis property: the full matrix, randomly sampled
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(st.integers(1, 7), st.sampled_from(CODECS),
       st.integers(1, 2500), st.integers(0, 1000))
def test_property_weighted_mean_any_size(n_clients, codec, size, seed):
    """Any (client count, codec, odd buffer size): Pallas == numpy <=1 ULP
    (deltas included).  Sizes straddle the int8 scale-window (1024) and
    never align with the kernel blocks."""
    sig = (("float32", (size,)),)
    layout = layout_for(sig)
    rng = np.random.default_rng(seed)
    if codec == "flat":
        flats = [FlatParams.from_arrays(
            [rng.normal(0, 1 + i, (size,)).astype(np.float32)], layout)
            for i in range(n_clients)]
    else:
        base_fp = FlatParams.from_arrays(
            [rng.normal(0, 0.5, (size,)).astype(np.float32)], layout)
        if codec == "q8_delta_quant":
            qb, sb = quantize_int8(base_fp.math_view())
            base = QuantParams(layout, "q8", qb, sb)
        else:
            base = base_fp
        flats = []
        for i in range(n_clients):
            vec = rng.normal(0, 1 + 0.1 * i, (size,)).astype(np.float32)
            if codec.startswith("bf16"):
                flats.append(QuantParams(
                    layout, "bf16", vec.astype(np_dtype("bfloat16")),
                    is_delta=codec.endswith("delta"),
                    base=base if codec.endswith("delta") else None))
            else:
                q, s = quantize_int8(vec)
                is_d = codec.startswith("q8_delta")
                flats.append(QuantParams(layout, "q8", q, s, is_delta=is_d,
                                         base=base if is_d else None))
    pairs = [(fp, 1.0 + i) for i, fp in enumerate(flats)]
    got, want = both_backends(K.weighted_mean, pairs, layout,
                              block=1024 if size > 1024 else None)
    assert_flat_ulp(got, want)


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 7), st.integers(1, 1500), st.integers(0, 500))
def test_property_robust_reductions_any_size(n_clients, size, seed):
    layout = layout_for((("float32", (size,)),))
    rng = np.random.default_rng(seed + 31)
    flats = [FlatParams.from_arrays(
        [rng.normal(0, 1 + i, (size,)).astype(np.float32)], layout)
        for i in range(n_clients)]
    got, want = both_backends(K.median, flats, layout)
    assert_flat_ulp(got, want)
    k = max(0, (n_clients - 1) // 3)
    got, want = both_backends(K.trimmed_mean, flats, layout, k)
    assert_flat_ulp(got, want)


# ---------------------------------------------------------------------------
# dispatch contract
# ---------------------------------------------------------------------------
def test_dispatch_falls_back_on_heterogeneous_codecs():
    """One raw straggler among q8 clients must not abort — the round
    falls back to the numpy kernels and still aggregates exactly."""
    layout, quants = make_payloads("big_unaligned", "q8", 3, seed=11)
    _, raws = make_payloads("big_unaligned", "flat", 1, seed=12)
    pairs = [(fp, 2.0 + i) for i, fp in enumerate(quants + raws)]
    got = K.weighted_mean(pairs, layout, backend="pallas")
    want = K.weighted_mean(pairs, layout, backend="numpy")
    assert_flat_ulp(got, want, maxulp=0)       # same path => bitwise


def test_dispatch_falls_back_on_integer_domain():
    """SecAgg's uint64 shares have no float tile — numpy fallback, and
    wrapping_sum_u64 stays numpy-only."""
    layout = layout_for([("uint64", (9,))])
    flats = [FlatParams.from_arrays(
        [np.arange(9, dtype=np.uint64) * (i + 1)], layout)
        for i in range(3)]
    assert flats[0].tile_source() is None
    got = K.weighted_mean([(f, 1.0) for f in flats], layout,
                          backend="pallas")
    want = K.weighted_mean([(f, 1.0) for f in flats], layout,
                           backend="numpy")
    for g, w in zip(got.to_arrays(), want.to_arrays()):
        np.testing.assert_array_equal(g, w)


def test_dispatch_falls_back_on_distinct_delta_bases():
    layout, a = make_payloads("big_unaligned", "q8_delta_flat", 2, seed=13)
    _, b = make_payloads("big_unaligned", "q8_delta_flat", 2, seed=14)
    pairs = [(fp, 1.0 + i) for i, fp in enumerate(a + b)]  # two base objects
    got = K.weighted_mean(pairs, layout, backend="pallas")
    want = K.weighted_mean(pairs, layout, backend="numpy")
    assert_flat_ulp(got, want, maxulp=0)


def test_backend_resolution_and_env_override(monkeypatch):
    assert K.resolve_backend("numpy") == "numpy"
    assert K.resolve_backend("pallas") == "pallas"
    with pytest.raises(ValueError):
        K.resolve_backend("cuda")
    try:
        # CPU container, no env override: auto resolves to numpy
        monkeypatch.delenv("REPRO_AGG_BACKEND", raising=False)
        K.set_default_backend(None)
        assert K.resolve_backend(None) == "numpy"
        assert K.resolve_backend("auto") == "numpy"
        # the env knob flips the process default (the CI pallas lane)
        monkeypatch.setenv("REPRO_AGG_BACKEND", "pallas")
        K.set_default_backend(None)
        assert K.resolve_backend(None) == "pallas"
    finally:
        monkeypatch.delenv("REPRO_AGG_BACKEND", raising=False)
        K.set_default_backend(None)


def test_server_config_threads_backend_to_strategy():
    from repro.fl.server import ServerApp, ServerConfig
    from repro.fl.strategy import FedAvg

    strat = FedAvg()
    assert strat.backend is None
    ServerApp(ServerConfig(num_rounds=1, agg_backend="pallas"), strat)
    assert strat.backend == "pallas"
    # explicit strategy choice survives when the config does not override
    strat2 = FedAvg(backend="numpy")
    ServerApp(ServerConfig(num_rounds=1), strat2)
    assert strat2.backend == "numpy"


def test_strategies_run_on_pallas_backend_end_to_end():
    """aggregate_fit through the strategy layer on both backends, all
    robust aggregators — the path the ServerApp drives."""
    from repro.fl.messages import FitRes
    from repro.fl.strategy import make_strategy

    rng = np.random.default_rng(15)
    shapes = [(16, 8), (33,), (1,)]
    results = []
    for c in range(6):
        arrays = [rng.normal(0, 1 + c, s).astype(np.float32) for s in shapes]
        results.append((f"site-{c}", FitRes(arrays, 10 + c, {})))
    current = [np.zeros(s, np.float32) for s in shapes]
    for name in ("fedavg", "fedmedian", "fedtrimmedmean", "krum"):
        got, _ = make_strategy(name, backend="pallas") \
            .aggregate_fit(1, results, [], current)
        want, _ = make_strategy(name, backend="numpy") \
            .aggregate_fit(1, results, [], current)
        for g, w in zip(got, want):
            assert ulp_diff(g, w) <= 1, name


def test_empty_layout_is_safe_on_pallas():
    layout = layout_for([])
    fp = FlatParams.zeros(layout)
    out = K.weighted_mean([(fp, 1.0)], layout, backend="pallas")
    assert out.layout.total_size == 0


# ---------------------------------------------------------------------------
# mesh-sharded streaming fold: shard-count / overlap / placement invariance
# (the shard-cpu CI lane re-runs these under
#  XLA_FLAGS=--xla_force_host_platform_device_count=8)
# ---------------------------------------------------------------------------
SHARD_CODECS = ("flat", "bf16", "q8", "q8_delta_quant")


def _fold(layout, flats, *, backend, shards=None, overlap=None, **kw):
    s = K.StreamingWeightedSum(layout, backend=backend, shards=shards,
                               overlap=overlap, **kw)
    for i, fp in enumerate(flats):
        s.add(fp, 3.0 + i)
    return s.finalize()


@pytest.mark.shard
@pytest.mark.parametrize("codec", SHARD_CODECS)
@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_sharded_fold_bitwise_across_shard_counts(codec, backend):
    """finalize() must not depend on how the accumulator is split: 1, 2
    and 8 shards x both backends agree bitwise (the fold is pure
    elementwise ops in arrival order on every path)."""
    layout, flats = make_payloads("big_unaligned", codec, 5, seed=21)
    want = _fold(layout, flats, backend="numpy", shards=1, overlap=False)
    for shards in (2, 8):
        got = _fold(layout, flats, backend=backend, shards=shards,
                    overlap=False)
        assert_flat_ulp(got, want, maxulp=0)


@pytest.mark.shard
@pytest.mark.parametrize("codec", SHARD_CODECS)
def test_sharded_overlap_is_bitwise(codec):
    """The decode thread must change wall-clock only: FIFO job order
    keeps the (arrival, shard) fold order of the serial path."""
    layout, flats = make_payloads("big_unaligned", codec, 5, seed=22)
    got = _fold(layout, flats, backend="numpy", shards=8, overlap=True)
    want = _fold(layout, flats, backend="numpy", shards=8, overlap=False)
    assert_flat_ulp(got, want, maxulp=0)


@pytest.mark.shard
@pytest.mark.parametrize("codec", ["flat", "bf16", "q8"])
def test_sharded_matches_single_host_non_delta(codec):
    """Non-delta payloads: the deferred-base algebra is vacuous, so the
    sharded fold equals the frozen single-host accumulator bitwise."""
    layout, flats = make_payloads("big_unaligned", codec, 5, seed=23)
    legacy = _fold(layout, flats, backend="numpy")
    got = _fold(layout, flats, backend="numpy", shards=8, overlap=False)
    assert_flat_ulp(got, legacy, maxulp=0)


@pytest.mark.shard
@pytest.mark.parametrize("codec", ["q8_delta_flat", "q8_delta_quant",
                                   "bf16_delta"])
def test_sharded_delta_close_to_single_host(codec):
    """Deferred bases regroup the summation (sum w_k(d_k+b) folded as
    sum w_k d_k + W b): <=1 ULP of the fp32 output leaves vs the
    per-arrival reconstruction."""
    layout, flats = make_payloads("big_unaligned", codec, 5, seed=24)
    legacy = _fold(layout, flats, backend="numpy")
    got = _fold(layout, flats, backend="numpy", shards=4, overlap=False)
    assert_flat_ulp(got, legacy, maxulp=1)


@pytest.mark.shard
@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_sharded_fault_partial_round_bitwise(backend):
    """Straggler faults (PR 2 semantics): a round that aggregates only
    the arrived subset is still shard-count invariant."""
    layout, flats = make_payloads("big_unaligned", "q8_delta_quant", 6,
                                  seed=25)
    arrived = flats[:2] + flats[4:]          # clients 2, 3 timed out
    want = _fold(layout, arrived, backend="numpy", shards=1, overlap=False)
    got = _fold(layout, arrived, backend=backend, shards=8, overlap=False)
    assert_flat_ulp(got, want, maxulp=0)


@pytest.mark.shard
@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_sharded_mixed_codec_arrivals(backend):
    """A raw straggler interleaved with q8 clients: per-arrival fallback
    (and per-shard geometry retire on the Pallas path) must not change
    the elementwise fold."""
    layout, quants = make_payloads("big_unaligned", "q8", 3, seed=26)
    _, raws = make_payloads("big_unaligned", "flat", 2, seed=27)
    mixed = [quants[0], raws[0], quants[1], raws[1], quants[2]]
    want = _fold(layout, mixed, backend="numpy", shards=1, overlap=False)
    got = _fold(layout, mixed, backend=backend, shards=8, overlap=False)
    assert_flat_ulp(got, want, maxulp=0)


@pytest.mark.shard
def test_sharded_f32_tile_with_f64_carry_tolerance():
    """The TPU tile scheme (fp32 decode/scale + fp64 accumulate) vs the
    fp64 oracle: per-arrival fp32 rounding only, no compounding drift."""
    layout, flats = make_payloads("big_unaligned", "q8", 5, seed=28)
    oracle = _fold(layout, flats, backend="pallas", shards=2,
                   overlap=False)
    got = _fold(layout, flats, backend="pallas", shards=2, overlap=False,
                tile_dtype="float32")
    for g, w in zip(got.to_arrays(), oracle.to_arrays()):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)


@pytest.mark.shard
def test_sharded_mesh_placement_bitwise():
    """An explicit mesh pins each shard's kernel to a device; placement
    must be invisible in the result.  Needs >1 simulated device (the
    shard-cpu CI lane forces 8)."""
    jax = pytest.importorskip("jax")
    if jax.device_count() < 2:
        pytest.skip("single-device host; shard-cpu lane covers this")
    from repro.launch.mesh import make_agg_mesh

    mesh = make_agg_mesh(min(8, jax.device_count()))
    layout, flats = make_payloads("big_unaligned", "q8", 4, seed=29)
    assert K.StreamingWeightedSum(layout, mesh=mesh).shards \
        == mesh.devices.size
    want = _fold(layout, flats, backend="numpy",
                 shards=mesh.devices.size, overlap=False)
    got = _fold(layout, flats, backend="pallas", mesh=mesh)
    assert_flat_ulp(got, want, maxulp=0)


@pytest.mark.shard
@pytest.mark.parametrize("name", ["fedavgm", "fedadam", "fedyogi"])
def test_sharded_fedopt_moments_match_over_rounds(name):
    """FedOpt server state (velocity / m / v) sharded vs single-vector
    over 3 rounds: the update is elementwise, so the returned parameters
    must match bitwise every round."""
    from repro.fl.messages import FitRes
    from repro.fl.strategy import make_strategy

    rng = np.random.default_rng(30)
    shapes = [(64, 8), (1031,), (3,)]
    sharded = make_strategy(name, shards=4)
    exact = make_strategy(name, low_memory=True)
    cur_s = [np.zeros(s, np.float32) for s in shapes]
    cur_e = [np.zeros(s, np.float32) for s in shapes]
    for rnd in (1, 2, 3):
        results = []
        for c in range(5):
            arrays = [rng.normal(0, 1 + c, s).astype(np.float32)
                      for s in shapes]
            results.append((f"site-{c}", FitRes(arrays, 10 + c, {})))
        got, _ = sharded.aggregate_fit(rnd, results, [], cur_s)
        want, _ = exact.aggregate_fit(rnd, results, [], cur_e)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w, err_msg=name)
        cur_s, cur_e = got, want

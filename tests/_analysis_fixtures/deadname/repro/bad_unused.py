"""Seeded: imports bound and never read."""
import json                             # dead-name
import os
from typing import Dict, List           # dead-name (List)


def manifest(root: str) -> Dict[str, str]:
    return {name: os.path.join(root, name) for name in os.listdir(root)}

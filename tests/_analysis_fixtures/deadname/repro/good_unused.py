"""Clean twin: every import is read (or noqa'd re-export)."""
import os
from typing import Dict

from tests.conftest import seed_rng  # noqa: F401 -- re-export for plugins


def manifest(root: str) -> Dict[str, str]:
    return {name: os.path.join(root, name) for name in os.listdir(root)}

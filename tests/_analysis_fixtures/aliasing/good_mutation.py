"""Clean twin: copies are materialized before any write."""
import numpy as np


def shift_tile(src, i):
    view = src.tile_source(i)
    out = view.copy()
    out[0] = 0.0
    out += 1.0
    return out


def shift_wire(raw):
    buf = np.frombuffer(raw, dtype=np.float64)
    buf.flags.writeable = False
    result = buf.copy()
    result *= 2.0
    return result

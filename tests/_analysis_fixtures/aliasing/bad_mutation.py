"""Seeded: in-place writes into borrow-only views."""
import numpy as np


def clobber_tile(src, i):
    view = src.tile_source(i)
    view[0] = 0.0                       # alias-mutation (subscript store)
    view.fill(1.0)                      # alias-mutation (.fill in-place)
    return view


def clobber_wire(raw):
    buf = np.frombuffer(raw, dtype=np.float64)
    buf.flags.writeable = False
    buf += 1.0                          # alias-mutation (augmented assignment)
    np.copyto(buf, buf * 2)             # alias-mutation (copyto destination)
    return buf

"""Clean twin: every frombuffer view is frozen or copied immediately."""
import numpy as np


def decode(buf):
    arr = np.frombuffer(buf, dtype=np.float32)
    arr.flags.writeable = False
    return arr


def materialize(buf):
    return np.frombuffer(buf, dtype=np.uint8).copy()

"""Seeded: frombuffer views handed out without freezing."""
import numpy as np


def decode(buf):
    arr = np.frombuffer(buf, dtype=np.float32)      # alias-writeable (never frozen)
    return arr


def peek(buf):
    return np.frombuffer(buf, dtype=np.uint8)       # alias-writeable (unbound)

"""Seeded: unparseable module -> parse-error (meta, unsuppressible)."""


def broken(:
    return

"""Seeded ambient entropy in a fold path."""
import random
import time

import numpy as np


def fold(xs):
    jitter = random.random()            # det-entropy
    noise = np.random.normal(0, 1)      # det-entropy (legacy global)
    stamp = time.monotonic()            # det-entropy (clock in fold)
    return sum_like(xs) + jitter + noise + stamp


def sum_like(xs):
    return xs

"""Seeded set iteration in an aggregation module: hash order leaks."""


def fold(results):
    total = 0.0
    for node_id in {r.node for r in results}:       # det-set-iter
        total += results[node_id]
    for x in set(results):                          # det-set-iter
        total += x
    return total

"""Clean twin: node ids are sorted before the fold touches them."""


def fold(results):
    total = 0.0
    for node_id in sorted({r.node for r in results}):
        total += results[node_id]
    for x in sorted(set(results)):
        total += x
    return total

"""Clean twin: explicitly seeded generator plumbing only."""
import numpy as np


def fold(xs, seed):
    rng = np.random.default_rng(np.random.SeedSequence(seed))
    return xs + rng.normal(0, 1)

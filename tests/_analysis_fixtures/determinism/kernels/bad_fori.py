"""Seeded constant-foldable fori_loop trip counts: XLA unrolls these and
LLVM's reassociation re-enables FMA contraction in the fold."""
import jax


def kernel(o_ref, x_ref):
    def body(i, acc):
        return acc + x_ref[i]

    o_ref[...] = jax.lax.fori_loop(0, 16, body, 0.0)        # det-fori-trip
    o_ref[...] += jax.lax.fori_loop(0, x_ref.shape[0] - 1,  # det-fori-trip
                                    body, 0.0)

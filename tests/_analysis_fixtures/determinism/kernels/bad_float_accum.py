"""Seeded Python-float accumulation inside a traced function."""
import jax.numpy as jnp


def traced_loss(parts):
    return sum(jnp.sum(p) for p in parts)           # det-float-accum

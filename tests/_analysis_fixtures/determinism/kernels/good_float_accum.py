"""Clean twin: the reduction stays inside the traced fp64 accumulator."""
import jax.numpy as jnp


def traced_loss(parts):
    acc = jnp.zeros((), jnp.float64)
    for p in parts:
        acc = acc + jnp.sum(p, dtype=jnp.float64)
    return acc


def python_total(weights):
    # builtin sum over plain Python floats in an UNtraced helper is fine
    return sum(weights)

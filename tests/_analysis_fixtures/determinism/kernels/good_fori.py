"""Clean twin: the trip count flows through a runtime ref, so the loop
cannot be unrolled at trace time (kernels/agg_reduce.py idiom)."""
import jax


def kernel(o_ref, x_ref, n_ref):
    def body(i, acc):
        return acc + x_ref[i]

    o_ref[...] = jax.lax.fori_loop(0, n_ref[0], body, 0.0)

"""Clean twin: monotonic deadlines; wall clock only for a human-facing
timestamp, under a reasoned allow."""
import time


def wait_for(predicate, timeout_s: float) -> bool:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(0.01)
    return False


def report() -> dict:
    # timestamp shown to humans in an exported log, never compared
    wall = time.time()  # repro: allow[monotonic-clock] reason=human-facing log timestamp
    return {"wall_time": wall, "elapsed": time.perf_counter()}

"""Clean twin: heartbeat expiry and result-cache TTL on the monotonic
clock, immune to wall-clock steps."""
import time


class PeerState:
    def __init__(self):
        self.last_seen = time.monotonic()

    def beat(self):
        self.last_seen = time.monotonic()

    def silent_for(self) -> float:
        return time.monotonic() - self.last_seen


class ResultCache:
    TTL = 30.0

    def __init__(self):
        self._done = {}

    def put(self, msg_id, payload):
        self._done[msg_id] = (payload, time.monotonic())

    def reap(self):
        cutoff = time.monotonic() - self.TTL
        for mid, (_, ts) in list(self._done.items()):
            if ts < cutoff:
                del self._done[mid]

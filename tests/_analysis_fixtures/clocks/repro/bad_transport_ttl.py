"""Seeded: wall clock driving transport heartbeat expiry and result-cache
TTL — an NTP step would mass-expire live peers and cached results."""
import time


class PeerState:
    def __init__(self):
        self.last_seen = time.time()                # monotonic-clock

    def beat(self):
        self.last_seen = time.time()                # monotonic-clock

    def silent_for(self) -> float:
        return time.time() - self.last_seen         # monotonic-clock


class ResultCache:
    TTL = 30.0

    def __init__(self):
        self._done = {}

    def put(self, msg_id, payload):
        self._done[msg_id] = (payload, time.time())     # monotonic-clock

    def reap(self):
        cutoff = time.time() - self.TTL                 # monotonic-clock
        for mid, (_, ts) in list(self._done.items()):
            if ts < cutoff:
                del self._done[mid]

"""Seeded: wall clock used for deadline/TTL arithmetic."""
import time


def wait_for(predicate, timeout_s: float) -> bool:
    deadline = time.time() + timeout_s          # monotonic-clock
    while time.time() < deadline:               # monotonic-clock
        if predicate():
            return True
        time.sleep(0.01)
    return False


def stamp_ns() -> int:
    return time.time_ns()                       # monotonic-clock

"""Seeded: suppression pragmas that are themselves findings."""
import numpy as np


def decode(buf):
    arr = np.frombuffer(buf, dtype=np.float32)  # repro: allow[alias-writeable]
    return arr                                  # ^ bare-allow (no reason=)


def frame(payload):
    x = 1  # repro: allow[not-a-rule] reason=typo'd rule id -> unknown-rule
    return payload + bytes([x])

"""Clean twin: a reasoned allow fully suppresses the finding."""
import numpy as np


def decode(buf):
    # repro: allow[alias-writeable] reason=caller owns buf exclusively in this fixture
    arr = np.frombuffer(buf, dtype=np.float32)
    return arr

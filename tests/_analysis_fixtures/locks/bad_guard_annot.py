"""Seeded guarded-by annotation violation: `_cache` declares its guard
but flush() writes it holding the wrong lock."""
import threading


class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._io_lock = threading.Lock()
        self._cache = {}  # guarded-by: _lock

    def put(self, k, v):
        with self._lock:
            self._cache[k] = v

    def flush(self):
        with self._io_lock:
            self._cache.clear()

"""Seeded: credit-ledger state debited under the lock but refilled bare —
a concurrent grant and debit lose credits (or mint them from thin air)."""
import threading


class CreditLedger:
    def __init__(self, limit: int):
        self._lock = threading.Lock()
        self.credits = limit

    def debit(self, n: int) -> bool:
        with self._lock:
            if self.credits < n:
                return False
            self.credits -= n
            return True

    def refill(self, n: int):
        self.credits = self.credits + n

"""Seeded lock-held-helper misuse: `_reap` declares guarded-by but
tick() calls it without holding the lock."""
import threading


class Cache:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = {}  # guarded-by: _lock

    def _reap(self):  # guarded-by: _lock
        self._items.clear()

    def tick(self):
        self._reap()

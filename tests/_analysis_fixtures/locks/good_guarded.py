"""Clean twin: annotated attributes only written under their guard,
lock-held helper only called with the lock held."""
import threading


class Tracker:
    def __init__(self):
        self._lock = threading.Lock()
        self.stats = {"seen": 0}  # guarded-by: _lock
        self._items = {}          # guarded-by: _lock

    def record(self):
        with self._lock:
            self.stats["seen"] += 1

    def reset(self):
        with self._lock:
            self.stats = {"seen": 0}

    def _reap(self):  # guarded-by: _lock
        self._items.clear()

    def tick(self):
        with self._lock:
            self._reap()

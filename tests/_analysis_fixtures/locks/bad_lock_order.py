"""Seeded lock-order inversion: _a -> _b in push, _b -> _a in drain."""
import threading


class Queue:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.items = []

    def push(self, x):
        with self._a:
            with self._b:
                self.items.append(x)

    def drain(self):
        with self._b:
            with self._a:
                out, self.items = self.items, []
        return out

"""Clean twin: every credit mutation under the ledger lock, the lock-held
grant helper only called with the lock taken."""
import threading


class CreditLedger:
    def __init__(self, limit: int):
        self._lock = threading.Lock()
        self.credits = limit      # guarded-by: _lock
        self._pending = 0         # guarded-by: _lock

    def debit(self, n: int) -> bool:
        with self._lock:
            if self.credits < n:
                return False
            self.credits -= n
            return True

    def refill(self, n: int):
        with self._lock:
            self._pending += n
            self._flush()

    def _flush(self):  # guarded-by: _lock
        self.credits += self._pending
        self._pending = 0

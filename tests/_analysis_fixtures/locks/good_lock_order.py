"""Clean twin: one global acquisition order (_a before _b), and the
reentrant helper pattern uses an RLock."""
import threading


class Queue:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self._r = threading.RLock()
        self.items = []

    def push(self, x):
        with self._a:
            with self._b:
                self.items.append(x)

    def drain(self):
        with self._a:
            with self._b:
                out, self.items = self.items, []
        return out

    def _bump(self):
        with self._r:
            self.items.append(None)

    def bump_twice(self):
        with self._r:
            self._bump()        # fine: RLock is reentrant

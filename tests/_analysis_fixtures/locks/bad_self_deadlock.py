"""Seeded self-deadlock: a non-reentrant Lock re-acquired through a
helper called while it is already held."""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def _bump(self):
        with self._lock:
            self.n += 1

    def bump_twice(self):
        with self._lock:
            self._bump()        # re-acquires the non-reentrant lock
            self.n += 1

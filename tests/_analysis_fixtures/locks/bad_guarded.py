"""Seeded mixed-discipline write: `stats` is locked in record() but
written bare in reset() — the unlocked write is the race."""
import threading


class Tracker:
    def __init__(self):
        self._lock = threading.Lock()
        self.stats = {"seen": 0}

    def record(self):
        with self._lock:
            self.stats["seen"] += 1

    def reset(self):
        self.stats = {"seen": 0}

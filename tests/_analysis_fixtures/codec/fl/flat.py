"""Fixture registry: a miniature fl/flat.py whose WIRE_MAGICS table is
the single allowed home for 0xF0-0xFF hex literals.  The codec fixtures
pass this file alongside their own so CodecCheck sees a registry."""
from typing import Dict

WIRE_MAGIC_LO = 0xF0
WIRE_MAGIC_HI = 0xFF
WIRE_MAGICS: Dict[str, int] = {
    "flat": 0xF1,
    "bf16": 0xF2,
    "q8": 0xF3,
    "partial": 0xF4,
    "sparse": 0xF5,
    "metric_batch": 0xFB,
}
PAYLOAD_CODEC_MAGICS = ("flat", "bf16", "q8", "partial", "sparse")

"""Clean twin: the uncovered tail raises UnsupportedCodec."""
from tests._analysis_fixtures.codec.fl.flat import WIRE_MAGICS


class UnsupportedCodec(ValueError):
    pass


FLAT_MAGIC = WIRE_MAGICS["flat"]
BF16_MAGIC = WIRE_MAGICS["bf16"]


def decode(b: bytes):
    v = b[0]
    if v == FLAT_MAGIC:
        return ("flat", b[1:])
    if v == BF16_MAGIC:
        return ("bf16", b[1:])
    raise UnsupportedCodec(f"no decoder branch for version byte {v:#04x}")

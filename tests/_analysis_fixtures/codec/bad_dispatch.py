"""Seeded: a payload-magic dispatch that silently drops q8 frames."""
from tests._analysis_fixtures.codec.fl.flat import WIRE_MAGICS

FLAT_MAGIC = WIRE_MAGICS["flat"]
BF16_MAGIC = WIRE_MAGICS["bf16"]


def decode(b: bytes):                   # codec-dispatch (q8 uncovered, no raise)
    v = b[0]
    if v == FLAT_MAGIC:
        return ("flat", b[1:])
    if v == BF16_MAGIC:
        return ("bf16", b[1:])
    return None

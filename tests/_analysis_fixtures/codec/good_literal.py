"""Clean twin: version bytes flow through named registry aliases."""
from tests._analysis_fixtures.codec.fl.flat import WIRE_MAGICS

FLAT_MAGIC = WIRE_MAGICS["flat"]


def frame(payload: bytes) -> bytes:
    return bytes([FLAT_MAGIC]) + payload

"""Seeded: a hex version byte claimed outside the registry."""


def frame(payload: bytes) -> bytes:
    return bytes([0xF7]) + payload      # codec-literal (raw version byte)

"""Flat-buffer codec + vectorized-strategy equivalence tests.

Covers the guarantees the aggregation engine rests on:
- bitwise round-trip of the flat wire format for every dtype (incl. bf16);
- interop with the legacy per-array codec (decode auto-detects);
- zero-copy decode (leaves are views into the received bytes);
- every strategy's flat-path output matches the legacy per-layer path
  exactly (FedAvg family, median, trimmed mean) or to within 1 ULP;
- incremental (as-results-arrive) accumulation == batch aggregation.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

import ml_dtypes

from repro.fl import agg_kernels as kernels
from repro.fl.flat import FlatParams, layout_of, unflatten_vector
from repro.fl.legacy import LEGACY_TABLE
from repro.fl.messages import (FLAT_MAGIC, FitIns, FitRes, arrays_to_bytes,
                               bytes_to_arrays, decode_fit_ins,
                               decode_fit_res, encode_fit_ins,
                               encode_fit_res, set_default_codec)
from repro.fl.strategy import make_strategy

RNG = np.random.default_rng(7)

ALL_DTYPES = [np.float32, np.float64, np.float16, np.int32, np.int64,
              np.int8, np.uint8, np.uint64, np.bool_, ml_dtypes.bfloat16]


def _arrays(dtypes, shapes=None):
    shapes = shapes or [(3, 4), (7,), (2, 2, 2), (1,)] * 3
    out = []
    for i, dt in enumerate(dtypes):
        shape = shapes[i % len(shapes)]
        a = RNG.normal(0, 3, size=shape)
        if np.dtype(dt) == np.bool_:
            out.append((a > 0).astype(np.bool_))
        elif np.issubdtype(np.dtype(dt), np.integer):
            out.append(a.astype(np.int64).astype(dt))
        else:
            out.append(a.astype(dt))
    return out


# ---------------------------------------------------------------------------
# flat representation
# ---------------------------------------------------------------------------
def test_flat_roundtrip_all_dtypes_bitwise():
    arrays = _arrays(ALL_DTYPES)
    fp = FlatParams.from_arrays(arrays)
    back = fp.to_arrays()
    assert len(back) == len(arrays)
    for a, b in zip(arrays, back):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()


def test_layout_cache_interns():
    a1 = _arrays([np.float32, np.float32])
    a2 = [np.copy(x) for x in a1]
    assert layout_of(a1) is layout_of(a2)


def test_flat_math_view_and_f64():
    arrays = [np.arange(6, dtype=np.float32).reshape(2, 3),
              np.ones(4, np.float32)]
    fp = FlatParams.from_arrays(arrays)
    v = fp.math_view()
    assert v.dtype == np.float32 and v.size == 10
    np.testing.assert_array_equal(fp.to_f64(),
                                  np.concatenate([a.ravel() for a in arrays])
                                  .astype(np.float64))


def test_unflatten_vector_casts_to_leaf_dtype():
    arrays = _arrays([np.float32, np.float16])
    layout = layout_of(arrays)
    vec = np.arange(layout.total_size, dtype=np.float64)
    leaves = unflatten_vector(vec, layout)
    assert [l.dtype for l in leaves] == [np.dtype(np.float32),
                                         np.dtype(np.float16)]


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------
def test_flat_codec_fit_res_roundtrip_bitwise():
    arrays = _arrays(ALL_DTYPES)
    res = FitRes(arrays, 17, {"loss": 0.5, "tag": "x"})
    dec = decode_fit_res(encode_fit_res(res, codec="flat"))
    assert dec.num_examples == 17 and dec.metrics["loss"] == 0.5
    for a, b in zip(arrays, dec.parameters):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()
    assert dec.flat is not None


def test_flat_decode_is_zero_copy():
    arrays = [RNG.normal(size=(64, 64)).astype(np.float32)]
    b = encode_fit_res(FitRes(arrays, 1, {}), codec="flat")
    dec = decode_fit_res(b)
    # views into the message bytes, not fresh allocations
    for leaf in dec.parameters:
        assert not leaf.flags["OWNDATA"]
        assert not leaf.flags["WRITEABLE"]
    assert not dec.flat.buf.flags["OWNDATA"]


def test_fit_ins_decode_is_writable():
    """Clients may mutate fit() parameters in place (legacy contract), so
    the client-facing decode copies the payload once into a writable
    buffer; only the server-side FitRes hot path stays zero-copy."""
    arrays = [RNG.normal(size=(8, 8)).astype(np.float32)]
    dec = decode_fit_ins(encode_fit_ins(FitIns(arrays, {}), codec="flat"))
    dec.parameters[0] += 1.0                 # must not raise
    np.testing.assert_allclose(dec.parameters[0], arrays[0] + 1.0)


def test_codec_interop_legacy_and_flat():
    arrays = _arrays([np.float32, np.float64, np.int32])
    ins = FitIns(arrays, {"round": 3})
    for codec in ("legacy", "flat"):
        dec = decode_fit_ins(encode_fit_ins(ins, codec=codec))
        assert dec.config["round"] == 3
        for a, b in zip(arrays, dec.parameters):
            assert a.tobytes() == b.tobytes(), codec
    # arrays_to_bytes round-trips through both codecs too
    for codec in ("legacy", "flat"):
        back = bytes_to_arrays(arrays_to_bytes(arrays, codec=codec))
        for a, b in zip(arrays, back):
            assert a.tobytes() == b.tobytes(), codec


def test_default_codec_switch():
    arrays = [np.ones(3, np.float32)]
    prev = set_default_codec("legacy")
    try:
        b = encode_fit_res(FitRes(arrays, 1, {}))
        assert b[0] != FLAT_MAGIC                # msgpack fixmap marker
        assert decode_fit_res(b).parameters[0].tobytes() == \
            arrays[0].tobytes()
    finally:
        set_default_codec(prev)
    b = encode_fit_res(FitRes(arrays, 1, {}))
    assert b[0] == FLAT_MAGIC


def test_flat_codec_empty_parameters():
    dec = decode_fit_res(encode_fit_res(FitRes([], 1, {}), codec="flat"))
    assert dec.parameters == []


# ---------------------------------------------------------------------------
# strategy equivalence: flat kernels vs legacy per-layer loops
# ---------------------------------------------------------------------------
def _make_results(n_clients=5, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    shapes = [(16, 8), (32,), (4, 4, 4), (1,)]
    results = []
    for c in range(n_clients):
        arrays = [rng.normal(0, 1, size=s).astype(dtype) for s in shapes]
        results.append((f"site-{c}", FitRes(arrays, 10 + 7 * c, {})))
    current = [np.zeros(s, dtype) for s in shapes]
    return results, current


def _assert_leaves_close(got, want, exact=False):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype
        if exact:
            np.testing.assert_array_equal(g, w)
        else:
            np.testing.assert_array_max_ulp(g, w, maxulp=1)


STRATEGY_KW = {
    "fedavgm": dict(server_lr=0.7, momentum=0.9),
    "fedadam": dict(server_lr=0.1, beta1=0.9, beta2=0.99, tau=1e-3),
    "fedyogi": dict(server_lr=0.1),
    "fedtrimmedmean": dict(beta=0.25),
    "krum": dict(num_byzantine=1, num_selected=2),
}


@pytest.mark.parametrize("name", sorted(LEGACY_TABLE))
def test_strategy_matches_legacy(name):
    kw = STRATEGY_KW.get(name, {})
    new = make_strategy(name, **kw)
    old = LEGACY_TABLE[name](**kw)
    exact = name in ("fedavg", "fedmedian", "fedtrimmedmean", "krum")
    current = None
    cur_new = cur_old = None
    for rnd in range(1, 4):                      # stateful over 3 rounds
        results, current0 = _make_results(n_clients=6, seed=100 + rnd)
        if cur_new is None:
            cur_new, cur_old = current0, [np.copy(a) for a in current0]
        got, m_new = new.aggregate_fit(rnd, results, [], cur_new)
        want, m_old = old.aggregate_fit(rnd, results, [], cur_old)
        _assert_leaves_close(got, want, exact=exact)
        if name == "krum":
            # new API reports node ids; legacy reports list positions
            assert m_new["krum_selected"] == \
                [results[i][0] for i in m_old["krum_selected"]]
        cur_new, cur_old = got, want


def test_fedavg_matches_legacy_bitwise_f64_leaves():
    results, current = _make_results(n_clients=4, seed=3, dtype=np.float64)
    got, _ = make_strategy("fedavg").aggregate_fit(1, results, [], current)
    want, _ = LEGACY_TABLE["fedavg"]().aggregate_fit(1, results, [], current)
    _assert_leaves_close(got, want, exact=True)


def test_incremental_accumulator_equals_batch():
    st_ = make_strategy("fedavg")
    results, current = _make_results(n_clients=5, seed=11)
    acc = st_.fit_accumulator(1, current)
    for node, r in results:
        acc.add(node, r)
    got, m = acc.finalize([])
    want, _ = st_.aggregate_fit(1, results, [], current)
    _assert_leaves_close(got, want, exact=True)
    assert m["num_clients"] == 5


def test_low_memory_streaming_within_ulp():
    results, current = _make_results(n_clients=6, seed=13)
    got, _ = make_strategy("fedavg", low_memory=True) \
        .aggregate_fit(1, results, [], current)
    want, _ = LEGACY_TABLE["fedavg"]().aggregate_fit(1, results, [], current)
    _assert_leaves_close(got, want, exact=False)


def test_fedavg_min_clients_enforced_by_accumulator():
    st_ = make_strategy("fedavg", min_fit_clients=3)
    results, current = _make_results(n_clients=2, seed=1)
    with pytest.raises(RuntimeError):
        st_.aggregate_fit(1, results, [], current)


def test_strategy_accepts_mixed_dtype_leaves():
    rng = np.random.default_rng(5)
    shapes = [(8, 4), (16,)]
    results = []
    for c in range(4):
        arrays = [rng.normal(size=shapes[0]).astype(np.float32),
                  rng.normal(size=shapes[1]).astype(ml_dtypes.bfloat16)]
        results.append((f"s{c}", FitRes(arrays, 5 + c, {})))
    current = [np.zeros(shapes[0], np.float32),
               np.zeros(shapes[1], ml_dtypes.bfloat16)]
    got, _ = make_strategy("fedavg").aggregate_fit(1, results, [], current)
    want, _ = LEGACY_TABLE["fedavg"]().aggregate_fit(1, results, [], current)
    assert got[1].dtype == ml_dtypes.bfloat16
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.astype(np.float32),
                                      w.astype(np.float32))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(0, 1000))
def test_weighted_mean_property(n_clients, seed):
    """flat weighted mean == legacy per-layer loop, any client count."""
    from repro.fl.legacy import legacy_weighted_average
    from repro.fl.strategy import weighted_average

    rng = np.random.default_rng(seed)
    pairs = [([rng.normal(size=(5, 3)).astype(np.float32),
               rng.normal(size=(7,)).astype(np.float32)],
              float(rng.integers(1, 100))) for _ in range(n_clients)]
    got = weighted_average(pairs)
    want = legacy_weighted_average(pairs)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------------
# kernels edge cases
# ---------------------------------------------------------------------------
def test_kernels_chunk_boundaries():
    """Totals straddling CHUNK exercise the blocked loops."""
    for total in (kernels.CHUNK - 1, kernels.CHUNK, kernels.CHUNK + 1,
                  2 * kernels.CHUNK + 5):
        rng = np.random.default_rng(total)
        pairs = [(FlatParams.from_arrays(
            [rng.normal(size=total).astype(np.float32)]), 1.0 + i)
            for i in range(3)]
        layout = pairs[0][0].layout
        got = kernels.weighted_mean(pairs, layout).math_view()
        W = sum(w for _, w in pairs)
        want = sum((np.float64(w / W) * p.math_view().astype(np.float64)
                    for p, w in pairs), np.zeros(total))
        np.testing.assert_array_equal(got, want.astype(np.float32))


def test_krum_gram_distances_match_direct():
    rng = np.random.default_rng(2)
    flats = [FlatParams.from_arrays([rng.normal(size=1000)
                                     .astype(np.float32)]) for _ in range(5)]
    D = kernels.krum_distances(flats, flats[0].layout)
    X = np.stack([f.to_f64() for f in flats])
    for i in range(5):
        for j in range(5):
            want = float(np.sum((X[i] - X[j]) ** 2))
            assert abs(D[i, j] - want) <= 1e-6 * max(want, 1.0)


def test_krum_gram_survives_large_common_offset():
    """Late-round regime: updates share a huge common component and differ
    by tiny per-client deltas. The naive ||a||²+||b||²−2<a,b> expansion
    cancels catastrophically; the centered tiles must not."""
    rng = np.random.default_rng(3)
    base = rng.normal(0, 1e5, size=4096)
    flats = [FlatParams.from_arrays(
        [(base + rng.normal(0, 1e-3, size=4096)).astype(np.float64)])
        for _ in range(5)]
    D = kernels.krum_distances(flats, flats[0].layout)
    X = np.stack([f.to_f64() for f in flats])
    for i in range(5):
        for j in range(i + 1, 5):
            want = float(np.sum((X[i] - X[j]) ** 2))
            assert abs(D[i, j] - want) <= 1e-6 * want, (i, j, D[i, j], want)


def test_secagg_masked_share_bitwise_matches_seed_algorithm():
    """Wire compat: masked shares must equal what the seed per-leaf
    implementation produces, or mixed-version fleets' masks stop
    cancelling mod 2^64."""
    from repro.fl.mods import _prg_mask, quantize, SecAggMod
    from repro.fl.messages import (TaskIns, decode_task_res, encode_fit_ins,
                                   encode_task_ins)
    from repro.fl.client import ClientApp, NumPyClient

    rng = np.random.default_rng(9)
    arrays = [rng.normal(size=(5, 3)).astype(np.float32),
              rng.normal(size=(7,)).astype(np.float32)]

    class _Echo(NumPyClient):
        def fit(self, parameters, config):
            return parameters, 10, {}

    mod = SecAggMod(site="a", peers=["a", "b"],
                    pairwise_seed_fn=lambda x, y: 1234)
    app = ClientApp(lambda cid: _Echo().to_client(), mods=[mod])
    t = TaskIns("fit", 2, encode_fit_ins(FitIns(arrays, {})), task_id="t")
    got = decode_fit_res(decode_task_res(app.handle(encode_task_ins(t)))
                         .payload).parameters
    # seed algorithm: per-leaf quantize + per-leaf spawn_key=(round, leaf)
    for leaf, a in enumerate(arrays):
        q = quantize(np.asarray(a, np.float64) * 10.0)
        q = q + _prg_mask(1234, 2, leaf, q.shape, positive=True)
        np.testing.assert_array_equal(got[leaf], q)


# ---------------------------------------------------------------------------
# batched metric streaming (satellite)
# ---------------------------------------------------------------------------
def test_metric_batch_encode_decode():
    from repro.runtime.streaming import (_BATCH_MAGIC, _decode_batch,
                                         _encode, _encode_batch)

    items = [("site-1/loss", 0.25, 3), ("site-1/acc", 0.9, 3),
             ("site-1/lr", 1e-3, 3)]
    b = _encode_batch(items)
    assert b[0] == _BATCH_MAGIC
    assert _decode_batch(b) == items
    # legacy single-scalar frames must never collide with the magic
    assert _encode("site-1/loss", 0.25, 3)[0] != _BATCH_MAGIC


def test_metric_collector_accepts_batches():
    from repro.runtime.streaming import MetricCollector, _encode_batch

    class _Msg:
        def __init__(self, payload):
            self.payload = payload

    mc = MetricCollector()
    mc.on_event(_Msg(_encode_batch([("s/a", 1.0, 0), ("s/b", 2.0, 0)])))
    mc.on_event(_Msg(_encode_batch([("s/a", 3.0, 1)])))
    assert mc.tags() == ["s/a", "s/b"]
    assert mc.series("s/a") == [(0, 1.0), (1, 3.0)]

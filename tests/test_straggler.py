"""Straggler / dead-node / timeout semantics (the fault-tolerance story).

One slow or dead SuperNode must never abort a round: the completion queue
yields results in arrival order under a single shared deadline, stragglers
demote to recorded ``(node, "timeout")`` failures, and orphaned state
(undelivered TaskIns, late TaskRes) is reaped instead of leaking into the
next round.
"""
import threading
import time
from contextlib import contextmanager

import msgpack
import numpy as np
import pytest

from repro.core.lgs import LGSConnection
from repro.core.superlink import (FleetConnection, NativeConnection,
                                  SuperLink, SuperLinkDriver, SuperNode)
from repro.fl import (ClientApp, FedAvg, NumPyClient, QuorumNotMet,
                      ServerApp, ServerConfig, make_strategy)
from repro.fl.messages import (FitIns, FitRes, TaskIns, decode_fit_res,
                               decode_task_res, encode_fit_ins,
                               encode_task_ins)
from repro.runtime.reliable import RequestTimeout

PARAMS = [np.zeros((8,), np.float32), np.zeros((3, 2), np.float32)]


class ConstClient(NumPyClient):
    """Returns constant parameters; optionally slow or dead (blocks on an
    event the test releases at teardown so threads join fast)."""

    def __init__(self, value, n=10, delay=0.0, dead=None, eval_error=False):
        self.value = float(value)
        self.n = n
        self.delay = delay
        self.dead = dead                 # threading.Event or None
        self.eval_error = eval_error

    def get_parameters(self, config):
        return [np.zeros_like(a) for a in PARAMS]

    def fit(self, parameters, config):
        if self.dead is not None:
            self.dead.wait()
        if self.delay:
            time.sleep(self.delay)
        return [np.full_like(a, self.value) for a in PARAMS], self.n, {}

    def evaluate(self, parameters, config):
        if self.eval_error:
            raise ValueError("evaluate exploded")
        if self.dead is not None:
            self.dead.wait()
        return self.value, self.n, {}


@contextmanager
def fleet(clients):
    """SuperLink + one SuperNode per client, torn down promptly."""
    link = SuperLink()
    release = [c.dead for c in clients.values() if c.dead is not None]
    nodes = [SuperNode(s, ClientApp(lambda cid, c=c: c.to_client()),
                       NativeConnection(link))
             for s, c in sorted(clients.items())]
    for n in nodes:
        n.start()
    try:
        yield link, SuperLinkDriver(link, expected_nodes=len(nodes))
    finally:
        for ev in release:
            ev.set()
        for n in nodes:
            n.stop()


def _fit_task(params=PARAMS, rnd=1):
    ins = FitIns(params, {"round": rnd})
    import uuid
    return encode_task_ins(TaskIns("fit", rnd, encode_fit_ins(ins),
                                   task_id=uuid.uuid4().hex))


def _healthy_reference(values_weights):
    results = [(f"site-{i}", FitRes([np.full_like(a, v) for a in PARAMS], n))
               for i, (v, n) in enumerate(values_weights)]
    agg, _ = FedAvg().aggregate_fit(1, results, [], PARAMS)
    return agg


# ---------------------------------------------------------------------------
# the acceptance scenario: one straggler + one dead node, rounds complete
# ---------------------------------------------------------------------------
def test_dead_and_straggler_round_completes():
    delta = 0.35
    dead_ev = threading.Event()
    clients = {
        "site-1": ConstClient(1.0, n=10),
        "site-2": ConstClient(2.0, n=20),
        "site-3": ConstClient(3.0, n=30, delay=delta),
        "site-4-dead": ConstClient(9.0, n=40, dead=dead_ev),
    }
    timeout = 1.0
    app = ServerApp(ServerConfig(num_rounds=2, round_timeout=timeout),
                    FedAvg(initial_parameters=PARAMS))
    with fleet(clients) as (link, driver):
        t0 = time.monotonic()
        h = app.run(driver)
        elapsed = time.monotonic() - t0

    assert len(h.rounds) == 2                         # no round aborted
    for rec in h.rounds:
        failed = [n for n, _ in rec.failures]
        assert "site-4-dead" in failed
        assert all(r == "timeout" for n, r in rec.failures
                   if n == "site-4-dead")
        assert rec.metrics["num_clients"] == 3
    # aggregate == healthy-subset reference, <=1 ULP
    want = _healthy_reference([(1.0, 10), (2.0, 20), (3.0, 30)])
    for got, ref in zip(h.final_parameters, want):
        np.testing.assert_array_max_ulp(got, ref, maxulp=1)
    # one shared deadline per phase, not N x timeout: 2 rounds x
    # (fit + evaluate) wait out the dead node once per phase at most
    assert elapsed < 2 * 2 * timeout + 1.5, elapsed


def test_straggler_only_round_ends_at_arrival_not_deadline():
    """With no dead nodes the round finishes when the last result lands
    (~delta), far before the generous deadline."""
    delta = 0.5

    class NoEval(FedAvg):
        def configure_evaluate(self, rnd, parameters, nodes):
            return {}

    clients = {"site-1": ConstClient(1.0),
               "site-2": ConstClient(2.0),
               "site-3": ConstClient(3.0, delay=delta)}
    app = ServerApp(ServerConfig(num_rounds=1, round_timeout=10.0),
                    NoEval(initial_parameters=PARAMS))
    with fleet(clients) as (link, driver):
        t0 = time.monotonic()
        h = app.run(driver)
        elapsed = time.monotonic() - t0
    assert not h.rounds[0].failures
    assert delta - 0.05 <= elapsed < delta + 1.5, elapsed


def test_initial_parameters_fall_back_past_dead_node():
    """get_parameters round 0: a dead first node must not abort the run."""
    dead_ev = threading.Event()
    clients = {"site-0-dead": ConstClient(0.0, dead=dead_ev),
               "site-1": ConstClient(1.0)}

    class NoEval(FedAvg):
        def configure_evaluate(self, rnd, parameters, nodes):
            return {}

    app = ServerApp(ServerConfig(num_rounds=1, round_timeout=0.5), NoEval())
    with fleet(clients) as (link, driver):
        h = app.run(driver)
    assert len(h.rounds) == 1
    assert ("site-0-dead", "timeout") in h.rounds[0].failures


# ---------------------------------------------------------------------------
# shared deadline (regression: was N x timeout)
# ---------------------------------------------------------------------------
def test_send_and_receive_total_wait_bounded_by_one_timeout():
    dead1, dead2 = threading.Event(), threading.Event()
    clients = {"site-1": ConstClient(1.0),
               "site-2-dead": ConstClient(2.0, dead=dead1),
               "site-3-dead": ConstClient(3.0, dead=dead2)}
    timeout = 0.6
    with fleet(clients) as (link, driver):
        tasks = {s: _fit_task() for s in clients}
        t0 = time.monotonic()
        with pytest.raises(TimeoutError) as ei:
            driver.send_and_receive(tasks, timeout)
        elapsed = time.monotonic() - t0
    # one shared deadline: NOT 2 dead nodes x 0.6s each
    assert elapsed < timeout + 0.5, elapsed
    assert "site-2-dead" in str(ei.value) and "site-3-dead" in str(ei.value)


def test_iter_yields_in_arrival_order_before_deadline():
    clients = {"site-1": ConstClient(1.0, delay=0.4),
               "site-2": ConstClient(2.0)}
    with fleet(clients) as (link, driver):
        tasks = {s: _fit_task() for s in clients}
        order = [n for n, _ in driver.send_and_receive_iter(tasks, 5.0)]
    assert order == ["site-2", "site-1"]      # arrival order, not sorted


# ---------------------------------------------------------------------------
# reaping: late responses dropped, undelivered tasks removed, no state leak
# ---------------------------------------------------------------------------
def test_late_response_discarded_without_state_leak():
    clients = {"site-1-slow": ConstClient(5.0, delay=0.6),
               "site-2": ConstClient(2.0)}
    with fleet(clients) as (link, driver):
        tasks = {s: _fit_task() for s in clients}
        got = dict(driver.send_and_receive_iter(tasks, 0.25))
        assert set(got) == {"site-2"}
        # the slow node finishes late; its result must be dropped on arrival
        deadline = time.monotonic() + 3.0
        while (link.stats["late_dropped"] < 1
               and time.monotonic() < deadline):
            time.sleep(0.02)
        assert link.stats["late_dropped"] == 1
        with link._results_cv:
            assert not link._results          # nothing leaked
            assert not link._expired          # tombstone consumed
        # the node is healthy again: a fresh exchange works, uncorrupted
        res = driver.send_and_receive({"site-1-slow": _fit_task(rnd=2)}, 5.0)
        tr = decode_task_res(res["site-1-slow"])
        fr = decode_fit_res(tr.payload)
        assert float(fr.parameters[0][0]) == 5.0


def test_undelivered_task_reaped_from_queue():
    link = SuperLink()
    link.fleet_unary("register", b"ghost")    # registered but never polls
    driver = SuperLinkDriver(link)
    t0 = time.monotonic()
    with pytest.raises(TimeoutError):
        driver.send_and_receive({"ghost": _fit_task()}, 0.3)
    assert time.monotonic() - t0 < 1.0
    with link._lock:
        assert not link._task_queues["ghost"]  # TaskIns reaped
    with link._results_cv:
        assert not link._expired               # never delivered: no tombstone
    assert link.stats["discarded_ins"] == 1


def test_malformed_response_demoted_to_per_node_failure():
    """Garbage bytes from a byzantine/buggy node must not abort the
    exchange — they become a (node, "malformed response: ...") failure."""
    from repro.fl.messages import TaskRes, encode_fit_res, encode_task_res
    from repro.fl.server import Driver, ServerApp

    ok_bytes = encode_task_res(TaskRes(
        "fit", 1, encode_fit_res(FitRes(PARAMS, 10, {})), task_id="t1"))

    class TwoNodeDriver(Driver):
        def send_and_receive_iter(self, tasks, timeout):
            yield "site-bad", b"\xc1 not msgpack"
            yield "site-ok", ok_bytes

    got = []
    failures = ServerApp._exchange(
        TwoNodeDriver(), {"site-bad": b"", "site-ok": b""}, 1.0,
        lambda node, tr: got.append(node))
    assert got == ["site-ok"]
    assert len(failures) == 1
    node, reason = failures[0]
    assert node == "site-bad" and reason.startswith("malformed response:")


def test_wrong_shape_result_demoted_to_per_node_failure():
    """A well-formed FitRes with mismatched tensor shapes must be rejected
    at add time (per-node failure), not crash the kernel at finalize."""

    class WrongShape(NumPyClient):
        def fit(self, parameters, config):
            return [np.ones((3,), np.float32) for _ in PARAMS], 10, {}

        def evaluate(self, parameters, config):
            return 0.0, 10, {}

    class NoEval(FedAvg):
        def configure_evaluate(self, rnd, parameters, nodes):
            return {}

    clients = {"site-1": ConstClient(1.0), "site-2": ConstClient(2.0)}
    link = SuperLink()
    nodes = [SuperNode(s, ClientApp(lambda cid, c=c: c.to_client()),
                       NativeConnection(link))
             for s, c in sorted(clients.items())]
    bad = WrongShape()
    nodes.append(SuperNode("site-3-bad",
                           ClientApp(lambda cid: bad.to_client()),
                           NativeConnection(link)))
    for n in nodes:
        n.start()
    try:
        app = ServerApp(ServerConfig(num_rounds=2, round_timeout=5.0),
                        NoEval(initial_parameters=PARAMS))
        h = app.run(SuperLinkDriver(link, expected_nodes=3))
    finally:
        for n in nodes:
            n.stop()
    assert len(h.rounds) == 2
    for rec in h.rounds:
        reasons = dict(rec.failures)
        assert "shapes" in reasons["site-3-bad"]
        assert rec.metrics["num_clients"] == 2
    want = _healthy_reference([(1.0, 10), (2.0, 10)])
    for got, ref in zip(h.final_parameters, want):
        np.testing.assert_array_max_ulp(got, ref, maxulp=1)


def test_blocking_only_driver_timeout_demotes_to_failures():
    """A Driver that implements only the all-or-nothing blocking API must
    still honor the iter contract: a timeout yields nothing (all nodes
    recorded as failures), never an exception out of ServerApp.run."""
    from repro.fl.server import Driver

    class BlockingOnly(Driver):
        def node_ids(self):
            return ["site-1", "site-2"]

        def send_and_receive(self, tasks, timeout):
            raise TimeoutError("straggler in an all-or-nothing batch")

    class NoEval(FedAvg):
        def configure_evaluate(self, rnd, parameters, nodes):
            return {}

    app = ServerApp(ServerConfig(num_rounds=1, round_timeout=0.1),
                    NoEval(initial_parameters=PARAMS, min_fit_clients=0))
    with pytest.raises(QuorumNotMet):
        # 0 results < quorum 1 — but crucially via QuorumNotMet at
        # finalize (with both nodes recorded), not a raw TimeoutError
        app.run(BlockingOnly())


# ---------------------------------------------------------------------------
# ordering invariance of aggregation
# ---------------------------------------------------------------------------
def _rand_results(n=5, seed=7):
    rng = np.random.default_rng(seed)
    out = []
    for c in range(n):
        arrays = [rng.normal(0, 1, a.shape).astype(np.float32)
                  for a in PARAMS]
        out.append((f"site-{c}", FitRes(arrays, 10 + 3 * c, {})))
    return out


@pytest.mark.parametrize("name,kw", [
    ("fedavg", {}), ("fedavg", {"low_memory": True}), ("fedadam", {}),
    ("fedmedian", {}), ("krum", {"num_byzantine": 1}),
])
def test_arrival_order_matches_sorted_order_within_ulp(name, kw):
    results = _rand_results()
    shuffled = [results[i] for i in (3, 0, 4, 2, 1)]
    current = [np.zeros_like(a) for a in PARAMS]

    def run(order):
        strat = make_strategy(name, **kw)     # fresh server state each run
        acc = strat.fit_accumulator(1, current)
        for node, res in order:
            acc.add(node, res)
        return acc.finalize([])[0]

    for a, b in zip(run(shuffled), run(sorted(results))):
        np.testing.assert_array_max_ulp(a, b, maxulp=1)


# ---------------------------------------------------------------------------
# quorum knob
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["fedavg", "fedmedian", "fedtrimmedmean",
                                  "krum"])
def test_quorum_not_met_raises(name):
    strat = make_strategy(name, min_available=3)
    acc = strat.fit_accumulator(1, [np.zeros_like(a) for a in PARAMS])
    for node, res in _rand_results(n=2):
        acc.add(node, res)
    with pytest.raises(QuorumNotMet):
        acc.finalize([("site-9", "timeout")])


def test_quorum_met_succeeds_with_failures_present():
    strat = make_strategy("fedmedian", min_available=3)
    acc = strat.fit_accumulator(1, [np.zeros_like(a) for a in PARAMS])
    for node, res in _rand_results(n=3):
        acc.add(node, res)
    agg, metrics = acc.finalize([("site-9", "timeout")])
    assert metrics["num_clients"] == 3


# ---------------------------------------------------------------------------
# evaluate phase forwards real failures
# ---------------------------------------------------------------------------
def test_evaluate_failures_forwarded_to_strategy():
    seen = {}

    class Recording(FedAvg):
        def aggregate_evaluate(self, rnd, results, failures):
            seen[rnd] = (list(results), list(failures))
            return super().aggregate_evaluate(rnd, results, failures)

    dead_ev = threading.Event()
    clients = {"site-1": ConstClient(1.0),
               "site-2-boom": ConstClient(2.0, eval_error=True),
               "site-3-dead": ConstClient(3.0, dead=dead_ev)}
    app = ServerApp(ServerConfig(num_rounds=1, round_timeout=0.8),
                    Recording(initial_parameters=PARAMS))
    with fleet(clients) as (link, driver):
        h = app.run(driver)

    results, failures = seen[1]
    assert [n for n, _ in results] == ["site-1"]
    reasons = dict(failures)
    assert "evaluate exploded" in reasons["site-2-boom"]
    assert reasons["site-3-dead"] == "timeout"
    assert set(reasons) <= {n for n, _ in h.rounds[0].failures}


# ---------------------------------------------------------------------------
# transport-error demotion (FLARE-bridged path)
# ---------------------------------------------------------------------------
class FlakyConnection(FleetConnection):
    def __init__(self, inner, fail_first=3):
        self.inner = inner
        self.remaining = fail_first

    def unary(self, method, request):
        if method != "register" and self.remaining > 0:
            self.remaining -= 1
            raise RequestTimeout("injected transport timeout")
        return self.inner.unary(method, request)


def test_supernode_survives_transport_timeouts():
    link = SuperLink()
    client = ConstClient(4.0)
    node = SuperNode("site-1", ClientApp(lambda cid: client.to_client()),
                     FlakyConnection(NativeConnection(link), fail_first=3),
                     poll_interval=0.005)
    node.start()
    try:
        driver = SuperLinkDriver(link, expected_nodes=1)
        res = driver.send_and_receive({"site-1": _fit_task()}, 5.0)
        fr = decode_fit_res(decode_task_res(res["site-1"]).payload)
        assert float(fr.parameters[0][0]) == 4.0
        assert node.transport_errors >= 1
    finally:
        node.stop()


class _FakeCtx:
    def __init__(self, resp=None, exc=None):
        self.resp, self.exc = resp, exc

    def request(self, dest, topic, payload, timeout=None):
        if self.exc is not None:
            raise self.exc
        return self.resp


def test_lgs_demotes_tagged_timeout_to_request_timeout():
    resp = msgpack.packb({"r": b"", "e": "TimeoutError('x')", "k": "timeout"},
                         use_bin_type=True)
    with pytest.raises(RequestTimeout):
        LGSConnection(_FakeCtx(resp)).unary("pull_task_ins", b"site-1")


def test_lgs_keeps_non_timeout_errors_fatal():
    resp = msgpack.packb({"r": b"", "e": "ValueError('bad')", "k": "error"},
                         use_bin_type=True)
    with pytest.raises(RuntimeError) as ei:
        LGSConnection(_FakeCtx(resp)).unary("pull_task_ins", b"site-1")
    assert not isinstance(ei.value, RequestTimeout)


def test_request_timeout_carries_exchange_coordinates():
    err = RequestTimeout("x", target="server", topic="flower/unary",
                         timeout=1.5)
    assert (err.target, err.topic, err.timeout) == ("server", "flower/unary",
                                                    1.5)

"""ReliableMessage semantics (paper §4.1) under injected faults."""
import threading
import time

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.runtime.reliable import ReliableMessenger, RequestTimeout
from repro.runtime.transport import FaultSpec, Message, Network


def make_pair(faults=None, timeout=10.0):
    net = Network(faults)
    a = ReliableMessenger(net, "a", retry_interval=0.01, default_timeout=timeout)
    b = ReliableMessenger(net, "b", retry_interval=0.01, default_timeout=timeout)
    return net, a, b


def test_basic_roundtrip():
    net, a, b = make_pair()
    b.register_handler("echo", lambda m: b"pong:" + m.payload)
    assert a.request("b", "echo", b"x") == b"pong:x"


def test_exactly_once_execution_under_drops_and_dups():
    calls = []
    net, a, b = make_pair(FaultSpec(drop_prob=0.3, dup_prob=0.3, seed=7))

    def handler(m):
        calls.append(m.payload)
        return b"ok" + m.payload

    b.register_handler("work", handler)
    for i in range(20):
        assert a.request("b", "work", str(i).encode()) == b"ok" + str(i).encode()
    # dedup: each logical request executed exactly once
    assert sorted(calls) == sorted(str(i).encode() for i in range(20))


def test_result_recovered_via_query_when_push_lost():
    """Seed chosen so the first RESP pushes get dropped; the query-pull path
    must still deliver (paper §4.1 case 2)."""
    net, a, b = make_pair(FaultSpec(drop_prob=0.5, seed=3))
    b.register_handler("t", lambda m: b"r")
    for _ in range(10):
        assert a.request("b", "t", b"") == b"r"
    assert net.stats["dropped"] > 0


def test_timeout_aborts():
    net, a, b = make_pair(timeout=0.3)
    # no handler registered on b for this topic -> request can never complete
    with pytest.raises(RequestTimeout):
        a.request("b", "nope", b"", timeout=0.3)


def test_slow_handler_covered_by_query_pending():
    net, a, b = make_pair()

    def slow(m):
        time.sleep(0.2)
        return b"done"

    b.register_handler("slow", slow)
    t0 = time.monotonic()
    assert a.request("b", "slow", b"") == b"done"
    assert time.monotonic() - t0 < 5.0


def test_handler_registered_late_still_serves():
    """Requests arriving before the job process registers its handler must
    not be dedup-blackholed (regression: bridged SuperNode startup)."""
    net, a, b = make_pair()
    result = {}

    def requester():
        result["r"] = a.request("b", "late", b"", timeout=5.0)

    t = threading.Thread(target=requester)
    t.start()
    time.sleep(0.2)
    b.register_handler("late", lambda m: b"served")
    t.join(timeout=6.0)
    assert result.get("r") == b"served"


def test_responder_cache_reaped_after_ttl():
    """A long-lived responder must not grow its result cache forever:
    payloads are reaped once result_ttl passes, while the (tiny) dedup
    marks survive 10x longer so a straggling duplicate REQ can never
    re-execute a non-idempotent handler."""
    net = Network()
    a = ReliableMessenger(net, "a", retry_interval=0.01)
    b = ReliableMessenger(net, "b", retry_interval=0.01, result_ttl=0.15)
    b.register_handler("w", lambda m: b"ok")
    assert a.request("b", "w", b"1") == b"ok"
    with b._lock:
        assert len(b._results) == 1
    time.sleep(0.3)
    assert a.request("b", "w", b"2") == b"ok"   # insert triggers the reap
    with b._lock:
        assert len(b._results) == 1             # old payload reaped
        assert len(b._seen) == 2                # dedup marks retained
    time.sleep(1.6)                             # > 10 x result_ttl
    assert a.request("b", "w", b"3") == b"ok"
    with b._lock:
        assert len(b._results) == 1 and len(b._seen) == 1
    net.close()


def test_timeout_carries_target_and_topic():
    net, a, b = make_pair(timeout=0.2)
    with pytest.raises(RequestTimeout) as ei:
        a.request("b", "nope", b"", timeout=0.2)
    assert ei.value.target == "b" and ei.value.topic == "nope"
    assert ei.value.timeout == 0.2


def test_bytes_only_boundary():
    net, a, b = make_pair()
    with pytest.raises(TypeError):
        net.send(Message("x", 0, "REQ", "a", "b", "t", {"not": "bytes"}))


@settings(max_examples=12, deadline=None)
@given(st.floats(0.0, 0.4), st.floats(0.0, 0.4), st.integers(0, 10_000))
def test_reliability_property(drop, dup, seed):
    """For any (drop<=0.4, dup<=0.4, seed): every request completes with the
    right payload and executes exactly once."""
    net, a, b = make_pair(FaultSpec(drop_prob=drop, dup_prob=dup,
                                    max_delay_s=0.005, seed=seed),
                          timeout=30.0)
    seen = []
    b.register_handler("p", lambda m: (seen.append(m.payload), b"=" + m.payload)[1])
    for i in range(5):
        assert a.request("b", "p", f"{i}".encode()) == f"={i}".encode()
    assert sorted(seen) == [f"{i}".encode() for i in range(5)]
    net.close()

"""Hierarchical edge aggregation + async FedBuff suite.

Covers the 0xF4 partial-sum wire codec, the population registry's
seed-deterministic availability-weighted sampling, the bounded-staleness
FedBuff buffer (property-tested), the SuperLink waiter/stream primitives,
and end-to-end two-tier topologies — including the bitwise
hierarchical-vs-flat equivalence and a 10k-simulated-client round
(``-m hier``, the CI hier-cpu lane).
"""
import threading
import time

import msgpack
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare tier-1 container
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.interop import run_hierarchical, run_native
from repro.core.superlink import (EdgeAggregatorApp, InlineFleetDriver,
                                  NativeConnection, SuperLink,
                                  SuperLinkDriver, SuperNode, TaskStream,
                                  make_edge_tier)
from repro.fl.client import ClientApp, NumPyClient
from repro.fl.fedbuff import FedBuffBuffer
from repro.fl.flat import PartialSum, WIRE_MAGICS
from repro.fl.messages import (FitRes, UnsupportedCodec, bytes_to_arrays,
                               decode_evaluate_ins, decode_fit_ins,
                               decode_fit_res, encode_partial_fit_res)
from repro.fl.registry import PopulationRegistry
from repro.fl.server import ServerApp, ServerConfig
from repro.fl.strategy import FedAvg, FedAvgM, FedMedian
from repro.fl import flat as F


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _layout():
    return F.layout_for([("float32", (8, 4)), ("float32", (5,))])


def _partial(vec_fill=1.5, w=3.0, count=2, ids=("a", "b"),
             failures=(("c", "timeout"),)):
    lay = _layout()
    data = np.full(lay.total_size, vec_fill, np.float64)
    return PartialSum(lay, data, w, count, tuple(ids), tuple(failures))


class DyadicClient(NumPyClient):
    """Deterministic client whose updates are exact in binary fp
    (integers / 256, weight 1), so ANY summation grouping — flat, 1
    edge, 8 edges — produces the identical fp64 sum."""

    def __init__(self, site):
        self.idx = int(site.rsplit("-", 1)[1])

    def get_parameters(self, config):
        return [np.zeros((8, 4), np.float32), np.zeros((5,), np.float32)]

    def fit(self, parameters, config):
        rng = np.random.default_rng(self.idx)
        out = [p + rng.integers(-512, 512, p.shape).astype(np.float32) / 256.0
               for p in parameters]
        return out, 1, {}

    def evaluate(self, parameters, config):
        return float(sum(np.abs(p).sum() for p in parameters)), 4, {}


class NoisyClient(DyadicClient):
    """Non-dyadic update values: exposes any regrouping of the sum."""

    def fit(self, parameters, config):
        rng = np.random.default_rng(self.idx)
        out = [p + rng.standard_normal(p.shape).astype(np.float32) / 3.0
               for p in parameters]
        return out, 1 + self.idx % 3, {}


def _app_fn(cls):
    def fn(site):
        return ClientApp(client_fn=lambda cid, s=site: cls(s).to_client())
    return fn


def _same_params(a, b):
    return all(np.array_equal(x, y) for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# 0xF4 wire codec
# ---------------------------------------------------------------------------
def test_partial_frame_roundtrip_zero_copy():
    ps = _partial()
    wire = encode_partial_fit_res(ps, metrics={"edge": "e0"})
    assert wire[0] == WIRE_MAGICS["partial"]
    res = decode_fit_res(wire)
    assert res.partial is not None and res.parameters is None
    got = res.partial
    assert got.total_w == 3.0 and got.count == 2
    assert got.node_ids == ("a", "b")
    assert got.failures == (("c", "timeout"),)
    assert got.layout == ps.layout
    np.testing.assert_array_equal(got.data, ps.data)
    assert res.num_examples == 2 and res.metrics == {"edge": "e0"}
    # zero-copy view over the frame, born read-only
    assert not got.data.flags.writeable
    with pytest.raises((ValueError, RuntimeError)):
        got.data[0] = 9.0


def test_partial_frame_rejected_by_parameter_decoders():
    wire = encode_partial_fit_res(_partial())
    for decoder in (decode_fit_ins, decode_evaluate_ins, bytes_to_arrays):
        with pytest.raises(UnsupportedCodec):
            decoder(wire)
    with pytest.raises(UnsupportedCodec):
        decode_fit_res(wire).materialize()


def test_next_reserved_byte_still_unknown():
    # 0xF4 and 0xF5 are now taken; 0xF6 is the canonical unknown probe
    wire = bytearray(encode_partial_fit_res(_partial()))
    wire[0] = WIRE_MAGICS["sparse"] + 1
    with pytest.raises(UnsupportedCodec):
        decode_fit_res(bytes(wire))


# ---------------------------------------------------------------------------
# population registry
# ---------------------------------------------------------------------------
def test_registry_sampling_is_seed_deterministic():
    nodes = [f"n{i:02d}" for i in range(20)]
    r1 = PopulationRegistry(seed=5)
    r2 = PopulationRegistry(seed=5)
    for reg in (r1, r2):
        reg.observe(successes=nodes[:10], failures=[("n15", "timeout")])
    for rnd in range(4):
        assert r1.sample(nodes, 6, rnd) == r2.sample(nodes, 6, rnd)
    # a different seed (or round) moves the draw for some round
    r3 = PopulationRegistry(seed=6)
    r3.observe(successes=nodes[:10], failures=[("n15", "timeout")])
    assert any(r1.sample(nodes, 6, rnd) != r3.sample(nodes, 6, rnd)
               for rnd in range(8))
    # order of the input node list must not matter
    assert r1.sample(list(reversed(nodes)), 6, 0) == r1.sample(nodes, 6, 0)


def test_registry_demotes_flaky_nodes():
    nodes = ["flaky", "solid-a", "solid-b", "solid-c"]
    reg = PopulationRegistry(seed=1)
    for _ in range(30):
        reg.observe(successes=nodes[1:], failures=[("flaky", "timeout")])
    assert reg.availability("flaky") < 0.1
    assert reg.availability("solid-a") > 0.9
    picked = sum("flaky" in reg.sample(nodes, 2, rnd) for rnd in range(60))
    # availability-weighted: the flaky node is picked far below uniform
    # (uniform would give ~30/60); min_weight keeps it > 0 eventually
    assert picked < 15
    # min_weight floor keeps every node eligible
    assert reg.weight("flaky") >= reg.min_weight > 0.0


def test_registry_sample_edges():
    reg = PopulationRegistry(seed=0)
    nodes = ["a", "b", "c"]
    assert reg.sample(nodes, 3, 0) == sorted(nodes)      # k >= n: everyone
    assert reg.sample(nodes, 99, 0) == sorted(nodes)
    with pytest.raises(ValueError):
        reg.sample(nodes, 0, 0)
    out = reg.sample(nodes, 2, 0)
    assert out == sorted(out) and len(set(out)) == 2


# ---------------------------------------------------------------------------
# FedBuff buffer
# ---------------------------------------------------------------------------
def _leaf_res(seed=0, n=2):
    lay = _layout()
    rng = np.random.default_rng(seed)
    arrs = [rng.standard_normal(tuple(l.shape)).astype(np.float32)
            for l in lay.leaves]
    return FitRes(arrs, n, {})


def test_fedbuff_requires_weighted_sum_strategy():
    with pytest.raises(ValueError):
        FedBuffBuffer(FedMedian())
    FedBuffBuffer(FedAvg())          # FedAvg family is fine
    FedBuffBuffer(FedAvgM())


def test_fedbuff_window_weighted_mean_matches_manual():
    buf = FedBuffBuffer(FedAvg(), buffer_k=3, max_staleness=5,
                        staleness_exponent=0.5)
    offers = [(_leaf_res(seed=s, n=s + 1), 0) for s in range(3)]
    for res, ver in offers:
        assert buf.offer(f"n{ver}", res, ver) == "folded"
    assert buf.ready()
    current = [np.zeros((8, 4), np.float32), np.zeros((5,), np.float32)]
    new, metrics = buf.advance(current)
    # staleness 0 for all => discount 1, plain weighted mean
    ws = [float(r.num_examples) for r, _ in offers]
    want0 = sum(w * r.parameters[0].astype(np.float64)
                for (r, _), w in zip(offers, ws)) / sum(ws)
    np.testing.assert_allclose(new[0].astype(np.float64), want0, atol=1e-6)
    assert metrics["server_version"] == 1
    assert metrics["window_folds"] == 3
    assert buf.version == 1 and not buf.ready()


def test_fedbuff_discount_and_partial_scale():
    buf = FedBuffBuffer(FedAvg(), buffer_k=2, max_staleness=4,
                        staleness_exponent=1.0)
    buf.version = 2                    # pretend two advances happened
    assert buf.discount(0) == 1.0
    assert buf.discount(3) == 0.25
    ps = _partial(vec_fill=2.0, w=4.0, count=3, failures=())
    assert buf.offer("edge", decode_fit_res(encode_partial_fit_res(ps)),
                     trained_version=1) == "folded"      # staleness 1
    assert buf.offer("leaf", _leaf_res(seed=1, n=2),
                     trained_version=2) == "folded"      # staleness 0
    # discounted total weight: 0.5 * 4.0 (partial, s=1) + 1.0 * 2 (leaf)
    assert buf._acc.total_w == pytest.approx(0.5 * 4.0 + 2.0)
    assert buf.folded_staleness == [1, 0]


def test_fedbuff_rejects_future_versions():
    buf = FedBuffBuffer(FedAvg())
    with pytest.raises(ValueError):
        buf.offer("n", _leaf_res(), trained_version=1)   # ahead of server


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=9),
                          st.integers(min_value=1, max_value=4)),
                min_size=1, max_size=40),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=5))
def test_fedbuff_never_folds_beyond_staleness_bound(arrivals, buffer_k,
                                                    max_staleness):
    """Property: whatever the arrival sequence, no folded update is
    staler than the configured bound, and everything beyond the bound is
    dropped (never silently folded)."""
    buf = FedBuffBuffer(FedAvg(), buffer_k=buffer_k,
                        max_staleness=max_staleness)
    current = [np.zeros((8, 4), np.float32), np.zeros((5,), np.float32)]
    folded = dropped = 0
    for i, (age, n) in enumerate(arrivals):
        ver = max(buf.version - age, 0)
        verdict = buf.offer(f"n{i}", _leaf_res(seed=i, n=n), ver)
        s = buf.version - ver
        if s > max_staleness:
            assert verdict == "stale"
            dropped += 1
        else:
            assert verdict == "folded"
            folded += 1
        if buf.ready():
            current, metrics = buf.advance(current)
            assert metrics["max_folded_staleness"] <= max_staleness
    assert buf.folded == folded and buf.dropped == dropped
    assert all(s <= max_staleness for s in buf.folded_staleness)


# ---------------------------------------------------------------------------
# SuperLink waiter + TaskStream
# ---------------------------------------------------------------------------
def _push_res(link, tid, payload=b"r"):
    link.fleet_unary("push_task_res",
                     msgpack.packb({"id": tid, "res": payload},
                                   use_bin_type=True))


def test_waiter_routes_results_o1():
    link = SuperLink()
    tids = [link.push_task_ins("n0", b"t%d" % i) for i in range(3)]
    _push_res(link, tids[1], b"early")      # lands before anyone waits
    w = link.register_waiter(tids)
    got = link.waiter_next(w, time.monotonic() + 1.0)
    assert got == (tids[1], b"early")
    _push_res(link, tids[0], b"a")
    _push_res(link, tids[2], b"c")
    arrived = {link.waiter_next(w, time.monotonic() + 1.0)[0]
               for _ in range(2)}
    assert arrived == {tids[0], tids[2]}
    assert link.waiter_next(w, time.monotonic() + 0.02) is None
    link.release_waiter(w, tids)
    link.discard(tids)


def test_release_waiter_returns_undelivered_results():
    link = SuperLink()
    tid = link.push_task_ins("n0", b"t")
    w = link.register_waiter([tid])
    _push_res(link, tid, b"r")              # routed to w.ready, unread
    link.release_waiter(w, [tid])
    # back in the shared store: a later consumer still sees it
    assert link.pull_any([tid], time.monotonic() + 0.5) == (tid, b"r")


def test_waiter_wakes_without_polling():
    link = SuperLink()
    tid = link.push_task_ins("n0", b"t")
    w = link.register_waiter([tid])
    t = threading.Timer(0.05, _push_res, (link, tid))
    t.start()
    t0 = time.monotonic()
    got = link.waiter_next(w, t0 + 5.0)
    dt = time.monotonic() - t0
    t.join()
    assert got is not None and dt < 1.0     # woke on notify, not deadline


def test_task_stream_send_recv_close():
    link = SuperLink()
    stream = TaskStream(link)
    tids = stream.send({"n0": b"t0", "n1": b"t1"})
    assert set(tids) == {"n0", "n1"}
    _push_res(link, tids["n1"], b"r1")
    assert stream.recv(1.0) == ("n1", tids["n1"], b"r1")
    assert stream.recv(0.02) is None        # nothing else yet
    # simulate n0's node pulling its task, so close() must tombstone the
    # in-flight id (an undelivered one would just be reaped instead)
    link.fleet_unary("pull_task_ins", b"n0")
    stream.close()
    _push_res(link, tids["n0"], b"late")
    assert link.stats["late_dropped"] >= 1
    with pytest.raises(RuntimeError):
        stream.send({"n0": b"t"})
    with pytest.raises(RuntimeError):
        stream.recv(0.01)


def test_superlink_driver_round_still_works_with_waiters():
    # the rewritten send_and_receive_iter behaves like the seed version
    link = SuperLink()
    apps = {f"s{i}": ClientApp(
        client_fn=lambda cid: DyadicClient("x-0").to_client())
        for i in range(3)}
    nodes = [SuperNode(n, app, NativeConnection(link))
             for n, app in apps.items()]
    for n in nodes:
        n.start()
    try:
        driver = SuperLinkDriver(link, expected_nodes=3)
        from repro.fl.messages import TaskIns, encode_task_ins
        tasks = {n: encode_task_ins(TaskIns("get_properties", 0, b"",
                                            task_id=f"t{n}"))
                 for n in driver.node_ids()}
        out = driver.send_and_receive(tasks, 10.0)
        assert set(out) == set(tasks)
    finally:
        for n in nodes:
            n.stop()


# ---------------------------------------------------------------------------
# end-to-end: hierarchical sync
# ---------------------------------------------------------------------------
SITES8 = [f"c-{i:03d}" for i in range(8)]


@pytest.mark.parametrize("num_edges", [1, 2, 8])
def test_hierarchical_bitwise_equals_flat_dyadic(num_edges):
    flat = run_native(
        ServerApp(ServerConfig(num_rounds=2), FedAvg(low_memory=True)),
        _app_fn(DyadicClient), SITES8)
    hier = run_hierarchical(
        ServerApp(ServerConfig(num_rounds=2), FedAvg(low_memory=True)),
        _app_fn(DyadicClient), SITES8, num_edges=num_edges)
    assert _same_params(hier.final_parameters, flat.final_parameters)
    for r_h, r_f in zip(hier.rounds, flat.rounds):
        assert r_h.loss == r_f.loss
        assert r_h.metrics["num_clients"] == 8
        assert r_h.metrics["num_payloads"] == num_edges
        assert r_f.metrics["num_payloads"] == 8


def test_single_edge_bitwise_on_any_data():
    # one edge over the whole fleet continues the flat low-memory fold
    # exactly, dyadic or not: acc = 0 + 1.0*S, one divide by W
    flat = run_native(
        ServerApp(ServerConfig(num_rounds=2), FedAvg(low_memory=True)),
        _app_fn(NoisyClient), SITES8)
    hier = run_hierarchical(
        ServerApp(ServerConfig(num_rounds=2), FedAvg(low_memory=True)),
        _app_fn(NoisyClient), SITES8, num_edges=1)
    assert _same_params(hier.final_parameters, flat.final_parameters)


def test_multi_edge_matches_flat_within_regrouping_tolerance():
    flat = run_native(
        ServerApp(ServerConfig(num_rounds=2), FedAvg(low_memory=True)),
        _app_fn(NoisyClient), SITES8)
    hier = run_hierarchical(
        ServerApp(ServerConfig(num_rounds=2), FedAvg(low_memory=True)),
        _app_fn(NoisyClient), SITES8, num_edges=4)
    for a, b in zip(hier.final_parameters, flat.final_parameters):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_mixed_leaf_and_edge_fleet():
    # 1 edge pre-reducing 6 clients + 2 direct leaf clients on the root
    link = SuperLink()
    edge_apps = {s: _app_fn(DyadicClient)(s) for s in SITES8[:6]}
    edges = make_edge_tier(link, edge_apps, num_edges=1, timeout=30.0)
    leaves = [SuperNode(s, _app_fn(DyadicClient)(s), NativeConnection(link))
              for s in SITES8[6:]]
    for n in leaves:
        n.start()
    try:
        h = ServerApp(ServerConfig(num_rounds=1),
                      FedAvg(low_memory=True)).run(
            SuperLinkDriver(link, expected_nodes=3))
    finally:
        for n in edges + leaves:
            n.stop()
    flat = run_native(
        ServerApp(ServerConfig(num_rounds=1), FedAvg(low_memory=True)),
        _app_fn(DyadicClient), SITES8)
    r = h.rounds[0]
    assert r.metrics["num_clients"] == 8
    assert r.metrics["num_payloads"] == 3        # 1 edge + 2 leaves
    # dyadic data: regrouped sum is still exact
    assert _same_params(h.final_parameters, flat.final_parameters)


class FailingClient(DyadicClient):
    def fit(self, parameters, config):
        if self.idx == 3:
            raise RuntimeError("client 3 exploded")
        return super().fit(parameters, config)


def test_subtree_failures_surface_at_root():
    h = run_hierarchical(
        ServerApp(ServerConfig(num_rounds=1), FedAvg()),
        _app_fn(FailingClient), SITES8, num_edges=2)
    r = h.rounds[0]
    assert r.metrics["num_clients"] == 7
    assert r.metrics["num_payloads"] == 2
    subs = r.metrics.get("subtree_failures", [])
    assert any(n == "c-003" and "exploded" in reason for n, reason in subs)


def test_edge_downgrades_to_weighted_mean_for_nonpartial_strategy():
    # FedMedian needs every client's update, so the root never requests
    # the pre-reduction; edges fall back to a plain weighted-mean FitRes.
    h = run_hierarchical(
        ServerApp(ServerConfig(num_rounds=1), FedMedian()),
        _app_fn(DyadicClient), SITES8, num_edges=2)
    assert h.final_parameters is not None
    assert not h.rounds[0].failures


def test_evaluate_through_edges_matches_flat():
    flat = run_native(
        ServerApp(ServerConfig(num_rounds=1), FedAvg(low_memory=True)),
        _app_fn(DyadicClient), SITES8)
    hier = run_hierarchical(
        ServerApp(ServerConfig(num_rounds=1), FedAvg(low_memory=True)),
        _app_fn(DyadicClient), SITES8, num_edges=2)
    assert hier.rounds[0].loss == pytest.approx(flat.rounds[0].loss,
                                                rel=1e-12)


# ---------------------------------------------------------------------------
# end-to-end: sampling + async
# ---------------------------------------------------------------------------
def test_server_sampling_is_deterministic_and_partial():
    def once():
        return run_native(
            ServerApp(ServerConfig(num_rounds=3, sample_k=3, sample_seed=4),
                      FedAvg(low_memory=True)),
            _app_fn(DyadicClient), SITES8)
    h1, h2 = once(), once()
    for r1, r2 in zip(h1.rounds, h2.rounds):
        assert r1.metrics["num_clients"] == 3
        assert r1.loss == r2.loss
    assert _same_params(h1.final_parameters, h2.final_parameters)


def _run_async(config, cls=NoisyClient, n=4):
    sites = [f"c-{i:03d}" for i in range(n)]
    link = SuperLink()
    nodes = [SuperNode(s, _app_fn(cls)(s), NativeConnection(link))
             for s in sites]
    for nd in nodes:
        nd.start()
    try:
        return ServerApp(config, FedAvg()).run(
            SuperLinkDriver(link, expected_nodes=n))
    finally:
        for nd in nodes:
            nd.stop()


def test_async_run_reaches_target_versions_within_staleness_bound():
    cfg = ServerConfig(num_rounds=4, async_mode=True, async_buffer_k=2,
                       async_max_staleness=2, round_timeout=30.0)
    h = _run_async(cfg)
    assert len(h.rounds) == 4
    for i, r in enumerate(h.rounds, start=1):
        assert r.metrics["server_version"] == i
        assert r.metrics["window_folds"] == 2
        assert r.metrics["max_folded_staleness"] <= 2
        assert r.loss is not None            # async_eval_every=1 default
    assert h.final_parameters is not None


def test_async_requires_streaming_driver():
    class Blocking:
        def node_ids(self):
            return ["a"]

    app = ServerApp(ServerConfig(async_mode=True), FedAvg())
    with pytest.raises(RuntimeError, match="open_stream"):
        app.run_async(Blocking())


def test_async_with_edge_tier():
    # edges pre-reduce; the async buffer folds their 0xF4 partials with
    # the staleness discount applied as the partial's scale
    link = SuperLink()
    apps = {s: _app_fn(DyadicClient)(s) for s in SITES8}
    edges = make_edge_tier(link, apps, num_edges=2, timeout=30.0)
    try:
        cfg = ServerConfig(num_rounds=2, async_mode=True, async_buffer_k=2,
                           async_max_staleness=3, round_timeout=30.0)
        h = ServerApp(cfg, FedAvg()).run(
            SuperLinkDriver(link, expected_nodes=2))
    finally:
        for n in edges:
            n.stop()
    assert len(h.rounds) == 2
    # each advance folded two edge partials covering the whole fleet
    assert all(r.metrics["window_folds"] == 2 for r in h.rounds)


# ---------------------------------------------------------------------------
# scale: the 10k-client claim (CI hier-cpu lane re-runs under 8 devices)
# ---------------------------------------------------------------------------
@pytest.mark.hier
@pytest.mark.slow
def test_10k_clients_root_folds_only_edge_payloads():
    n, num_edges = 10_000, 8
    sites = [f"c-{i:05d}" for i in range(n)]
    h = run_hierarchical(
        ServerApp(ServerConfig(num_rounds=1, round_timeout=300.0,
                               agg_shards=8),
                  FedAvg()),
        _app_fn(DyadicClient), sites, num_edges=num_edges,
        edge_timeout=300.0)
    r = h.rounds[0]
    assert r.metrics["num_clients"] == n
    assert r.metrics["num_payloads"] <= num_edges
    assert not r.failures
    assert h.final_parameters is not None

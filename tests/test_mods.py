"""Client mods: DP clipping/noise, SecAgg exactness, Top-K compression."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import run_native
from repro.fl import (DPMod, FedAvg, SecAggFedAvg, SecAggMod, ServerApp,
                      ServerConfig, TopKCompressionMod)
from repro.fl.messages import (FitIns, TaskIns, decode_fit_res,
                               encode_fit_ins, encode_task_ins,
                               decode_task_ins)
from repro.fl.client import ClientApp, NumPyClient
from repro.fl.quickstart import make_client_app

SITES = ["site-1", "site-2", "site-3"]


class _StepClient(NumPyClient):
    """fit() moves params by a fixed delta — makes mod effects exact."""

    def __init__(self, delta):
        self.delta = np.asarray(delta, np.float64)

    def fit(self, parameters, config):
        return ([np.asarray(p, np.float64) + self.delta
                 for p in parameters], 10, {})

    def evaluate(self, parameters, config):
        return 0.0, 1, {}


def _run_fit_through(mods, delta, params):
    app = ClientApp(lambda cid: _StepClient(delta).to_client(), mods=mods)
    ins = FitIns([np.asarray(params, np.float64)], {})
    t = TaskIns("fit", 1, encode_fit_ins(ins), task_id="t")
    res_b = app.handle(encode_task_ins(t))
    from repro.fl.messages import decode_task_res

    return decode_fit_res(decode_task_res(res_b).payload)


def test_dp_clips_update_norm():
    mod = DPMod(clip_norm=0.5, noise_multiplier=0.0)
    res = _run_fit_through([mod], delta=[3.0, 4.0], params=[[0.0, 0.0]])
    # delta norm 5 -> clipped to 0.5
    norm = np.linalg.norm(res.parameters[0])
    assert abs(norm - 0.5) < 1e-9
    assert res.metrics["dp_clip_scale"] == pytest.approx(0.1)


def test_dp_noise_deterministic_per_site_round():
    m1 = DPMod(clip_norm=1.0, noise_multiplier=0.5, site_id=1, seed=9)
    m2 = DPMod(clip_norm=1.0, noise_multiplier=0.5, site_id=1, seed=9)
    r1 = _run_fit_through([m1], [0.1, 0.1], [[0.0, 0.0]])
    r2 = _run_fit_through([m2], [0.1, 0.1], [[0.0, 0.0]])
    np.testing.assert_array_equal(r1.parameters[0], r2.parameters[0])
    m3 = DPMod(clip_norm=1.0, noise_multiplier=0.5, site_id=2, seed=9)
    r3 = _run_fit_through([m3], [0.1, 0.1], [[0.0, 0.0]])
    assert not np.array_equal(r1.parameters[0], r3.parameters[0])


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-10, 10), min_size=2, max_size=6),
       st.floats(0.1, 5.0))
def test_dp_clip_property(delta, clip):
    """Post-mod update norm <= clip bound (+eps), for any delta."""
    mod = DPMod(clip_norm=clip, noise_multiplier=0.0)
    res = _run_fit_through([mod], delta, [[0.0] * len(delta)])
    assert np.linalg.norm(res.parameters[0]) <= clip + 1e-6


def test_secagg_equals_plain_fedavg():
    def seed_fn(a, b):
        lo, hi = sorted([a, b])
        import zlib
        return zlib.crc32(f"{lo}|{hi}".encode())

    plain = run_native(ServerApp(ServerConfig(num_rounds=2), FedAvg()),
                       lambda s: make_client_app(s), SITES)
    sec = run_native(
        ServerApp(ServerConfig(num_rounds=2), SecAggFedAvg()),
        lambda s: make_client_app(s, mods=[SecAggMod(
            site=s, peers=SITES, pairwise_seed_fn=seed_fn)]), SITES)
    for a, b in zip(plain.final_parameters, sec.final_parameters):
        assert np.abs(a.astype(np.float64) - b.astype(np.float64)).max() < 1e-3


def test_secagg_masked_share_looks_random():
    """An individual masked share must not reveal the raw update."""
    def seed_fn(a, b):
        return 12345

    mod = SecAggMod(site="site-1", peers=["site-1", "site-2"],
                    pairwise_seed_fn=seed_fn)
    res = _run_fit_through([mod], [0.25, -0.5], [[0.0, 0.0]])
    share = res.parameters[0]
    # quantized plaintext would be tiny ints; masked is full-range uint64
    assert share.dtype == np.uint64
    assert (share > np.uint64(1) << np.uint64(40)).any()


def test_topk_keeps_fraction():
    mod = TopKCompressionMod(fraction=0.25)
    res = _run_fit_through([mod], [1.0, 0.001, 0.002, 0.003], [[0.0] * 4])
    changed = np.nonzero(res.parameters[0])[0]
    assert len(changed) == 1 and changed[0] == 0
    assert res.metrics["topk_kept_frac"] == pytest.approx(0.25)


def test_mods_compose_in_order():
    """TopK after DP: final update is sparse AND clipped."""
    mods = [DPMod(clip_norm=0.5, noise_multiplier=0.0),
            TopKCompressionMod(fraction=0.5)]
    res = _run_fit_through(mods, [3.0, 4.0], [[0.0, 0.0]])
    assert np.linalg.norm(res.parameters[0]) <= 0.5 + 1e-9

"""Per-architecture smoke tests (deliverable f) + decode consistency.

Every assigned architecture instantiates a REDUCED variant (2-3 layers,
d_model <= 128, <= 4 experts) and runs one forward + one train step on CPU,
asserting output shapes and no NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_model_config, list_archs
from repro.models import build_model
from repro.train.steps import (greedy_generate, make_train_state,
                               make_train_step)

ARCHS = [a for a in list_archs()]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_model_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = model.make_batch(2, 12)
    logits, _, metrics = model.apply(params, batch, mode="train")
    assert logits.shape == (2, 12, cfg.padded_vocab_size)
    assert not jnp.isnan(logits[..., : cfg.vocab_size]).any()
    assert jnp.isfinite(metrics["aux_loss"])


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_model_config(arch, smoke=True)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    state = make_train_state(model, tcfg, jax.random.key(1))
    step = jax.jit(make_train_step(model, tcfg))
    batch = model.make_batch(2, 12)
    state2, m = step(state, batch)
    assert jnp.isfinite(m["loss"])
    assert int(state2.step) == 1
    # params actually changed
    d0 = jax.tree.leaves(state.params)[1]
    d1 = jax.tree.leaves(state2.params)[1]
    assert not np.array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_model_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2, _ = model.apply(params, {"tokens": tok}, mode="decode",
                                    cache=cache)
    assert logits.shape == (2, 1, cfg.padded_vocab_size)
    assert not jnp.isnan(logits[..., : cfg.vocab_size]).any()
    assert int(cache2["pos"][0]) == 1
    # second step advances
    logits, cache3, _ = model.apply(params, {"tokens": tok}, mode="decode",
                                    cache=cache2)
    assert int(cache3["pos"][0]) == 2


@pytest.mark.parametrize("arch", ["yi-34b", "h2o-danube-1.8b", "qwen3-32b",
                                  "deepseek-v2-236b", "xlstm-350m",
                                  "recurrentgemma-2b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill(S-1) + decode(1) logits == full-forward logits at the last
    position — the cache path computes the same function as the parallel
    path.  fp32 smoke variants keep the comparison tight."""
    cfg = get_model_config(arch, smoke=True).replace(
        dtype="float32", param_dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    S = 12
    toks = model.make_batch(2, S)["tokens"]

    full_logits, _, _ = model.apply(params, {"tokens": toks}, mode="train")

    _, cache, _ = model.apply(params, {"tokens": toks[:, :-1]},
                              mode="prefill", prefill_max_len=S)
    dec_logits, _, _ = model.apply(params, {"tokens": toks[:, -1:]},
                                   mode="decode", cache=cache)
    got = np.asarray(dec_logits[:, 0, : cfg.vocab_size])
    want = np.asarray(full_logits[:, -1, : cfg.vocab_size])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_greedy_generate_runs():
    cfg = get_model_config("yi-34b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = model.make_batch(2, 8)["tokens"]
    out = greedy_generate(model, params, prompt, num_new=5)
    assert out.shape == (2, 5)
    assert (np.asarray(out) < cfg.vocab_size).all()


def test_swa_rotating_cache_consistency():
    """Decode beyond the window: rotating cache must equal teacher forcing."""
    cfg = get_model_config("h2o-danube-1.8b", smoke=True).replace(
        dtype="float32", param_dtype="float32", remat=False)
    assert cfg.window == 16
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    S = 24  # > window
    toks = model.make_batch(1, S)["tokens"]
    full_logits, _, _ = model.apply(params, {"tokens": toks}, mode="train")
    _, cache, _ = model.apply(params, {"tokens": toks[:, :-1]},
                              mode="prefill", prefill_max_len=S)
    dec_logits, _, _ = model.apply(params, {"tokens": toks[:, -1:]},
                                   mode="decode", cache=cache)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0, : cfg.vocab_size]),
        np.asarray(full_logits[:, -1, : cfg.vocab_size]),
        rtol=2e-4, atol=2e-4)


def test_vocab_padding_masks_pad_columns():
    cfg = get_model_config("granite-moe-1b-a400m", smoke=True).replace(
        vocab_size=500)    # force a ragged vocab like the full config's 49155
    assert cfg.padded_vocab_size > cfg.vocab_size
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    logits, _, _ = model.apply(params, model.make_batch(1, 4), mode="train")
    pad_cols = np.asarray(logits[..., cfg.vocab_size:])
    assert (pad_cols <= -1e29).all()

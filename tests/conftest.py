import os
import signal
import sys
import threading

import pytest

# NB: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False


# ---------------------------------------------------------------------------
# pytest-timeout fallback shim
#
# The bare tier-1 environment has no pytest-timeout; without SOME per-test
# ceiling a hung socket read or CV wait in the transport suite wedges the
# whole lane.  When the real plugin is absent, honor the same `timeout`
# ini/marker surface with a SIGALRM interrupt (main thread only — exactly
# pytest-timeout's "signal" method).  When the plugin is installed this
# file defines nothing, so the two never fight over the option names.
# ---------------------------------------------------------------------------
if not _HAVE_PYTEST_TIMEOUT:

    def pytest_addoption(parser):
        parser.addini("timeout", "per-test timeout in seconds (shim)",
                      default="0")
        parser.addini("timeout_method", "ignored by the shim (signal only)",
                      default="signal")

    def _limit_for(item):
        marker = item.get_closest_marker("timeout")
        if marker is not None and marker.args:
            return float(marker.args[0])
        try:
            return float(item.config.getini("timeout") or 0)
        except ValueError:
            return 0.0

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        limit = _limit_for(item)
        use_alarm = (limit > 0 and hasattr(signal, "SIGALRM")
                     and threading.current_thread()
                     is threading.main_thread())
        if not use_alarm:
            yield
            return

        def _on_alarm(signum, frame):
            raise TimeoutError(
                f"test exceeded the {limit:.0f}s timeout (conftest shim; "
                f"install pytest-timeout for stack dumps)")

        old = signal.signal(signal.SIGALRM, _on_alarm)
        signal.setitimer(signal.ITIMER_REAL, limit)
        try:
            yield
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, old)

"""Cross-cutting FL integration: convergence through the runtime, FedProx
plumbing, tight-mode collective equivalence (multi-device subprocess)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import run_native
from repro.fl import FedAvg, FedProx, ServerApp, ServerConfig
from repro.fl.quickstart import make_client_app

SITES = ["site-1", "site-2", "site-3"]


def test_fedavg_converges_on_quickstart():
    h = run_native(ServerApp(ServerConfig(num_rounds=4), FedAvg()),
                   lambda s: make_client_app(s, lr=0.02, skew=0.2), SITES)
    losses = [l for _, l in h.losses()]
    assert losses[-1] < losses[0] * 0.5
    accs = [r.metrics.get("accuracy", 0) for r in h.rounds]
    assert accs[-1] > 0.9


def test_fedprox_reaches_similar_loss():
    h = run_native(ServerApp(ServerConfig(num_rounds=3),
                             FedProx(proximal_mu=0.01)),
                   lambda s: make_client_app(s, lr=0.02, skew=0.2), SITES)
    assert h.losses()[-1][1] < 1.0


def test_tight_mode_fedavg_equals_loose_mean():
    """tight-mode collective FedAvg (8 simulated devices, pod axis) must
    equal the arithmetic mean the loose path computes.  Runs in a
    subprocess so the forced device count cannot leak into other tests."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.collective import tight_fedavg

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        # pod-stacked params: two divergent site replicas
        params = {"w": jnp.stack([jnp.zeros((4,)), jnp.ones((4,)) * 2.0])}
        out = tight_fedavg(params, mesh)
        # FedAvg = mean over the pod dim, broadcast back to both pods
        assert np.allclose(out["w"], np.ones((2, 4))), out
        print("TIGHT_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "TIGHT_OK" in r.stdout, r.stdout + r.stderr


def test_fl_round_step_semantics_single_device():
    """vmapped round_fn: K local steps diverge per pod, FedAvg averages."""
    import jax
    import jax.numpy as jnp

    from repro.config import TrainConfig, get_model_config
    from repro.core.collective import make_fl_round_step, pod_stacked_state
    from repro.models import build_model
    from repro.train.steps import make_train_state

    cfg = get_model_config("flower-quickstart", smoke=True)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, global_batch=2, seq_len=16)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    round_fn = make_fl_round_step(model, tcfg, mesh, local_steps=2)

    state = pod_stacked_state(make_train_state(model, tcfg,
                                               jax.random.key(0)), 2)
    rng = np.random.default_rng(0)
    batches = {
        "tokens": rng.integers(0, cfg.vocab_size, (2, 2, 2, 16),
                               dtype=np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (2, 2, 2, 16),
                               dtype=np.int32),
    }
    new_state, metrics = jax.jit(round_fn)(state, batches)
    assert metrics["round_losses"].shape == (2, 2)
    assert np.isfinite(np.asarray(metrics["round_losses"])).all()
    # post-FedAvg params identical across the pod dim
    for leaf in jax.tree.leaves(new_state.params):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   rtol=1e-6, atol=1e-6)
    # and actually moved from init
    l0 = jax.tree.leaves(state.params)[1]
    l1 = jax.tree.leaves(new_state.params)[1]
    assert not np.array_equal(np.asarray(l0), np.asarray(l1))

"""End-to-end behaviour of the paper's system (Fig. 4/5/6 claims).

The heart of the reproduction: the SAME Flower-style app runs natively and
inside the FLARE runtime (clean + faulty transports) with bitwise-identical
results, plus multi-job concurrency, provisioning/authz, and metric
streaming through the runtime.
"""
import numpy as np
import pytest

from repro.core import run_in_flare, run_native
from repro.fl import FedAvg, ServerApp, ServerConfig
from repro.fl.client import ClientApp
from repro.fl.quickstart import QuickstartClient, make_client_app
from repro.runtime import FlareRuntime, JobSpec
from repro.runtime.jobs import JobStatus
from repro.runtime.transport import FaultSpec

SITES = ["site-1", "site-2", "site-3"]


def _server_app(rounds=2):
    return ServerApp(config=ServerConfig(num_rounds=rounds, round_timeout=60),
                     strategy=FedAvg())


@pytest.fixture
def runtime():
    rt = FlareRuntime()
    for s in SITES:
        rt.provision_site(s)
    yield rt
    rt.shutdown()


# ---------------------------------------------------------------------------
# Fig. 5: reproducibility — native == in-FLARE (bitwise)
# ---------------------------------------------------------------------------
def test_native_equals_flare_bitwise(runtime):
    h_native = run_native(_server_app(), lambda s: make_client_app(s), SITES)
    h_flare = run_in_flare(runtime, _server_app(),
                           lambda s: make_client_app(s), SITES)
    assert h_native.losses() == h_flare.losses()
    for a, b in zip(h_native.final_parameters, h_flare.final_parameters):
        assert np.array_equal(a, b)


def test_native_equals_flare_under_faults():
    h_native = run_native(_server_app(), lambda s: make_client_app(s), SITES)
    rt = FlareRuntime(faults=FaultSpec(drop_prob=0.15, dup_prob=0.1,
                                       max_delay_s=0.01, seed=42))
    for s in SITES:
        rt.provision_site(s)
    try:
        h_faulty = run_in_flare(rt, _server_app(),
                                lambda s: make_client_app(s), SITES)
        stats = rt.network.stats
    finally:
        rt.shutdown()
    assert stats["dropped"] > 0, "fault injection did not fire"
    assert h_native.losses() == h_faulty.losses()
    for a, b in zip(h_native.final_parameters, h_faulty.final_parameters):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Fig. 6: metric streaming (hybrid integration)
# ---------------------------------------------------------------------------
def test_metric_streaming_through_runtime(runtime):
    def client_app_fn(site):
        def with_ctx(ctx):
            writer = ctx.summary_writer()
            return ClientApp(client_fn=lambda cid: QuickstartClient(
                site, writer=writer).to_client())
        return with_ctx

    run_in_flare(runtime, _server_app(), client_app_fn, SITES)
    job_id = next(iter(runtime._jobs))
    mc = runtime.metrics(job_id)
    tags = mc.tags()
    for s in SITES:
        assert f"{s}/train_loss" in tags
        assert f"{s}/test_accuracy" in tags
    series = mc.series("site-1/train_loss")
    assert len(series) == 2                      # one point per round
    assert mc.export_tensorboard_json().startswith("{")


# ---------------------------------------------------------------------------
# §3.1 multi-job: concurrent jobs share clients/server without conflicts
# ---------------------------------------------------------------------------
def test_concurrent_jobs(runtime):
    admin = runtime.provisioner.issue("admin", "admin")

    class SJob:
        def __init__(self, tag):
            self.tag = tag

        def run(self, ctx):
            acc = []
            for site in sorted(ctx.sites):
                acc.append(ctx.request(site, "mul", self.tag.encode()).decode())
            return acc

    class CJob:
        def __init__(self, site):
            self.site = site

        def run(self, ctx):
            ctx.register_handler(
                "mul", lambda m: f"{self.site}:{m.payload.decode()}".encode())
            ctx.stop_event.wait()

    ids = []
    for tag in ("alpha", "beta", "gamma"):
        spec = JobSpec(name=tag, server_app_fn=lambda t=tag: SJob(t),
                       client_app_fn=lambda s: CJob(s), min_sites=3,
                       resources={"gpu": 0.25})
        ids.append(runtime.submit_job(spec, admin))
    recs = [runtime.wait(j, timeout=60) for j in ids]
    for rec, tag in zip(recs, ("alpha", "beta", "gamma")):
        assert rec.status == JobStatus.COMPLETED, rec.error
        assert rec.result == [f"{s}:{tag}" for s in SITES]


def test_job_queues_when_resources_exhausted(runtime):
    admin = runtime.provisioner.issue("admin", "admin")

    class SJob:
        def run(self, ctx):
            import time
            time.sleep(0.3)
            return "ok"

    class CJob:
        def __init__(self, site):
            pass

        def run(self, ctx):
            ctx.stop_event.wait()

    specs = [JobSpec(name=f"j{i}", server_app_fn=lambda: SJob(),
                     client_app_fn=lambda s: CJob(s), min_sites=3,
                     resources={"gpu": 1.0}) for i in range(2)]
    ids = [runtime.submit_job(sp, admin) for sp in specs]
    recs = [runtime.wait(j, timeout=60) for j in ids]
    assert all(r.status == JobStatus.COMPLETED for r in recs)


# ---------------------------------------------------------------------------
# provisioning / authorization
# ---------------------------------------------------------------------------
def test_unauthorized_submit_rejected(runtime):
    client_kit = runtime.provisioner.issue("site-1", "client")
    spec = JobSpec(name="x", server_app_fn=lambda: None,
                   client_app_fn=lambda s: None)
    with pytest.raises(PermissionError):
        runtime.submit_job(spec, client_kit)


def test_forged_kit_rejected(runtime):
    from repro.runtime.provision import StartupKit

    forged = StartupKit(runtime.provisioner.project, "admin", "admin",
                        b"\x00" * 32)
    spec = JobSpec(name="x", server_app_fn=lambda: None,
                   client_app_fn=lambda s: None)
    with pytest.raises(PermissionError):
        runtime.submit_job(spec, forged)


def test_pairwise_seeds_symmetric(runtime):
    p = runtime.provisioner
    assert p.pairwise_seed("site-1", "site-2") == p.pairwise_seed("site-2",
                                                                  "site-1")
    assert p.pairwise_seed("site-1", "site-2") != p.pairwise_seed("site-1",
                                                                  "site-3")

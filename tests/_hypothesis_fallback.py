"""Deterministic fallback for `hypothesis` on bare environments.

Tier-1 tests must collect and run without any dev dependencies installed
(ROADMAP: `python -m pytest -x -q` on a stock container).  When the real
`hypothesis` package is available it is always preferred (see the
try/except import in each test module); this shim only provides enough of
the API surface the test-suite actually uses:

    given, settings, strategies.{floats,integers,booleans,lists,
                                 sampled_from,tuples,just}

Draws are pseudo-random from a fixed seed, and the first two examples of
every bounded scalar strategy are its endpoints, so each property still
gets deterministic smoke + edge coverage — just not hypothesis's shrinking
or database. Property failures therefore reproduce exactly across runs.
"""
from __future__ import annotations


import random
from types import SimpleNamespace
from typing import Any, Callable, List

_SEED = 0xF10E25


class _Strategy:
    def __init__(self, draw: Callable[[random.Random, int], Any]):
        self._draw = draw


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    def draw(rng: random.Random, example: int) -> float:
        if example == 0:
            return float(min_value)
        if example == 1:
            return float(max_value)
        return rng.uniform(min_value, max_value)
    return _Strategy(draw)


def integers(min_value: int, max_value: int) -> _Strategy:
    def draw(rng: random.Random, example: int) -> int:
        if example == 0:
            return int(min_value)
        if example == 1:
            return int(max_value)
        return rng.randint(min_value, max_value)
    return _Strategy(draw)


def booleans() -> _Strategy:
    return _Strategy(lambda rng, ex: (rng.random() < 0.5) if ex > 1 else bool(ex))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng, ex: elements[rng.randrange(len(elements))])


def just(value) -> _Strategy:
    return _Strategy(lambda rng, ex: value)


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          **_kw) -> _Strategy:
    def draw(rng: random.Random, example: int) -> List[Any]:
        n = min_size if example == 0 else rng.randint(min_size, max_size)
        return [elements._draw(rng, 2) for _ in range(n)]
    return _Strategy(draw)


def tuples(*strats: _Strategy) -> _Strategy:
    return _Strategy(lambda rng, ex: tuple(s._draw(rng, ex) for s in strats))


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy, **kwstrats: _Strategy):
    def deco(fn):
        # NB: no functools.wraps — it would expose the wrapped signature via
        # __wrapped__ and pytest would treat the drawn params as fixtures.
        def runner():
            n = getattr(runner, "_fallback_max_examples", 10)
            rng = random.Random(_SEED)
            for example in range(n):
                vals = [s._draw(rng, example) for s in strats]
                kw = {k: s._draw(rng, example) for k, s in kwstrats.items()}
                fn(*vals, **kw)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.__dict__.update(getattr(fn, "__dict__", {}))
        return runner
    return deco


strategies = SimpleNamespace(
    floats=floats, integers=integers, booleans=booleans, lists=lists,
    sampled_from=sampled_from, just=just, tuples=tuples)

"""Direct coverage of the legacy.py reference path.

``fl/legacy.py`` is the seed per-layer implementation the flat engine's
bitwise-repro claim is measured against, yet since PR 1's rewrite it was
only ever exercised *as a comparator*.  These tests pin the reference
itself (hand-computed expectations, so legacy.py cannot silently drift)
and enforce the claim over the real wire: legacy-codec bytes through
LegacyFedAvg must equal flat-codec bytes through the flat engine bitwise.
"""
import numpy as np
import pytest

from repro.fl.legacy import (LEGACY_TABLE, LegacyFedAvg,
                             legacy_weighted_average)
from repro.fl.messages import FitRes, decode_fit_res, encode_fit_res
from repro.fl.strategy import make_strategy, weighted_average


def test_legacy_weighted_average_hand_computed():
    """Pin the reference arithmetic itself: sum((w_i/W) * x_i) in fp64,
    cast to the leaf dtype — on values chosen so the expectation is
    exactly representable."""
    a = [np.array([2.0, 4.0], np.float32), np.array([[8.0]], np.float32)]
    b = [np.array([6.0, 0.0], np.float32), np.array([[0.0]], np.float32)]
    out = legacy_weighted_average([(a, 1.0), (b, 3.0)])
    # W=4: (1/4)*a + (3/4)*b
    np.testing.assert_array_equal(out[0], np.array([5.0, 1.0], np.float32))
    np.testing.assert_array_equal(out[1], np.array([[2.0]], np.float32))
    assert out[0].dtype == np.float32 and out[1].dtype == np.float32


def test_legacy_fedavg_min_clients_and_metrics():
    params = [np.ones((3,), np.float32)]
    res = [("site-0", FitRes(params, 5, {}))]
    agg, metrics = LegacyFedAvg().aggregate_fit(1, res, [], params)
    assert metrics == {"num_clients": 1}
    with pytest.raises(RuntimeError):
        LegacyFedAvg(min_fit_clients=2).aggregate_fit(1, res, [], params)


def _wire_results(codec, n_clients=5, seed=0):
    """Client results as the server would decode them off the wire."""
    rng = np.random.default_rng(seed)
    shapes = [(16, 8), (33,), (4, 4, 4), (1,)]
    out = []
    for c in range(n_clients):
        arrays = [rng.normal(0, 1 + c, s).astype(np.float32)
                  for s in shapes]
        payload = encode_fit_res(FitRes(arrays, 10 + 3 * c, {}),
                                 codec=codec)
        r = decode_fit_res(payload)
        r.num_examples = 10 + 3 * c
        out.append((f"site-{c}", r))
    current = [np.zeros(s, np.float32) for s in shapes]
    return out, current


def test_legacy_wire_vs_flat_wire_bitwise():
    """The fig. 5 claim over the real wire: identical updates encoded
    with the legacy per-array codec and the 0xF1 flat codec must
    aggregate to bitwise-identical models through their own engines."""
    legacy_res, current = _wire_results("legacy")
    flat_res, _ = _wire_results("flat")
    want, _ = LegacyFedAvg().aggregate_fit(1, legacy_res, [], current)
    got, _ = make_strategy("fedavg").aggregate_fit(1, flat_res, [], current)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(g, w)


@pytest.mark.parametrize("name", sorted(LEGACY_TABLE))
def test_legacy_table_strategies_run_from_wire_bytes(name):
    """Every legacy reference strategy still executes end to end on
    wire-decoded results (guards against legacy.py bit-rotting into a
    comparator that can no longer run)."""
    results, current = _wire_results("legacy", n_clients=6, seed=3)
    kw = {"num_byzantine": 1} if name == "krum" else {}
    agg, metrics = LEGACY_TABLE[name](**kw).aggregate_fit(
        1, results, [], current)
    assert len(agg) == len(current)
    for a, c in zip(agg, current):
        assert a.shape == c.shape and a.dtype == c.dtype
        assert np.isfinite(a).all()


def test_public_weighted_average_matches_legacy_bitwise():
    rng = np.random.default_rng(11)
    shapes = [(7, 3), (19,)]
    results = [([rng.normal(0, 1, s).astype(np.float32) for s in shapes],
                4.0 + i) for i in range(4)]
    got = weighted_average(results)
    want = legacy_weighted_average(results)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)

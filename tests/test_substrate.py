"""Substrate tests: optimizers, schedules, sharding rules, data, checkpoint."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.config import TrainConfig
from repro.optim import (adamw, clip_by_global_norm, linear_warmup_cosine,
                         make_optimizer, sgd)
from repro.sharding import DEFAULT_RULES, spec_for


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def test_adamw_matches_reference_math():
    lr = 0.1
    opt = adamw(lambda s: jnp.float32(lr), b1=0.9, b2=0.99, eps=1e-8,
                weight_decay=0.0)
    params = {"w": jnp.ones((3,), jnp.float32)}
    grads = {"w": jnp.full((3,), 0.5, jnp.float32)}
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, jnp.int32(0))
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    want = -lr * (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.99)) + 1e-8)
    np.testing.assert_allclose(np.asarray(updates["w"]), want, rtol=1e-5)


def test_weight_decay_applied():
    opt = adamw(lambda s: jnp.float32(0.1), weight_decay=0.1)
    params = {"w": jnp.full((2,), 10.0)}
    grads = {"w": jnp.zeros((2,))}
    state = opt.init(params)
    updates, _ = opt.update(grads, state, params, jnp.int32(0))
    assert (np.asarray(updates["w"]) < 0).all()   # decay pulls toward zero


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert norm == pytest.approx(10.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped)))
    assert total == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_then_decay():
    sched = linear_warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.int32(0))) < float(sched(jnp.int32(9)))
    assert float(sched(jnp.int32(9))) == pytest.approx(1.0, rel=1e-6)
    assert float(sched(jnp.int32(80))) < 1.0


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw",
                                  "adafactor"])
def test_all_optimizers_step(name):
    cfg = TrainConfig(optimizer=name, learning_rate=1e-2)
    opt = make_optimizer(cfg)
    params = {"w": jnp.ones((4, 8)), "b": jnp.zeros((8,))}
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, jnp.int32(0))
    assert all(jnp.isfinite(u).all() for u in jax.tree.leaves(updates))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def _mesh(shape=(2, 4), axes=("data", "model")):
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return jax.sharding.Mesh(devs.reshape(shape), axes)


def test_spec_divisible_dims_shard():
    mesh = _mesh()
    spec = spec_for(("embed", "mlp"), (8, 16), mesh)
    assert spec == P("data", "model")


def test_spec_indivisible_falls_back():
    mesh = _mesh()
    spec = spec_for(("embed", "heads"), (8, 7), mesh)     # 7 % 4 != 0
    assert spec[1] is None


def test_two_pass_gives_model_to_tensor_dim():
    mesh = _mesh()
    # (embed, mlp): mlp (single-axis rule) must claim "model", embed gets data
    spec = spec_for(("embed", "mlp"), (16, 16), mesh)
    assert spec == P("data", "model")
    # expert weights: experts claims model first
    spec = spec_for(("experts", "embed_expert", "mlp"), (8, 16, 16), mesh)
    assert spec[0] == "model" and spec[1] == "data" and spec[2] is None


def test_no_mesh_axis_used_twice():
    mesh = _mesh()
    spec = spec_for(("mlp", "vocab"), (16, 16), mesh)     # both want "model"
    used = [s for s in spec if s is not None]
    assert len(set(used)) == len(used)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64))
def test_spec_property_divisibility(d0, d1):
    """Whatever the dims, sharded dims are always divisible by their axes."""
    mesh = _mesh()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = spec_for(("embed", "mlp"), (d0, d1), mesh)
    for dim, entry in zip((d0, d1), spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = int(np.prod([sizes[a] for a in axes]))
        assert dim % prod == 0


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_synthetic_deterministic_and_site_dependent():
    from repro.data.synthetic import SyntheticLMDataset

    a1 = SyntheticLMDataset(1000, 32, 10, seed=1, site=0).sample(4)
    a2 = SyntheticLMDataset(1000, 32, 10, seed=1, site=0).sample(4)
    b = SyntheticLMDataset(1000, 32, 10, seed=1, site=1).sample(4)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])
    assert not np.array_equal(a1["tokens"], b["tokens"])
    assert np.array_equal(a1["tokens"][:, 1:], a1["labels"][:, :-1])


def test_dirichlet_partition_covers_everything():
    from repro.data.partition import dirichlet_partition

    labels = np.repeat(np.arange(10), 100)
    parts = dirichlet_partition(labels, 5, alpha=0.5, seed=0)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(labels)
    assert len(np.unique(all_idx)) == len(labels)      # disjoint cover
    assert all(len(p) > 0 for p in parts)


def test_iid_partition_balanced():
    from repro.data.partition import iid_partition

    parts = iid_partition(100, 4, seed=0)
    assert sorted(len(p) for p in parts) == [25, 25, 25, 25]


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 5, tree)
    save_checkpoint(str(tmp_path), 10, jax.tree.map(lambda x: x * 2, tree))
    assert latest_step(str(tmp_path)) == 10
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) * 2)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    save_checkpoint(str(tmp_path), 1, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"a": jnp.ones((3,))})


# ---------------------------------------------------------------------------
# messages codec
# ---------------------------------------------------------------------------
def test_array_codec_bitwise():
    from repro.fl.messages import arrays_to_bytes, bytes_to_arrays

    arrays = [np.random.default_rng(0).normal(size=(3, 4)).astype(np.float32),
              np.arange(5, dtype=np.int64),
              np.asarray(jnp.ones((2,), jnp.bfloat16))]
    out = bytes_to_arrays(arrays_to_bytes(arrays))
    for a, b in zip(arrays, out):
        assert a.dtype == b.dtype
        assert np.array_equal(a.view(np.uint8), b.view(np.uint8))

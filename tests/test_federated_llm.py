"""Federated-LLM client pins (examples/federated_llm.py + train.steps).

Two bugfix regressions plus the shared-step cache contract:

- **moment continuity**: ``LMClient.fit`` must NOT rebuild the optimizer
  state each round.  Round R+1 continuing from round R's persisted
  ``TrainState`` is bitwise identical to one uninterrupted local run over
  the same batch stream; the old per-round ``opt.init(params)`` (with the
  step counter jumping to ``round * local_steps``) silently zeroed the
  Adam moments while the LR schedule advanced.
- **one trace per config**: ``get_train_step`` returns the SAME compiled
  callable for equal ``(model_cfg, train_cfg, impl, mesh)``, so an
  N-client simulation compiles once.
"""
import importlib.util
import pathlib
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.config import TrainConfig, get_model_config  # noqa: E402
from repro.data.loader import FederatedDataLoader  # noqa: E402
from repro.train.steps import TrainState, get_train_step  # noqa: E402

_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _example():
    spec = importlib.util.spec_from_file_location(
        "federated_llm_example", _ROOT / "examples" / "federated_llm.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("federated_llm_example", mod)
    spec.loader.exec_module(mod)
    return mod


def _tiny():
    cfg = get_model_config("flower-quickstart").replace(
        d_model=32, num_layers=1, d_ff=64, vocab_size=128, remat=False)
    tcfg = TrainConfig(global_batch=2, seq_len=16, learning_rate=1e-2,
                       warmup_steps=2, total_steps=64)
    return cfg, tcfg


def _loader(cfg, tcfg, seed=11):
    return FederatedDataLoader(cfg.vocab_size, tcfg.seq_len, num_sites=1,
                               batch_per_site=tcfg.global_batch, seed=seed,
                               non_iid_alpha=0.5, prefetch=1)


def test_fit_preserves_optimizer_state_across_rounds():
    mod = _example()
    cfg, tcfg = _tiny()
    local_steps = 3
    client = mod.LMClient("site-1", cfg, tcfg, _loader(cfg, tcfg),
                          local_steps)
    p0 = client.get_parameters({})
    out1, _, _ = client.fit(p0, {"round": 0})
    st1 = client._state                       # snapshot after round 0
    assert int(st1.step) == local_steps
    out2, _, _ = client.fit(out1, {"round": 1})
    assert int(client._state.step) == 2 * local_steps

    # replay round 1 by hand: same batch stream (same-seed loader, skip
    # round 0's batches), CONTINUING from round 0's moments + step
    replay = _loader(cfg, tcfg)
    for _ in range(local_steps):
        replay.next_batch(0)
    from repro.fl.messages import arrays_to_params
    state = TrainState(arrays_to_params(out1, client._like),
                       st1.opt_state, st1.step)
    step_fn = client._step_fn
    for _ in range(local_steps):
        state, _ = step_fn(state, replay.next_batch(0))
    for got, want in zip(out2, mod.params_to_arrays(state.params)):
        np.testing.assert_array_equal(got, want)

    # the pinned bug: re-initializing the moments each round (old fit
    # behavior) diverges from the continuous trajectory
    opt = client._opt
    params1 = arrays_to_params(out1, client._like)
    replay2 = _loader(cfg, tcfg)
    for _ in range(local_steps):
        replay2.next_batch(0)
    stale = TrainState(params1, opt.init(params1),
                       jnp.asarray(local_steps, jnp.int32))
    for _ in range(local_steps):
        stale, _ = step_fn(stale, replay2.next_batch(0))
    assert any(
        np.any(a != b) for a, b in zip(
            out2, mod.params_to_arrays(stale.params)))


def test_rounds_match_one_uninterrupted_local_run():
    """3 federated rounds on a single site == 9 straight local steps."""
    mod = _example()
    cfg, tcfg = _tiny()
    local_steps = 3
    client = mod.LMClient("site-1", cfg, tcfg, _loader(cfg, tcfg),
                          local_steps)
    params = client.get_parameters({})
    for rnd in range(3):
        params, _, _ = client.fit(params, {"round": rnd})

    from repro.fl.messages import arrays_to_params
    straight = _loader(cfg, tcfg)
    p = arrays_to_params(client.get_parameters({}), client._like)
    state = TrainState(p, client._opt.init(p), jnp.zeros((), jnp.int32))
    for _ in range(3 * local_steps):
        state, _ = client._step_fn(state, straight.next_batch(0))
    for got, want in zip(params, mod.params_to_arrays(state.params)):
        np.testing.assert_array_equal(got, want)


def test_train_step_cache_shares_one_compiled_step():
    cfg, tcfg = _tiny()
    assert get_train_step(cfg, tcfg) is get_train_step(cfg, tcfg)
    mesh = None
    try:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh()
    except Exception:  # noqa: BLE001 — no devices for a mesh
        pass
    if mesh is not None:
        assert get_train_step(cfg, tcfg, mesh=mesh) \
            is get_train_step(cfg, tcfg, mesh=mesh)
        assert get_train_step(cfg, tcfg) is not \
            get_train_step(cfg, tcfg, mesh=mesh)
    # distinct configs must NOT collide
    other = tcfg.replace(learning_rate=5e-3) if hasattr(tcfg, "replace") \
        else None
    if other is not None:
        assert get_train_step(cfg, other) is not get_train_step(cfg, tcfg)


def test_clients_with_equal_configs_share_the_step():
    mod = _example()
    cfg, tcfg = _tiny()
    loader = _loader(cfg, tcfg)
    c1 = mod.LMClient("site-1", cfg, tcfg, loader, 1)
    c2 = mod.LMClient("site-2", cfg, tcfg, loader, 1)
    assert c1._step_fn is c2._step_fn


@pytest.mark.slow
def test_sharded_step_matches_unsharded_on_local_mesh():
    """The (1,1)-mesh sharded jit and the plain jit compute the same
    training trajectory (same kernel math, different partitioning)."""
    from repro.launch.mesh import make_local_mesh

    cfg, tcfg = _tiny()
    mesh = make_local_mesh()
    plain = get_train_step(cfg, tcfg)
    sharded = get_train_step(cfg, tcfg, mesh=mesh)
    from repro.models import build_model
    from repro.optim import make_optimizer

    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    opt = make_optimizer(tcfg)
    s_a = s_b = TrainState(params, opt.init(params),
                           jnp.zeros((), jnp.int32))
    loader = _loader(cfg, tcfg, seed=23)
    for _ in range(3):
        batch = loader.next_batch(0)
        s_a, m_a = plain(s_a, batch)
        s_b, m_b = sharded(s_b, batch)
        np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]),
                                   rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s_a.params),
                    jax.tree.leaves(s_b.params)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=1e-5, atol=1e-6)

"""Sharded server aggregation state: units + strategy/server integration.

Covers the pieces ``tests/test_agg_pallas.py``'s differential lane does
not: the ``shard_bounds`` partition contract, the stable base-memo token
(the ``id()``-reuse regression), the padded-accumulator geometry cache,
decode-pipeline failure semantics, quantized FedOpt moments, and the
``ServerConfig`` plumbing.  The shard-cpu CI lane re-runs this module
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import gc

import numpy as np
import pytest

from repro.fl import agg_kernels as K
from repro.fl.flat import (QCHUNK, FlatParams, QuantParams, layout_for,
                           memo_token, quantize_int8)
from repro.sharding import shard_bounds

from test_agg_pallas import assert_flat_ulp, make_payloads, ulp_diff

pytestmark = pytest.mark.shard


# ---------------------------------------------------------------------------
# shard_bounds: the partition contract
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("total,shards,align", [
    (0, 1, 1), (0, 4, 1024), (1, 1, 1), (1, 8, 1024),
    (1537, 8, 1024), (10_000, 3, 1024), (QCHUNK * 7, 8, QCHUNK),
    (50_000_000, 16, QCHUNK), (5, 5, 1), (1023, 2, 1024),
])
def test_shard_bounds_partition_contract(total, shards, align):
    bounds = shard_bounds(total, shards, align=align)
    assert len(bounds) == shards
    # contiguous, ordered, disjoint, covering exactly [0, total)
    cursor = 0
    for lo, hi in bounds:
        assert lo == cursor and hi >= lo
        cursor = hi
    assert cursor == total
    # every non-empty shard starts on an align boundary (so q8 scale
    # windows never straddle a shard edge; empty tail shards clamp to
    # ``total``) and no shard exceeds the balanced size
    per = -(-max(total, 1) // shards)
    per = -(-per // align) * align
    for lo, hi in bounds:
        if hi > lo:
            assert lo % align == 0
        assert hi - lo <= per


def test_shard_bounds_ragged_tail_leaves_empty_shards():
    # total < shards * align: early shards take align-sized ranges, the
    # rest are empty — callers must tolerate (lo == hi) shards
    bounds = shard_bounds(3, 8, align=1024)
    assert bounds[0] == (0, 3)
    assert all(lo == hi == 3 for lo, hi in bounds[1:])


def test_shard_bounds_rejects_bad_arguments():
    with pytest.raises(ValueError):
        shard_bounds(10, 0)
    with pytest.raises(ValueError):
        shard_bounds(10, -2)
    with pytest.raises(ValueError):
        shard_bounds(10, 2, align=0)


class _FakeMesh:
    """axis_names/devices duck type of jax.sharding.Mesh — enough for
    resolve_shards without forcing a multi-device jax runtime here."""

    def __init__(self, shape, names):
        self.devices = np.empty(shape, object)
        self.axis_names = names


def test_resolve_shards_precedence():
    assert K.resolve_shards(None) == 0
    assert K.resolve_shards(None, None) == 0
    assert K.resolve_shards(4) == 4
    with pytest.raises(ValueError):
        K.resolve_shards(-1)
    mesh = _FakeMesh((8,), ("data",))
    assert K.resolve_shards(None, mesh) == 8
    assert K.resolve_shards(2, mesh) == 2          # explicit count wins
    # "data" axis picked out of a 2-D mesh; no "data" -> all devices
    assert K.resolve_shards(None, _FakeMesh((2, 4), ("model", "data"))) == 4
    assert K.resolve_shards(None, _FakeMesh((2, 3), ("x", "y"))) == 6


def test_per_shard_memory_is_fraction_of_single_host():
    """The ISSUE acceptance bound, checked analytically: per-shard fp64
    footprint <= (1/N + 10%) of the single-host accumulator."""
    layout = layout_for([("float32", (1_000_000,))])
    single = K.StreamingWeightedSum(layout).per_shard_acc_bytes()
    assert single == layout.total_size * 8
    for shards in (2, 4, 8, 16):
        s = K.StreamingWeightedSum(layout, shards=shards)
        assert s.per_shard_acc_bytes() <= single * (1 / shards + 0.10)


# ---------------------------------------------------------------------------
# base memo: stable tokens vs id() reuse
# ---------------------------------------------------------------------------
def test_memo_token_stable_and_distinct():
    layout = layout_for([("float32", (8,))])
    a, b = FlatParams.zeros(layout), FlatParams.zeros(layout)
    assert memo_token(a) == memo_token(a)      # stable per object
    assert memo_token(a) != memo_token(b)      # distinct across objects


def test_memo_token_never_recycled_across_id_reuse():
    """Regression: the delta-base memo used to key on ``id(base)``.
    CPython recycles addresses as soon as an object dies, so a freed
    round base could alias a *new* base's cache entry and decode stale
    fp64 bytes.  Tokens must stay unique even when ids collide."""
    layout = layout_for([("float32", (QCHUNK,))])
    tokens, ids = [], []
    for i in range(64):
        q, s = quantize_int8(
            np.full(layout.total_size, float(i + 1), np.float32))
        base = QuantParams(layout, "q8", q, s)
        tokens.append(memo_token(base))
        ids.append(id(base))
        del base
        gc.collect()               # force the allocator to recycle
    assert len(set(tokens)) == len(tokens)
    if len(set(ids)) == len(ids):
        pytest.skip("allocator never recycled an id; collision not forced")


def test_base_memo_not_poisoned_by_id_reuse():
    """Functional form of the regression: three deltas against three
    *different* short-lived bases (freed between arrivals, so their ids
    can be recycled).  The memoizing Pallas fold must match the
    memo-free numpy fold bitwise — a stale memo hit decodes the wrong
    base and diverges wildly."""
    layout = layout_for([("float32", (2048,))])
    rng = np.random.default_rng(31)

    def delta_against_fresh_base(level):
        bq, bs = quantize_int8(
            np.full(layout.total_size, level, np.float32))
        base = QuantParams(layout, "q8", bq, bs)
        q, s = quantize_int8(
            rng.normal(0, 1e-3, layout.total_size).astype(np.float32))
        return QuantParams(layout, "q8", q, s, is_delta=True, base=base)

    s_pl = K.StreamingWeightedSum(layout, backend="pallas")
    s_np = K.StreamingWeightedSum(layout, backend="numpy")
    for i, level in enumerate((1.0, 2.0, 3.0)):
        fp = delta_against_fresh_base(level)
        s_pl.add(fp, 1.0 + i)
        s_np.add(fp, 1.0 + i)
        del fp                     # frees the base; id may be recycled
        gc.collect()
    assert_flat_ulp(s_pl.finalize(), s_np.finalize(), maxulp=0)


# ---------------------------------------------------------------------------
# padded-accumulator geometry cache (single-host Pallas mode)
# ---------------------------------------------------------------------------
def test_padded_acc_cached_across_homogeneous_arrivals():
    """A codec-homogeneous round keeps one padded device accumulator for
    every arrival (no per-arrival pad + slice + sync)."""
    layout, flats = make_payloads("big_unaligned", "q8", 4, seed=32)
    s = K.StreamingWeightedSum(layout, backend="pallas")
    geoms = set()
    for i, fp in enumerate(flats):
        s.add(fp, 2.0 + i)
        assert s._acc_padded is not None and s._acc is None
        geoms.add(s._pad_geom)
    assert len(geoms) == 1         # one geometry, cache never retired
    want = K.StreamingWeightedSum(layout, backend="numpy")
    for i, fp in enumerate(flats):
        want.add(fp, 2.0 + i)
    assert_flat_ulp(s.finalize(), want.finalize(), maxulp=0)


def test_padded_acc_retired_on_geometry_change():
    """block=1536: q8 rounds the block up to the 1024 scale window
    (-> 2048) while raw frames keep 1536, so interleaving the codecs
    forces the retire + re-pad fallback on every switch — which must
    stay invisible in the result."""
    layout, quants = make_payloads("big_unaligned", "q8", 2, seed=33)
    _, raws = make_payloads("big_unaligned", "flat", 1, seed=34)
    arrivals = [(quants[0], 2.0), (raws[0], 3.0), (quants[1], 4.0)]
    s = K.StreamingWeightedSum(layout, backend="pallas", block=1536)
    geoms = []
    for fp, w in arrivals:
        s.add(fp, w)
        geoms.append(s._pad_geom)
    assert geoms[0] != geoms[1]    # the mixed arrival changed geometry
    assert geoms[2] == geoms[0]
    want = K.StreamingWeightedSum(layout, backend="numpy")
    for fp, w in arrivals:
        want.add(fp, w)
    assert_flat_ulp(s.finalize(), want.finalize(), maxulp=0)


# ---------------------------------------------------------------------------
# decode pipeline: ring reuse + failure semantics
# ---------------------------------------------------------------------------
def test_pipeline_ring_reuse_many_arrivals_bitwise():
    """More arrivals than ring slots (12 > 3): slot recycling and the
    depth-1 job queue must preserve the serial fold order."""
    layout, flats = make_payloads("big_unaligned", "q8_delta_quant", 12,
                                  seed=35)
    on = K.StreamingWeightedSum(layout, backend="numpy", shards=4,
                                overlap=True)
    off = K.StreamingWeightedSum(layout, backend="numpy", shards=4,
                                 overlap=False)
    assert on.overlap and not off.overlap
    for i, fp in enumerate(flats):
        on.add(fp, 1.0 + i)
        off.add(fp, 1.0 + i)
    assert_flat_ulp(on.finalize(), off.finalize(), maxulp=0)


class _BoomPayload:
    is_delta = False

    def f64_chunk(self, lo, hi, out):
        raise RuntimeError("decode boom")


def test_pipeline_propagates_decoder_errors():
    """A decoder-thread exception must surface on the caller's thread
    (at add() or finalize(), whichever drains it first), and the failed
    pipeline must reject further work instead of folding silently."""
    layout = layout_for([("float32", (4096,))])
    s = K.StreamingWeightedSum(layout, backend="numpy", shards=2,
                               overlap=True)
    assert s.overlap
    with pytest.raises(RuntimeError):
        s.add(_BoomPayload(), 1.0)
        s.finalize()
    good = FlatParams.zeros(layout)
    with pytest.raises(RuntimeError):
        s.add(good, 1.0)
        s.finalize()


def test_sharded_delta_without_base_is_an_error():
    layout, flats = make_payloads("big_unaligned", "q8_delta_quant", 1,
                                  seed=36)
    orphan = QuantParams(layout, "q8", flats[0].data, flats[0].scales,
                         is_delta=True, base=None)
    s = K.StreamingWeightedSum(layout, backend="numpy", shards=2,
                               overlap=False)
    with pytest.raises(ValueError, match="base"):
        s.add(orphan, 1.0)


def test_sharded_empty_and_tiny_layouts():
    # empty model: all shards empty, finalize is a no-op frame
    empty = layout_for([])
    s = K.StreamingWeightedSum(empty, shards=4)
    s.add(FlatParams.zeros(empty), 1.0)
    assert s.finalize().layout.total_size == 0
    # model smaller than one align window: one real shard + empties
    tiny = layout_for([("float32", (3,))])
    fp = FlatParams.from_arrays(
        [np.array([1.0, -2.0, 3.5], np.float32)], tiny)
    s8 = K.StreamingWeightedSum(tiny, shards=8, overlap=False)
    s1 = K.StreamingWeightedSum(tiny, shards=1, overlap=False)
    s8.add(fp, 2.0)
    s1.add(fp, 2.0)
    assert_flat_ulp(s8.finalize(), s1.finalize(), maxulp=0)


# ---------------------------------------------------------------------------
# FedOpt sharded server state
# ---------------------------------------------------------------------------
def _run_rounds(strategy, shapes, rounds=3, clients=4, seed=36):
    from repro.fl.messages import FitRes

    rng = np.random.default_rng(seed)
    cur = [np.zeros(s, np.float32) for s in shapes]
    for rnd in range(1, rounds + 1):
        results = [
            (f"site-{c}", FitRes(
                [rng.normal(0, 1, s).astype(np.float32) for s in shapes],
                10 + c, {}))
            for c in range(clients)]
        cur, _ = strategy.aggregate_fit(rnd, results, [], cur)
    return cur


def test_quantized_moments_storage_and_tolerance():
    """quantize_moments stores each shard's m/v as int8 + per-QCHUNK
    scales (~1/8 the fp64 bytes).  The lossiness is documented and
    denominator-shaped: coordinates whose true ``v`` is tiny relative to
    their scale chunk's max see a coarse ``sqrt(v) + tau`` and drift the
    most, so the contract is bulk closeness, not elementwise equality."""
    from repro.fl.strategy import FedAdam

    shapes = [(4096,), (515,)]
    n = sum(int(np.prod(s)) for s in shapes)
    exact = _run_rounds(FedAdam(shards=2), shapes)
    quant_strat = FedAdam(shards=2, quantize_moments=True)
    quant = _run_rounds(quant_strat, shapes)
    state_bytes = 0
    for st in quant_strat._shard_mv:
        for mom in st:
            assert isinstance(mom, tuple) and mom[0].dtype == np.int8
            state_bytes += mom[0].nbytes + mom[1].nbytes
    assert state_bytes <= 0.25 * (2 * n * 8)   # ~1/8 of fp64 m+v
    err = np.abs(np.concatenate([q.ravel() - e.ravel()
                                 for q, e in zip(quant, exact)]))
    assert np.mean(err > 0.05) < 0.03          # >=97% of coords close
    assert np.median(err) < 5e-3               # the bulk is tight


def test_fedavgm_sharded_velocity_state_shape():
    from repro.fl.strategy import FedAvgM

    strat = FedAvgM(shards=3)
    shapes = [(1031,), (7,)]
    _run_rounds(strat, shapes, rounds=2)
    total = sum(int(np.prod(s)) for s in shapes)
    bounds = shard_bounds(total, 3, align=QCHUNK)
    assert [v.size for v in strat._shard_vel] \
        == [hi - lo for lo, hi in bounds]


# ---------------------------------------------------------------------------
# server / strategy plumbing
# ---------------------------------------------------------------------------
def test_server_config_threads_shards_to_strategy():
    from repro.fl.server import ServerApp, ServerConfig
    from repro.fl.strategy import FedAvg

    strat = FedAvg()
    assert strat.shards is None
    ServerApp(ServerConfig(num_rounds=1, agg_shards=4), strat)
    assert strat.shards == 4
    mesh = _FakeMesh((8,), ("data",))
    strat_m = FedAvg()
    ServerApp(ServerConfig(num_rounds=1, shard_mesh=mesh), strat_m)
    assert strat_m.shard_mesh is mesh
    # explicit strategy choice survives when the config does not override
    strat2 = FedAvg(shards=2)
    ServerApp(ServerConfig(num_rounds=1), strat2)
    assert strat2.shards == 2


def test_fedavg_end_to_end_sharded_matches_streaming():
    """aggregate_fit with shards=2 vs the single-host streaming fold:
    bitwise (non-delta payloads); vs the deferred batch kernel: <=1 ULP
    (the documented streaming-vs-deferred difference, not sharding's)."""
    from repro.fl.strategy import FedAvg

    shapes = [(33, 5), (2049,)]
    sharded = _run_rounds(FedAvg(shards=2), shapes)
    streaming = _run_rounds(FedAvg(low_memory=True), shapes)
    deferred = _run_rounds(FedAvg(), shapes)
    for g, w in zip(sharded, streaming):
        np.testing.assert_array_equal(g, w)
    for g, w in zip(sharded, deferred):
        assert ulp_diff(g, w) <= 1

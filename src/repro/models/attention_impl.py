"""Attention contraction implementations.

``impl="xla"`` — einsum + masked softmax; lowers on every backend and is
what the 512-device dry-run compiles.  ``impl="pallas"`` — the flash
attention TPU kernel from ``repro.kernels`` (interpret-mode on CPU).
Both satisfy the same contract and are cross-checked in tests.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _expand_kv(k, heads_per_kv: int):
    if heads_per_kv == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.repeat(k, heads_per_kv, axis=2)


def _chunk_size(S: int, target: int = 1024) -> int:
    for c in range(min(target, S), 0, -1):
        if S % c == 0:
            return c
    return S


def causal_attention(q, k, v, *, window: int = 0, impl: str = "xla",
                     causal: bool = True, chunk: int = 1024):
    """q: (B,S,H,hd) (pre-scaled), k/v: (B,S,KV,hd); returns (B,S,H,hd_v).

    The XLA path processes queries in chunks (lax.scan) so the score matrix
    materializes as (B,KV,g,chunk,S) instead of (B,KV,g,S,S) — the pure-XLA
    stand-in for flash attention (the Pallas kernel is the TPU fast path).
    """
    if impl == "pallas":
        from repro.kernels import ops

        return ops.flash_attention(q, k, v, causal=causal, window=window,
                                   interpret=True)
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    from repro.sharding import constrain_scores, model_axis_size

    # GQA -> MHA expansion when the q-head count shards over "model" but
    # the kv-head count does not: each model shard then owns its heads'
    # scores with zero attention collectives, at the cost of replicating
    # the small (B,S,KV,hd) K/V (§Perf iteration C-1'')
    msz = model_axis_size()
    if msz > 1 and g > 1 and H % msz == 0 and KV % msz != 0:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        KV, g = H, 1
    qg = q.reshape(B, S, KV, g, hd)
    C = _chunk_size(S, chunk)

    def one_chunk(start, q_chunk):
        scores = jnp.einsum("bqkgh,bskh->bkgqs", q_chunk, k,
                            preferred_element_type=jnp.float32)
        # Sk stays model-sharded: local partial QK^T + tiny softmax
        # reductions, no K/V gather and no replicated score matrix
        scores = constrain_scores(scores)
        kpos = jnp.arange(S)[None, :]
        qpos = start + jnp.arange(C)[:, None]
        mask = jnp.ones((C, S), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        scores = constrain_scores(scores)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bkgqs,bskh->bqkgh", w, v)

    if C == S:
        ctx = one_chunk(0, qg)
    else:
        n = S // C
        qs = jnp.moveaxis(qg.reshape(B, n, C, KV, g, hd), 1, 0)

        # checkpoint the chunk body: otherwise scan's backward stacks every
        # chunk's fp32 scores/softmax weights (flash attention recomputes
        # them per block; this is the XLA equivalent)
        chunk_fn = jax.checkpoint(one_chunk)

        def body(_, xs):
            i, qc = xs
            return (), chunk_fn(i * C, qc)

        _, ctx = jax.lax.scan(body, (), (jnp.arange(n), qs))
        ctx = jnp.moveaxis(ctx, 0, 1).reshape(B, S, KV, g, v.shape[-1])
    return ctx.reshape(B, S, H, v.shape[-1])


def decode_attention(q, k_cache, v_cache, *, slot_pos, query_pos, window: int = 0):
    """One-token attention against a (possibly rotating) cache.

    q: (B,1,H,hd) pre-scaled; k/v_cache: (B,S,KV,hd); slot_pos: (B,S) absolute
    position held in each slot (-1 = empty); query_pos: (B,).
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    g = H // KV
    qg = q[:, 0].reshape(B, KV, g, hd)
    # preferred_element_type (NOT a post-cast): an explicit convert of the
    # cache gets hoisted out of the layer scan by XLA, materializing a full
    # f32 cache copy (observed +8.6GB/device)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    valid = (slot_pos >= 0) & (slot_pos <= query_pos[:, None])
    if window:
        valid &= slot_pos > (query_pos[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgs,bskh->bkgh", w, v_cache)
    return ctx.reshape(B, 1, H, v_cache.shape[-1])

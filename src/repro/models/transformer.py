"""Decoder-only transformer trunk.

Composes the block library (attention / MLA / MoE / RG-LRU / mLSTM / sLSTM)
according to ``cfg.layer_kinds``:

- layers are grouped into repeating *periods* (RecurrentGemma: (rglru,
  rglru, local); xLSTM: 7×mlstm+1×slstm; dense models: period 1) and the
  repeated periods are executed with ``jax.lax.scan`` over **stacked**
  params — one compiled layer body regardless of depth, which keeps the
  88-layer dry-runs compact;
- ``moe.first_dense_layers`` leading layers (DeepSeek-V2) and any trailing
  remainder (26 = 8×3 + 2) run unstacked;
- ``cfg.remat`` wraps the scan body in ``jax.checkpoint`` for training.

Caches follow the same grouping: {"pos", "pre": (...), "scan": (stacked,)*P,
"rem": (...)}.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import (ATTENTION_KINDS, ATTN, LOCAL_ATTN, MLA, MLSTM,
                          RGLRU, SLSTM, SWA, ModelConfig)
from repro.models import attention, mlp, recurrent
from repro.models.base import (ParamSpec, apply_norm, norm_spec)
from repro.sharding import cast_weight, constrain_batch, constrain_logits


def _needs_mlp(cfg: ModelConfig, kind: str) -> bool:
    if kind in (MLSTM, SLSTM):
        return False          # xLSTM blocks are self-contained
    return cfg.d_ff > 0 or cfg.moe.enabled


def _kind_specs(cfg: ModelConfig, kind: str):
    if kind == MLA:
        return attention.mla_specs(cfg)
    if kind in (ATTN, SWA, LOCAL_ATTN):
        return attention.specs(cfg)
    if kind == RGLRU:
        return recurrent.rglru_specs(cfg)
    if kind == MLSTM:
        return recurrent.mlstm_specs(cfg)
    if kind == SLSTM:
        return recurrent.slstm_specs(cfg)
    raise ValueError(kind)


def layer_specs(cfg: ModelConfig, kind: str, moe_layer: bool) -> Dict:
    sp: Dict[str, Any] = {
        "norm1": norm_spec(cfg, cfg.d_model),
        "mix": _kind_specs(cfg, kind),
    }
    if _needs_mlp(cfg, kind):
        sp["norm2"] = norm_spec(cfg, cfg.d_model)
        sp["mlp"] = mlp.moe_specs(cfg) if moe_layer else mlp.specs(cfg)
    return sp


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> Dict:
    if kind in ATTENTION_KINDS:
        return attention.init_cache(cfg, batch, max_len, kind)
    if kind == RGLRU:
        return recurrent.rglru_init_cache(cfg, batch)
    if kind == MLSTM:
        return recurrent.mlstm_init_cache(cfg, batch)
    if kind == SLSTM:
        return recurrent.slstm_init_cache(cfg, batch)
    raise ValueError(kind)


def apply_layer(params, x, cfg: ModelConfig, kind: str, moe_layer: bool, *,
                mode: str, positions, cache, impl: str = "xla",
                max_len=None):
    """One residual block. Returns (x, aux_loss_delta, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(params["norm1"], x, cfg)
    if kind in ATTENTION_KINDS:
        y, new_cache = attention.apply(params["mix"], h, cfg, mode=mode,
                                       positions=positions, cache=cache,
                                       kind=kind, impl=impl, max_len=max_len)
    elif kind == RGLRU:
        y, new_cache = recurrent.rglru_apply(params["mix"], h, cfg, mode=mode,
                                             cache=cache)
    elif kind == MLSTM:
        y, new_cache = recurrent.mlstm_apply(params["mix"], h, cfg, mode=mode,
                                             cache=cache)
    elif kind == SLSTM:
        y, new_cache = recurrent.slstm_apply(params["mix"], h, cfg, mode=mode,
                                             cache=cache)
    else:
        raise ValueError(kind)
    x = constrain_batch(x + y)
    if "mlp" in params:
        h = apply_norm(params["norm2"], x, cfg)
        if moe_layer:
            y, metrics = mlp.moe_apply(params["mlp"], h, cfg)
            aux = aux + metrics["moe_aux_loss"] + metrics["moe_z_loss"]
        else:
            y = mlp.apply(params["mlp"], h, cfg)
        x = constrain_batch(x + y)
    return x, aux, (new_cache if new_cache is not None else {})


# ---------------------------------------------------------------------------
# layer grouping
# ---------------------------------------------------------------------------
def _grouping(cfg: ModelConfig):
    kinds = cfg.layer_kinds
    n_pre = cfg.moe.first_dense_layers if cfg.moe.enabled else 0
    body = kinds[n_pre:]
    pat = cfg.block_pattern or (kinds[n_pre] if body else ATTN,)
    if isinstance(pat, str):
        pat = (pat,)
    P = len(pat)
    n_periods = len(body) // P
    n_rem = len(body) - n_periods * P
    return n_pre, P, n_periods, n_rem, kinds


def _is_moe_layer(cfg: ModelConfig, layer_idx: int) -> bool:
    return cfg.moe.enabled and layer_idx >= cfg.moe.first_dense_layers


def param_specs(cfg: ModelConfig) -> Dict:
    n_pre, P, n_periods, n_rem, kinds = _grouping(cfg)
    d, V = cfg.d_model, cfg.padded_vocab_size
    sp: Dict[str, Any] = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), "normal", scale=0.02),
        "final_norm": norm_spec(cfg, d),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec((d, V), ("embed", "vocab"))
    # pre (unstacked) layers — dense-MLP even in MoE models
    for i in range(n_pre):
        sp[f"pre_{i}"] = layer_specs(cfg, kinds[i], moe_layer=False)
    # scanned periods: stack each period-position's specs over n_periods
    for p in range(P):
        kind = kinds[n_pre + p]
        base = layer_specs(cfg, kind, _is_moe_layer(cfg, n_pre + p))
        sp[f"scan_{p}"] = jax.tree.map(
            lambda s: ParamSpec((n_periods,) + s.shape, ("stack",) + s.axes,
                                s.init, s.scale, s.dtype),
            base, is_leaf=lambda x: isinstance(x, ParamSpec))
    # remainder layers
    for r in range(n_rem):
        li = n_pre + n_periods * P + r
        sp[f"rem_{r}"] = layer_specs(cfg, kinds[li], _is_moe_layer(cfg, li))
    return sp


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    n_pre, P, n_periods, n_rem, kinds = _grouping(cfg)
    cache: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    for i in range(n_pre):
        cache[f"pre_{i}"] = _layer_cache(cfg, kinds[i], batch, max_len)
    for p in range(P):
        one = _layer_cache(cfg, kinds[n_pre + p], batch, max_len)
        cache[f"scan_{p}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), one)
    for r in range(n_rem):
        li = n_pre + n_periods * P + r
        cache[f"rem_{r}"] = _layer_cache(cfg, kinds[li], batch, max_len)
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens, *, mode: str,
            cache: Optional[Dict] = None, extra_embeds=None,
            impl: str = "xla", prefill_max_len: Optional[int] = None,
            last_logit_only: bool = False,
            ) -> Tuple[jnp.ndarray, Optional[Dict], Dict]:
    """Returns (logits, new_cache, metrics).

    tokens: (B, S) int32 (S == 1 in decode mode).
    extra_embeds: (B, N, d) prepended modality embeddings (VLM stub).
    """
    n_pre, P, n_periods, n_rem, kinds = _grouping(cfg)
    B, S = tokens.shape
    x = constrain_batch(params["embed"].astype(cfg.compute_dtype)[tokens])
    n_prefix = 0
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        n_prefix = extra_embeds.shape[1]
        S = S + n_prefix

    if mode == "decode":
        assert cache is not None and S == 1
        positions = cache["pos"][:, None]                 # (B,1)
    else:
        positions = jnp.arange(S)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    # --- pre layers --------------------------------------------------------
    for i in range(n_pre):
        x, aux, nc = apply_layer(params[f"pre_{i}"], x, cfg, kinds[i], False,
                                 mode=mode, positions=positions,
                                 cache=None if cache is None else cache[f"pre_{i}"],
                                 impl=impl, max_len=prefill_max_len)
        aux_total += aux
        new_cache[f"pre_{i}"] = nc

    # --- scanned periods -----------------------------------------------------
    if n_periods > 0:
        scan_params = tuple(params[f"scan_{p}"] for p in range(P))
        scan_caches = tuple(
            (cache[f"scan_{p}"] if cache is not None else {}) for p in range(P))
        period_kinds = tuple(kinds[n_pre + p] for p in range(P))
        period_moe = tuple(_is_moe_layer(cfg, n_pre + p) for p in range(P))

        def body(carry, xs):
            xc, auxc = carry
            pslices, cslices = xs
            ncs = []
            for p in range(P):
                xc, aux, nc = apply_layer(pslices[p], xc, cfg, period_kinds[p],
                                          period_moe[p], mode=mode,
                                          positions=positions,
                                          cache=cslices[p] or None, impl=impl,
                                          max_len=prefill_max_len)
                auxc = auxc + aux
                ncs.append(nc)
            return (xc, auxc), tuple(ncs)

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)
        (x, aux_total), scan_new = jax.lax.scan(
            body, (x, aux_total), (scan_params, scan_caches))
        for p in range(P):
            new_cache[f"scan_{p}"] = scan_new[p]

    # --- remainder layers -----------------------------------------------------
    for r in range(n_rem):
        li = n_pre + n_periods * P + r
        x, aux, nc = apply_layer(params[f"rem_{r}"], x, cfg, kinds[li],
                                 _is_moe_layer(cfg, li), mode=mode,
                                 positions=positions,
                                 cache=None if cache is None else cache[f"rem_{r}"],
                                 impl=impl, max_len=prefill_max_len)
        aux_total += aux
        new_cache[f"rem_{r}"] = nc

    x = apply_norm(params["final_norm"], x, cfg)
    if n_prefix and mode != "decode":
        x = x[:, n_prefix:]
    if last_logit_only:
        x = x[:, -1:]
    if cfg.tie_embeddings:
        head = cast_weight(params["embed"], x.dtype, ("vocab", "embed")).T
    else:
        head = cast_weight(params["lm_head"], x.dtype, ("embed", "vocab"))
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain_logits(logits.astype(jnp.float32))
    if cfg.logits_softcap:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    if cfg.padded_vocab_size != cfg.vocab_size:
        # mask pad columns exactly (shard-friendly elementwise iota compare)
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)

    metrics = {"aux_loss": aux_total}
    if mode == "train":
        return logits, None, metrics
    if mode in ("prefill", "decode"):
        new_cache["pos"] = (jnp.full((B,), S, jnp.int32) if mode == "prefill"
                            else cache["pos"] + 1)
        return logits, new_cache, metrics
    raise ValueError(mode)

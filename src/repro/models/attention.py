"""Attention blocks: full / GQA / MQA / sliding-window / local, and
DeepSeek-V2 Multi-head Latent Attention (MLA).

Each block exposes
  specs(cfg)                         -> ParamSpec tree (one layer)
  init_cache(cfg, batch, max_len)    -> decode cache (one layer)
  apply(params, x, cfg, *, mode, positions, cache, layer_kind)
        -> (y, new_cache)

``mode`` is "train" | "prefill" | "decode".  In decode mode x is (B, 1, d)
and the cache advances by one position.  Sliding-window kinds keep a
rotating cache of ``window`` slots.

The score/softmax/value contraction is routed through
``repro.models.attention_impl`` so the XLA path (used by the 512-device
dry-run; CPU-lowerable) and the Pallas flash kernel path (TPU target,
validated in interpret mode) are interchangeable.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ATTN, LOCAL_ATTN, MLA, SWA, ModelConfig
from repro.models import attention_impl
from repro.models.base import ParamSpec, apply_rope, norm_spec, apply_norm
from repro.sharding import cast_weight, constrain_heads


# ---------------------------------------------------------------------------
# Standard (GQA) attention
# ---------------------------------------------------------------------------
def specs(cfg: ModelConfig) -> Dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    out = {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        out["q_norm"] = {"scale": ParamSpec((hd,), ("head_dim",), "zeros")}
        out["k_norm"] = {"scale": ParamSpec((hd,), ("head_dim",), "zeros")}
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str) -> Dict:
    if kind == MLA:
        return mla_init_cache(cfg, batch, max_len)
    if kind in (SWA, LOCAL_ATTN) and cfg.window:
        max_len = min(max_len, cfg.window)
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = cfg.compute_dtype
    return {
        "k": jnp.zeros((batch, max_len, KV, hd), dt),
        "v": jnp.zeros((batch, max_len, KV, hd), dt),
        # absolute position stored per slot (rotating caches need it for
        # masking + rope); -1 marks an empty slot.
        "slot_pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def _qk_normalize(params, q, k, cfg):
    if not cfg.qk_norm:
        return q, k

    def _rms(x, scale):
        x32 = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        return (x32 * jax.lax.rsqrt(var + 1e-6)
                * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)

    return _rms(q, params["q_norm"]["scale"]), _rms(k, params["k_norm"]["scale"])


def apply(params, x, cfg: ModelConfig, *, mode: str, positions,
          cache: Optional[Dict] = None, kind: str = ATTN,
          impl: str = "xla", max_len: Optional[int] = None,
          ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    if kind == MLA:
        return mla_apply(params, x, cfg, mode=mode, positions=positions,
                         cache=cache, max_len=max_len)
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    window = cfg.window if kind in (SWA, LOCAL_ATTN) else 0

    q = jnp.einsum("bsd,dnh->bsnh", x,
                   cast_weight(params["wq"], x.dtype,
                               ("embed", "heads", "head_dim")))
    k = jnp.einsum("bsd,dnh->bsnh", x,
                   cast_weight(params["wk"], x.dtype,
                               ("embed", "kv_heads", "head_dim")))
    v = jnp.einsum("bsd,dnh->bsnh", x,
                   cast_weight(params["wv"], x.dtype,
                               ("embed", "kv_heads", "head_dim")))
    q, k = _qk_normalize(params, q, k, cfg)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = q / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)

    if mode in ("train", "prefill"):
        ctx = attention_impl.causal_attention(q, k, v, window=window, impl=impl)
        new_cache = None
        if mode == "prefill":
            new_cache = _fill_cache_from_prefill(cfg, k, v, positions, kind,
                                                 max_len=max_len)
    else:  # decode: S == 1
        assert cache is not None
        cache_len = cache["k"].shape[1]
        pos = positions[:, 0] if positions.ndim == 2 else positions  # (B,)
        slot = jnp.mod(pos, cache_len) if window else jnp.minimum(pos, cache_len - 1)
        bidx = jnp.arange(B)
        new_k = cache["k"].at[bidx, slot].set(k[:, 0])
        new_v = cache["v"].at[bidx, slot].set(v[:, 0])
        new_sp = cache["slot_pos"].at[bidx, slot].set(pos)
        ctx = attention_impl.decode_attention(
            q, new_k, new_v, slot_pos=new_sp, query_pos=pos, window=window)
        new_cache = {"k": new_k, "v": new_v, "slot_pos": new_sp}

    y = jnp.einsum("bsnh,nhd->bsd", ctx,
                   cast_weight(params["wo"], x.dtype,
                               ("heads", "head_dim", "embed")))
    return y, new_cache


def _fill_cache_from_prefill(cfg, k, v, positions, kind,
                             max_len: Optional[int] = None) -> Dict:
    """Build a decode-ready cache from prefill K/V (last `window` if SWA).

    If ``max_len`` exceeds the prefill length the cache is padded with empty
    (slot_pos = -1) slots so decode can append new tokens."""
    B, S = k.shape[0], k.shape[1]
    window = cfg.window if kind in (SWA, LOCAL_ATTN) and cfg.window else 0
    pos = jnp.broadcast_to(positions, (B, S)) if positions.ndim == 1 else positions
    if window and S > window:
        # rotating cache: position p lives in slot p % window; the last
        # `window` tokens occupy exactly the full cache.
        k, v, pos = k[:, -window:], v[:, -window:], pos[:, -window:]
        S = window
        slots = jnp.mod(pos[0], window)
        order = jnp.argsort(slots)
        k, v, pos = k[:, order], v[:, order], pos[:, order]
    pos = pos.astype(jnp.int32)
    target = min(max_len, cfg.window) if (max_len and window) else max_len
    if target and target > S:
        pad = target - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    return {"k": k, "v": v, "slot_pos": pos}


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434)
# ---------------------------------------------------------------------------
# Projections:
#   c_q   = W_DQ x                (q_lora_rank)
#   q     = W_UQ c_q              -> per head [q_nope (nope_dim), q_pe (rope_dim)]
#   c_kv  = W_DKV x               (kv_lora_rank)      <- THE cached latent
#   k_pe  = W_KR x                (rope_dim, shared across heads, rope'd)
#   k     = [W_UK c_kv, k_pe] ; v = W_UV c_kv
# Decode uses the absorbed form: score_h = q_nope_h^T W_UK_h c + q_pe_h^T k_pe
# so only (c_kv, k_pe) is cached — the paper's 93%-smaller KV cache.
def mla_specs(cfg: ModelConfig) -> Dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    nope, rope_d, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    return {
        "wdq": ParamSpec((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": norm_spec(cfg, m.q_lora_rank),
        "wuq": ParamSpec((m.q_lora_rank, H, nope + rope_d),
                         ("lora", "heads", "head_dim")),
        "wdkv": ParamSpec((d, m.kv_lora_rank), ("embed", "lora")),
        "kv_norm": norm_spec(cfg, m.kv_lora_rank),
        "wuk": ParamSpec((m.kv_lora_rank, H, nope), ("lora", "heads", "head_dim")),
        "wuv": ParamSpec((m.kv_lora_rank, H, vdim), ("lora", "heads", "head_dim")),
        "wkr": ParamSpec((d, rope_d), ("embed", "head_dim")),
        "wo": ParamSpec((H, vdim, d), ("heads", "head_dim", "embed")),
    }


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    m, dt = cfg.mla, cfg.compute_dtype
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "kpe": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt),
        "slot_pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_apply(params, x, cfg: ModelConfig, *, mode: str, positions,
              cache: Optional[Dict] = None, max_len: Optional[int] = None):
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.num_heads
    nope, rope_d = m.qk_nope_head_dim, m.qk_rope_head_dim
    scale = 1.0 / jnp.sqrt(jnp.asarray(nope + rope_d, jnp.float32)).astype(x.dtype)

    cq = jnp.einsum("bsd,dr->bsr", x, params["wdq"].astype(x.dtype))
    cq = apply_norm(params["q_norm"], cq, cfg)
    q = jnp.einsum("bsr,rnh->bsnh", cq, params["wuq"].astype(x.dtype))
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["wdkv"].astype(x.dtype))
    ckv = apply_norm(params["kv_norm"], ckv, cfg)
    kpe = jnp.einsum("bsd,dr->bsr", x, params["wkr"].astype(x.dtype))
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if mode in ("train", "prefill"):
        # naive (non-absorbed) form: expand k, v per head — best for FLOPs
        # utilization during training where S is large.
        k_nope = jnp.einsum("bsr,rnh->bsnh", ckv, params["wuk"].astype(x.dtype))
        v = jnp.einsum("bsr,rnh->bsnh", ckv, params["wuv"].astype(x.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe[:, :, None, :], (B, S, H, rope_d))],
            axis=-1)
        qq = jnp.concatenate([q_nope, q_pe], axis=-1) * scale
        # re-pin head sharding: the k_pe broadcast + concat otherwise lets
        # GSPMD replicate heads (observed 8.6GB f32 score buffers x many)
        qq = constrain_heads(qq)
        k = constrain_heads(k)
        v = constrain_heads(v)
        ctx = attention_impl.causal_attention(qq, k, v, window=0, impl="xla")
        new_cache = None
        if mode == "prefill":
            pos = jnp.broadcast_to(positions, (B, S)) if positions.ndim == 1 else positions
            pos = pos.astype(jnp.int32)
            ckv_c, kpe_c = ckv, kpe
            if max_len and max_len > S:
                pad = max_len - S
                ckv_c = jnp.pad(ckv_c, ((0, 0), (0, pad), (0, 0)))
                kpe_c = jnp.pad(kpe_c, ((0, 0), (0, pad), (0, 0)))
                pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
            new_cache = {"ckv": ckv_c, "kpe": kpe_c, "slot_pos": pos}
    else:
        assert cache is not None
        pos = positions[:, 0] if positions.ndim == 2 else positions
        cache_len = cache["ckv"].shape[1]
        slot = jnp.minimum(pos, cache_len - 1)
        bidx = jnp.arange(B)
        ckv_c = cache["ckv"].at[bidx, slot].set(ckv[:, 0])
        kpe_c = cache["kpe"].at[bidx, slot].set(kpe[:, 0])
        sp = cache["slot_pos"].at[bidx, slot].set(pos)
        # absorbed decode: q'_h = W_UK_h^T q_nope_h  (B,H,rank)
        q_abs = jnp.einsum("bnh,rnh->bnr", q_nope[:, 0], params["wuk"].astype(x.dtype))
        scores = (jnp.einsum("bnr,bsr->bns", q_abs, ckv_c,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bnh,bsh->bns", q_pe[:, 0], kpe_c,
                               preferred_element_type=jnp.float32)) \
            * jnp.float32(scale)
        mask = (sp >= 0) & (sp <= pos[:, None])          # (B, S)
        scores = jnp.where(mask[:, None, :], scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bns,bsr->bnr", w, ckv_c)   # attend in latent space
        ctx = jnp.einsum("bnr,rnh->bnh", ctx_lat, params["wuv"].astype(x.dtype))
        ctx = ctx[:, None]                                # (B,1,H,vdim)
        new_cache = {"ckv": ckv_c, "kpe": kpe_c, "slot_pos": sp}

    y = jnp.einsum("bsnh,nhd->bsd", ctx, params["wo"].astype(x.dtype))
    return y, new_cache

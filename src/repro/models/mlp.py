"""MLP blocks: gated (SwiGLU / GeGLU) dense MLP and token-choice top-k MoE.

MoE baseline = GShard-style dense dispatch: tokens split into G groups with
per-group capacity C = Tg*k/E*cf; dispatch/combine are one-hot einsums over
(G, Tg, E, C) masks, experts sharded over the "model" mesh axis (expert
parallelism), groups over the batch axes.  Overflow beyond C is dropped
(standard GShard/Switch semantics).  The cross-shard reduction of the
combine einsum is the MoE traffic the paper's workloads put on the wire;
§Perf hillclimbs it.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.base import ParamSpec, activation
from repro.sharding import cast_weight


# ---------------------------------------------------------------------------
# Dense gated MLP
# ---------------------------------------------------------------------------
def specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    out = {
        "wi_up": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }
    if cfg.gated_mlp:
        out["wi_gate"] = ParamSpec((d, f), ("embed", "mlp"))
    return out


def apply(params, x, cfg: ModelConfig):
    act = activation(cfg.act)
    wi_up = cast_weight(params["wi_up"], x.dtype, ("embed", "mlp"))
    wo = cast_weight(params["wo"], x.dtype, ("mlp", "embed"))
    u = jnp.einsum("bsd,df->bsf", x, wi_up)
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x,
                       cast_weight(params["wi_gate"], x.dtype, ("embed", "mlp")))
        h = act(g) * u
    else:
        h = act(u)
    return jnp.einsum("bsf,fd->bsd", h, wo)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------
def moe_specs(cfg: ModelConfig) -> Dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.num_experts
    out = {
        "router": ParamSpec((d, E), ("embed", "experts"), "normal", scale=0.02),
        "wi_gate": ParamSpec((E, d, f), ("experts", "embed_expert", "mlp")),
        "wi_up": ParamSpec((E, d, f), ("experts", "embed_expert", "mlp")),
        "wo": ParamSpec((E, f, d), ("experts", "mlp", "embed_expert")),
    }
    if m.num_shared_experts:
        out["shared"] = specs(cfg, d_ff=m.d_ff * m.num_shared_experts)
    return out


def router_probs(params, x, cfg) -> jnp.ndarray:
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1), logits


def _num_groups(T: int, E: int, k: int, cf: float) -> int:
    """Largest group count such that per-group capacity stays >= ~16
    (statistical load-balance) and groups divide the token count."""
    min_tg = max(int(16 * E / max(k * max(cf, 1.0), 1.0)), 1)
    g_max = min(max(T // min_tg, 1), 4096)
    for g in range(g_max, 0, -1):
        if T % g == 0:
            return g
    return 1


def moe_apply(params, x, cfg: ModelConfig,
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """GShard-style dense dispatch (groups x capacity), returns (y, metrics).

    Tokens are split into G groups (sharded over the batch axes); each group
    has local capacity C = Tg*k/E*cf.  Dispatch/combine are one-hot einsums,
    so every intermediate is a well-shaped dense tensor GSPMD can shard:
    group dim -> ("pod","data"), expert dim -> "model".  The sort/scatter
    formulation this replaces forced a replicated (T*k, d) gather (observed
    +128GB/device on DeepSeek-V2).  Dispatch-einsum FLOPs overhead is
    ~T*d*E*C — 5-15%% of expert FLOPs at these shapes; §Perf targets it.
    """
    from repro.sharding import constrain_moe

    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.experts_per_token
    T = B * S
    cf = m.capacity_factor if m.capacity_factor > 0 else 1.25
    G = _num_groups(T, E, k, cf)
    Tg = T // G
    C = max(int(Tg * k / E * cf), 1)

    probs, logits = router_probs(params, x, cfg)   # (B,S,E) fp32
    probs_g = probs.reshape(G, Tg, E)
    gate_vals, expert_idx = jax.lax.top_k(probs_g, k)        # (G,Tg,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch-style load balance + router z-loss) ----------
    density = jnp.mean(probs_g.reshape(T, E), axis=0)
    usage_oh = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (G,Tg,k,E)
    usage = jnp.mean(usage_oh.sum(2).reshape(T, E), axis=0)
    aux_loss = E * jnp.sum(density * usage) * m.router_aux_loss
    z_loss = m.router_z_loss * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits.reshape(T, E), axis=-1)))

    # ---- per-group positions + dispatch/combine masks ---------------------
    dtype = x.dtype
    counts = jnp.zeros((G, 1, E), jnp.float32)
    dispatch = jnp.zeros((G, Tg, E, C), dtype)
    combine = jnp.zeros((G, Tg, E, C), dtype)
    kept = jnp.zeros((), jnp.float32)
    for r in range(k):
        mr = jax.nn.one_hot(expert_idx[..., r], E, dtype=jnp.float32)  # (G,Tg,E)
        pos = jnp.cumsum(mr, axis=1) - mr + counts                      # (G,Tg,E)
        p = jnp.sum(pos * mr, axis=-1)                                  # (G,Tg)
        keep = (p < C) & (mr.sum(-1) > 0)
        cpos = jax.nn.one_hot(p, C, dtype=jnp.float32)                  # (G,Tg,C)
        dr = (mr[..., None] * cpos[:, :, None, :]
              * keep[..., None, None]).astype(dtype)
        dispatch = dispatch + dr
        combine = combine + gate_vals[..., r][..., None, None].astype(dtype) * dr
        counts = counts + mr.sum(axis=1, keepdims=True)
        kept = kept + jnp.mean(keep.astype(jnp.float32))

    xg = constrain_moe(x.reshape(G, Tg, d))
    dispatch = constrain_moe(dispatch, expert_dim=2)
    combine = constrain_moe(combine, expert_dim=2)

    # ---- dispatch -> expert FFN -> combine ---------------------------------
    dispatched = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    dispatched = constrain_moe(dispatched, expert_dim=1)
    act = activation(cfg.act)
    eaxes = ("experts", "embed_expert", "mlp")
    u = jnp.einsum("gecd,edf->gecf", dispatched,
                   cast_weight(params["wi_up"], dtype, eaxes))
    g_ = jnp.einsum("gecd,edf->gecf", dispatched,
                    cast_weight(params["wi_gate"], dtype, eaxes))
    h = act(g_) * u
    expert_out = jnp.einsum("gecf,efd->gecd", h,
                            cast_weight(params["wo"], dtype,
                                        ("experts", "mlp", "embed_expert")))
    expert_out = constrain_moe(expert_out, expert_dim=1)
    y = jnp.einsum("gsec,gecd->gsd", combine, expert_out)
    y = constrain_moe(y).reshape(B, S, d)

    if m.num_shared_experts:
        y = y + _shared_apply(params["shared"], x, cfg)

    metrics = {"moe_aux_loss": aux_loss, "moe_z_loss": z_loss,
               "moe_dropped_frac": 1.0 - kept / k}
    return y, metrics


def _shared_apply(params, x, cfg):
    act = activation(cfg.act)
    g = jnp.einsum("bsd,df->bsf", x,
                   cast_weight(params["wi_gate"], x.dtype, ("embed", "mlp")))
    u = jnp.einsum("bsd,df->bsf", x,
                   cast_weight(params["wi_up"], x.dtype, ("embed", "mlp")))
    return jnp.einsum("bsf,fd->bsd", act(g) * u,
                      cast_weight(params["wo"], x.dtype, ("mlp", "embed")))

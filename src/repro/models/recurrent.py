"""Recurrent blocks: Griffin/RecurrentGemma RG-LRU, xLSTM mLSTM + sLSTM.

All three expose the same contract as the attention blocks:
  *_specs(cfg)                       -> ParamSpec tree (one layer)
  *_init_cache(cfg, batch)           -> decode state (one layer)
  *_apply(params, x, cfg, mode, cache) -> (y, new_cache)

Training/prefill use parallel forms (associative scan for RG-LRU, the
stabilized quadratic parallel form for mLSTM, a `lax.scan` for the
inherently-sequential sLSTM); decode advances the recurrent state by one
token — O(1) per token, which is why these archs run the `long_500k` shape.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.base import ParamSpec, activation

RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _block_diag_spec(heads: int, width: int) -> ParamSpec:
    per = width // heads
    return ParamSpec((heads, per, per), ("heads", "state", "state"))


def _block_diag_apply(w, x, heads: int):
    """x: (..., width) -> block-diagonal linear per head."""
    per = w.shape[-1]
    xh = x.reshape(x.shape[:-1] + (heads, per))
    y = jnp.einsum("...hi,hij->...hj", xh, w.astype(x.dtype))
    return y.reshape(x.shape)


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise temporal conv. x: (B,S,W); w: (K,W); returns y, new_state.

    conv_state: (B,K-1,W) previous inputs (decode/prefill-carry)."""
    B, S, width = x.shape
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((B, K - 1, width), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+K-1, W)
    y = sum(xp[:, i:i + S] * w[i].astype(x.dtype) for i in range(K))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(K - 1):] if K > 1 else jnp.zeros((B, 0, width), x.dtype)
    return y, new_state


# ===========================================================================
# RG-LRU (RecurrentGemma, arXiv:2402.19427)
# ===========================================================================
def rglru_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    H = cfg.num_heads
    return {
        "wx": ParamSpec((d, w), ("embed", "mlp")),
        "wy": ParamSpec((d, w), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.conv_width, w), ("conv", "mlp"), "normal",
                            scale=1.0 / math.sqrt(cfg.conv_width)),
        "conv_b": ParamSpec((w,), ("mlp",), "zeros"),
        "gate_a": _block_diag_spec(H, w),
        "gate_a_b": ParamSpec((w,), ("mlp",), "zeros"),
        "gate_i": _block_diag_spec(H, w),
        "gate_i_b": ParamSpec((w,), ("mlp",), "zeros"),
        # Λ parametrized so that a = exp(-c*softplus(Λ)) starts in [0.9, 0.999]
        "lam": ParamSpec((w,), ("mlp",), "normal", scale=0.5),
        "wo": ParamSpec((w, d), ("mlp", "embed")),
    }


def rglru_init_cache(cfg: ModelConfig, batch: int) -> Dict:
    w = cfg.lru_width or cfg.d_model
    dt = cfg.compute_dtype
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dt),
    }


def _rglru_gates(params, xb, cfg):
    H = cfg.num_heads
    r = jax.nn.sigmoid(_block_diag_apply(params["gate_a"], xb, H)
                       + params["gate_a_b"].astype(xb.dtype))
    i = jax.nn.sigmoid(_block_diag_apply(params["gate_i"], xb, H)
                       + params["gate_i_b"].astype(xb.dtype))
    log_a = (-RGLRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))                    # (B,S,W) or (B,W)
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.clip(1.0 - jnp.square(a), 1e-12)) \
        * (i.astype(jnp.float32) * xb.astype(jnp.float32))
    return a, gated_x


def rglru_apply(params, x, cfg: ModelConfig, *, mode: str,
                cache: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    xb = jnp.einsum("bsd,dw->bsw", x, params["wx"].astype(x.dtype))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["wy"].astype(x.dtype)))
    conv_state = cache["conv"] if cache is not None else None
    xb, new_conv = _causal_conv(xb, params["conv_w"], params["conv_b"], conv_state)

    a, gx = _rglru_gates(params, xb, cfg)               # fp32 (B,S,W)
    h0 = cache["h"] if cache is not None else jnp.zeros((B, xb.shape[-1]), jnp.float32)

    if mode == "decode":                                 # S == 1
        h = a[:, 0] * h0 + gx[:, 0]
        y_rec = h[:, None]
        new_cache = {"h": h, "conv": new_conv}
    else:
        # h_t = a_t h_{t-1} + gx_t  via associative scan on (a, b) pairs
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        gx0 = gx.at[:, 0].add(a[:, 0] * h0)              # fold initial state in
        a_s, h_all = jax.lax.associative_scan(combine, (a, gx0), axis=1)
        y_rec = h_all
        new_cache = None
        if mode == "prefill":
            new_cache = {"h": h_all[:, -1], "conv": new_conv}

    y = (y_rec.astype(x.dtype) * gate)
    return jnp.einsum("bsw,wd->bsd", y, params["wo"].astype(x.dtype)), new_cache


# ===========================================================================
# mLSTM (xLSTM, arXiv:2405.04517) — matrix memory, parallelizable
# ===========================================================================
def mlstm_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    H = cfg.num_heads
    dk = di // H
    return {
        "w_up": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.conv_width, di), ("conv", "mlp"), "normal",
                            scale=1.0 / math.sqrt(cfg.conv_width)),
        "conv_b": ParamSpec((di,), ("mlp",), "zeros"),
        "wq": ParamSpec((di, H, dk), ("mlp", "heads", "head_dim")),
        "wk": ParamSpec((di, H, dk), ("mlp", "heads", "head_dim")),
        "wv": ParamSpec((di, H, dk), ("mlp", "heads", "head_dim")),
        "w_if": ParamSpec((di, 2 * H), ("mlp", "heads"), "normal", scale=0.02),
        "b_if": ParamSpec((2 * H,), ("heads",), "zeros"),
        "gn_scale": ParamSpec((H, dk), ("heads", "head_dim"), "zeros"),
        "skip_scale": ParamSpec((di,), ("mlp",), "ones"),
        "w_down": ParamSpec((di, d), ("mlp", "embed")),
    }


def mlstm_init_cache(cfg: ModelConfig, batch: int) -> Dict:
    di = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    dk = di // H
    return {
        "C": jnp.zeros((batch, H, dk, dk), jnp.float32),
        "n": jnp.zeros((batch, H, dk), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), cfg.compute_dtype),
    }


def _groupnorm_heads(h, scale):
    """h: (B,S,H,dk) per-head normalization."""
    h32 = h.astype(jnp.float32)
    mu = jnp.mean(h32, axis=-1, keepdims=True)
    var = jnp.var(h32, axis=-1, keepdims=True)
    y = (h32 - mu) * jax.lax.rsqrt(var + 1e-6)
    return y * (1.0 + scale.astype(jnp.float32))


def mlstm_apply(params, x, cfg: ModelConfig, *, mode: str,
                cache: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    H = cfg.num_heads
    up = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(x.dtype))
    xm, z = jnp.split(up, 2, axis=-1)                    # (B,S,di) each
    di = xm.shape[-1]
    dk = di // H
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xm, params["conv_w"], params["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    q = jnp.einsum("bse,enh->bsnh", xc, params["wq"].astype(x.dtype)) / math.sqrt(dk)
    k = jnp.einsum("bse,enh->bsnh", xc, params["wk"].astype(x.dtype)) / math.sqrt(dk)
    v = jnp.einsum("bse,enh->bsnh", xm, params["wv"].astype(x.dtype))
    if_gates = (jnp.einsum("bse,eg->bsg", xc, params["w_if"].astype(x.dtype))
                + params["b_if"].astype(x.dtype)).astype(jnp.float32)
    i_raw, f_raw = jnp.split(if_gates, 2, axis=-1)       # (B,S,H)
    logf = -jax.nn.softplus(-f_raw)                      # log sigmoid(f)

    if mode == "decode":
        C0, n0, m0 = cache["C"], cache["n"], cache["m"]
        i1, f1 = i_raw[:, 0], logf[:, 0]                 # (B,H)
        m1 = jnp.maximum(f1 + m0, i1)
        fs = jnp.exp(f1 + m0 - m1)[..., None]
        isc = jnp.exp(i1 - m1)[..., None]
        k1, v1, q1 = k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32), \
            q[:, 0].astype(jnp.float32)
        C1 = fs[..., None] * C0 + isc[..., None] * k1[..., :, None] * v1[..., None, :]
        n1 = fs * n0 + isc * k1
        num = jnp.einsum("bhk,bhkv->bhv", q1, C1)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q1, n1)),
                          jnp.exp(-m1))[..., None]
        h = (num / den)[:, None]                         # (B,1,H,dk)
        new_cache = {"C": C1, "n": n1, "m": m1, "conv": new_conv}
    else:
        # stabilized parallel (quadratic) form, CHUNKED over queries:
        # the naive (B,Sq,Sk,H) fp32 logd/D/scores tensors cost
        # B_loc*S^2*H*4B each (observed 25GB/device at train_4k — §Perf
        # iteration A); chunking bounds them to (B,Cq,Sk,H) and
        # jax.checkpoint recomputes them in the backward pass.
        F = jnp.cumsum(logf, axis=1)                     # (B,S,H)
        q32 = q.astype(jnp.float32)
        k32 = k.astype(jnp.float32)
        v32 = v.astype(jnp.float32)

        def chunk_fn(start, Fq, qc):
            # Fq: (B,Cq,H); qc: (B,Cq,H,dk)
            logd = (Fq[:, :, None, :] - F[:, None, :, :]
                    + i_raw[:, None, :, :])              # (B,Cq,Sk,H)
            qpos = start + jnp.arange(Fq.shape[1])[:, None]
            kpos = jnp.arange(S)[None, :]
            mask = kpos <= qpos
            logd = jnp.where(mask[None, :, :, None], logd, -jnp.inf)
            mrow = jnp.max(logd, axis=2)                 # (B,Cq,H)
            D = jnp.exp(logd - mrow[:, :, None, :])
            sc = jnp.einsum("bqnh,bknh->bqkn", qc, k32) * D
            norm = jnp.maximum(jnp.abs(sc.sum(2)), jnp.exp(-mrow))
            return jnp.einsum("bqkn,bknh->bqnh", sc, v32) / norm[..., None]

        Cq = S
        for c in range(min(1024, S), 0, -1):
            if S % c == 0:
                Cq = c
                break
        if Cq == S:
            h = chunk_fn(0, F, q32)
        else:
            n = S // Cq
            Fqs = jnp.moveaxis(F.reshape(B, n, Cq, H), 1, 0)
            qcs = jnp.moveaxis(q32.reshape(B, n, Cq, H, -1), 1, 0)
            body = jax.checkpoint(
                lambda _, xs: ((), chunk_fn(xs[0] * Cq, xs[1], xs[2])))
            _, hs = jax.lax.scan(body, (), (jnp.arange(n), Fqs, qcs))
            h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, -1)
        new_cache = None
        if mode == "prefill":
            # final recurrent state for decode continuation
            w = F[:, -1:, :] - F + i_raw                 # (B,S,H)
            ms = jnp.max(w, axis=1)                      # (B,H)
            wexp = jnp.exp(w - ms[:, None, :])
            Cf = jnp.einsum("bsn,bsnk,bsnv->bnkv", wexp,
                            k.astype(jnp.float32), v.astype(jnp.float32))
            nf = jnp.einsum("bsn,bsnk->bnk", wexp, k.astype(jnp.float32))
            new_cache = {"C": Cf, "n": nf, "m": ms, "conv": new_conv}

    h = _groupnorm_heads(h, params["gn_scale"]).reshape(B, -1, di).astype(x.dtype)
    h = h + params["skip_scale"].astype(x.dtype) * xc
    y = h * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["w_down"].astype(x.dtype)), new_cache


# ===========================================================================
# sLSTM (xLSTM) — scalar memory, sequential recurrence
# ===========================================================================
def slstm_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H = cfg.num_heads
    f = int(cfg.slstm_proj_factor * d)
    sp = {}
    for g in ("z", "i", "f", "o"):
        sp[f"w_{g}"] = ParamSpec((d, d), ("embed", "mlp"))
        sp[f"r_{g}"] = _block_diag_spec(H, d)
        sp[f"b_{g}"] = ParamSpec((d,), ("mlp",), "zeros")
    sp["gn_scale"] = ParamSpec((H, d // H), ("heads", "head_dim"), "zeros")
    sp["ffn"] = {
        "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
        "wi_up": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }
    return sp


def slstm_init_cache(cfg: ModelConfig, batch: int) -> Dict:
    d = cfg.d_model
    return {
        "sc": jnp.zeros((batch, d), jnp.float32),
        "sn": jnp.full((batch, d), 1e-6, jnp.float32),
        "sm": jnp.full((batch, d), -1e30, jnp.float32),
        "sh": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(params, x_t, state, cfg):
    """x_t: (B,d) pre-projected inputs per gate; state: (c,n,m,h)."""
    c, n, m, h = state
    H = cfg.num_heads
    hd = h.astype(jnp.float32)

    def gate(name):
        wx = x_t[name]
        rh = _block_diag_apply(params[f"r_{name}"], hd, H)
        return wx + rh + params[f"b_{name}"].astype(jnp.float32)

    z = jnp.tanh(gate("z"))
    i_raw = gate("i")
    f_raw = gate("f")
    o = jax.nn.sigmoid(gate("o"))
    logf = -jax.nn.softplus(-f_raw)
    m1 = jnp.maximum(logf + m, i_raw)
    i1 = jnp.exp(i_raw - m1)
    f1 = jnp.exp(logf + m - m1)
    c1 = f1 * c + i1 * z
    n1 = f1 * n + i1
    h1 = o * c1 / jnp.maximum(n1, 1e-6)
    return (c1, n1, m1, h1)


def slstm_apply(params, x, cfg: ModelConfig, *, mode: str,
                cache: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    H = cfg.num_heads
    # pre-compute the input contributions for all gates (parallel over S)
    xg = {g: jnp.einsum("bsd,de->bse", x, params[f"w_{g}"].astype(x.dtype))
          .astype(jnp.float32) for g in ("z", "i", "f", "o")}

    if cache is not None:
        state0 = (cache["sc"], cache["sn"], cache["sm"], cache["sh"])
    else:
        z0 = jnp.zeros((B, d), jnp.float32)
        state0 = (z0, jnp.full((B, d), 1e-6, jnp.float32),
                  jnp.full((B, d), -1e30, jnp.float32), z0)

    if mode == "decode":
        xt = {g: xg[g][:, 0] for g in xg}
        c1, n1, m1, h1 = _slstm_cell(params, xt, state0, cfg)
        hs = h1[:, None]
        new_cache = {"sc": c1, "sn": n1, "sm": m1, "sh": h1}
    else:
        def step(state, xt):
            s1 = _slstm_cell(params, xt, state, cfg)
            return s1, s1[3]

        xs = {g: jnp.swapaxes(xg[g], 0, 1) for g in xg}  # (S,B,d)
        final, hs = jax.lax.scan(step, state0, xs)
        hs = jnp.swapaxes(hs, 0, 1)                      # (B,S,d)
        new_cache = None
        if mode == "prefill":
            new_cache = {"sc": final[0], "sn": final[1], "sm": final[2],
                         "sh": final[3]}

    y = _groupnorm_heads(hs.reshape(B, -1, H, d // H),
                         params["gn_scale"]).reshape(B, -1, d).astype(x.dtype)
    # post sLSTM gated FFN (proj factor 4/3)
    act = activation(cfg.act)
    f = params["ffn"]
    g = jnp.einsum("bsd,df->bsf", y, f["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", y, f["wi_up"].astype(x.dtype))
    y = jnp.einsum("bsf,fd->bsd", act(g) * u, f["wo"].astype(x.dtype))
    return y, new_cache

"""Encoder–decoder trunk (Whisper-medium backbone).

Per the assignment carve-out, the conv/mel frontend is a STUB: the model
consumes precomputed frame embeddings ``audio_embeds`` (B, frames, d) —
``input_specs()`` provides them.  The transformer itself is complete:

  encoder: sinusoidal positions + N bidirectional attention+MLP layers
  decoder: causal self-attention (RoPE; Whisper's learned 448-position
           table cannot address the assigned 32k shapes — deviation noted
           in DESIGN.md) + cross-attention into the encoder + MLP

Decode mode caches both the decoder self-attn K/V and the (fixed)
projected encoder K/V, so a serve step touches the encoder zero times.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ATTN, ModelConfig
from repro.models import attention, attention_impl, mlp
from repro.models.base import (ParamSpec, apply_norm, norm_spec,
                               sinusoidal_positions)
from repro.sharding import constrain_batch, constrain_logits


# ---------------------------------------------------------------------------
# cross attention
# ---------------------------------------------------------------------------
def cross_specs(cfg: ModelConfig) -> Dict:
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    return {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, H, hd), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, H, hd), ("embed", "heads", "head_dim")),
        "wo": ParamSpec((H, hd, d), ("heads", "head_dim", "embed")),
    }


def cross_kv(params, enc_out):
    k = jnp.einsum("bsd,dnh->bsnh", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", enc_out, params["wv"].astype(enc_out.dtype))
    return k, v


def cross_apply(params, x, k, v, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(x.dtype))
    q = q / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
    scores = jnp.einsum("bqnh,bknh->bnqk", q, k).astype(jnp.float32)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bnqk,bknh->bqnh", w, v)
    return jnp.einsum("bsnh,nhd->bsd", ctx, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------
def _enc_layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "norm1": norm_spec(cfg, cfg.d_model),
        "attn": attention.specs(cfg),
        "norm2": norm_spec(cfg, cfg.d_model),
        "mlp": mlp.specs(cfg),
    }


def _dec_layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "norm1": norm_spec(cfg, cfg.d_model),
        "self_attn": attention.specs(cfg),
        "norm_x": norm_spec(cfg, cfg.d_model),
        "cross": cross_specs(cfg),
        "norm2": norm_spec(cfg, cfg.d_model),
        "mlp": mlp.specs(cfg),
    }


def _stack(base, n):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("stack",) + s.axes, s.init,
                            s.scale, s.dtype),
        base, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg: ModelConfig) -> Dict:
    d, V = cfg.d_model, cfg.padded_vocab_size
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    return {
        "embed": ParamSpec((V, d), ("vocab", "embed"), "normal", scale=0.02),
        "enc_scan": _stack(_enc_layer_specs(cfg), n_enc),
        "enc_final_norm": norm_spec(cfg, d),
        "dec_scan": _stack(_dec_layer_specs(cfg), cfg.num_layers),
        "final_norm": norm_spec(cfg, d),
        "lm_head": ParamSpec((d, V), ("embed", "vocab")),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------
def encode(params, cfg: ModelConfig, audio_embeds, impl: str = "xla"):
    B, F, d = audio_embeds.shape
    x = audio_embeds.astype(cfg.compute_dtype)
    x = constrain_batch(x + sinusoidal_positions(F, d).astype(x.dtype)[None])
    positions = jnp.arange(F)

    def body(xc, pslice):
        h = apply_norm(pslice["norm1"], xc, cfg)
        # bidirectional attention: reuse the projections, no causal mask
        hd = cfg.resolved_head_dim
        p = pslice["attn"]
        q = jnp.einsum("bsd,dnh->bsnh", h, p["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dnh->bsnh", h, p["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dnh->bsnh", h, p["wv"].astype(h.dtype))
        q = q / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
        ctx = attention_impl.causal_attention(q, k, v, causal=False, impl=impl)
        y = jnp.einsum("bsnh,nhd->bsd", ctx, p["wo"].astype(h.dtype))
        xc = xc + y
        h = apply_norm(pslice["norm2"], xc, cfg)
        xc = constrain_batch(xc + mlp.apply(pslice["mlp"], h, cfg))
        return xc, ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_scan"])
    return apply_norm(params["enc_final_norm"], x, cfg)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    n_enc_frames = cfg.encoder_seq or 1500
    L, H, hd = cfg.num_layers, cfg.num_heads, cfg.resolved_head_dim
    dt = cfg.compute_dtype
    one = attention.init_cache(cfg, batch, max_len, ATTN)
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "self": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), one),
        "cross_k": jnp.zeros((L, batch, n_enc_frames, H, hd), dt),
        "cross_v": jnp.zeros((L, batch, n_enc_frames, H, hd), dt),
    }


def forward(params, cfg: ModelConfig, tokens, *, mode: str,
            audio_embeds=None, cache: Optional[Dict] = None,
            impl: str = "xla", last_logit_only: bool = False,
            ) -> Tuple[jnp.ndarray, Optional[Dict], Dict]:
    B, S = tokens.shape
    x = constrain_batch(params["embed"].astype(cfg.compute_dtype)[tokens])

    if mode == "decode":
        assert cache is not None
        positions = cache["pos"][:, None]
        enc_out = None
    else:
        assert audio_embeds is not None
        enc_out = encode(params, cfg, audio_embeds, impl=impl)
        positions = jnp.arange(S)

    def body(carry, xs):
        xc = carry
        if mode == "decode":
            pslice, cslice, ck, cv = xs
        else:
            pslice = xs
            cslice, ck, cv = None, None, None
        h = apply_norm(pslice["norm1"], xc, cfg)
        y, nc = attention.apply(pslice["self_attn"], h, cfg, mode=mode,
                                positions=positions, cache=cslice, kind=ATTN,
                                impl=impl)
        xc = xc + y
        h = apply_norm(pslice["norm_x"], xc, cfg)
        if mode == "decode":
            k, v = ck, cv
        else:
            k, v = cross_kv(pslice["cross"], enc_out)
        xc = xc + cross_apply(pslice["cross"], h, k, v, cfg)
        h = apply_norm(pslice["norm2"], xc, cfg)
        xc = constrain_batch(xc + mlp.apply(pslice["mlp"], h, cfg))
        nc = nc if nc is not None else {}
        if mode == "decode":
            ys = (nc,)
        elif mode == "prefill":
            ys = (nc, k, v)
        else:
            ys = (nc, (), ())
        return xc, ys

    body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
    if mode == "decode":
        xs = (params["dec_scan"], cache["self"], cache["cross_k"], cache["cross_v"])
        x, (new_self,) = jax.lax.scan(body_fn, x, xs)
        new_cache = dict(cache)
        new_cache["self"] = new_self
        new_cache["pos"] = cache["pos"] + 1
    else:
        x, ys = jax.lax.scan(body_fn, x, params["dec_scan"])
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "pos": jnp.full((B,), S, jnp.int32),
                "self": ys[0],
                "cross_k": ys[1],
                "cross_v": ys[2],
            }

    x = apply_norm(params["final_norm"], x, cfg)
    if last_logit_only:
        x = x[:, -1:]
    logits = constrain_logits(
        jnp.einsum("bsd,dv->bsv", x,
                   params["lm_head"].astype(x.dtype)).astype(jnp.float32))
    if cfg.padded_vocab_size != cfg.vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits, new_cache, {"aux_loss": jnp.zeros((), jnp.float32)}

"""Spec-first parameter system + shared layer primitives.

Single source of truth per module: a nested dict of :class:`ParamSpec`
(shape, logical axes, initializer).  From the same spec tree we derive
  * random initial params            (:func:`init_params`)
  * allocation-free abstract params  (:func:`abstract_params`) — this is how
    the 236B dry-run never materializes a weight
  * the logical-axes tree            (:func:`axes_tree`) consumed by
    ``repro.sharding.spec_for``

Apply functions consume plain pytrees of arrays.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Axes
    init: str = "fan_in"      # fan_in | normal | zeros | ones | embed
    scale: float = 1.0
    dtype: Optional[str] = None   # override param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _leaf_key(root_key, path: str):
    # deterministic per-leaf key: fold a *stable* path hash into the root key
    # (zlib.crc32, not hash() — PYTHONHASHSEED must not affect init, or the
    # paper's Fig.5 bitwise-reproducibility experiment breaks across runs)
    import zlib

    h = np.uint32(zlib.crc32(path.encode()) & 0x7FFFFFFF)
    return jax.random.fold_in(root_key, h)


def _materialize(spec: ParamSpec, key, param_dtype) -> jnp.ndarray:
    dtype = jnp.dtype(spec.dtype or param_dtype)
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if spec.init == "embed":
        return (spec.scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if spec.init == "fan_in":
        # truncated-normal fan-in init over the second-to-last... we use the
        # convention: contraction dim(s) are all dims except the last.
        fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                  jnp.float32)).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(specs, key, param_dtype="float32"):
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)
    leaves = [
        _materialize(spec, _leaf_key(key, jax.tree_util.keystr(path)), param_dtype)
        for path, spec in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(specs, param_dtype="float32"):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or param_dtype)),
        specs, is_leaf=_is_spec)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# Primitives (pure functions over plain arrays)
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_spec(cfg, dim: int) -> Dict[str, ParamSpec]:
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((dim,), ("embed_nofsdp",), "ones"),
                "bias": ParamSpec((dim,), ("embed_nofsdp",), "zeros")}
    return {"scale": ParamSpec((dim,), ("embed_nofsdp",), "zeros")}


def apply_norm(params, x, cfg):
    if cfg.norm == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    return rmsnorm(x, params["scale"])


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)


# --------------------------- rotary embeddings ------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim * math.log(10000.0))
    ang = pos * inv[None, :]
    emb = jnp.zeros((seq, dim), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(ang))
    emb = emb.at[:, 1::2].set(jnp.cos(ang))
    return emb


def softcap(logits, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits

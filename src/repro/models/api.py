"""Unified model facade.

``build_model(cfg)`` returns a :class:`Model` whose methods cover every
architecture family (decoder-only, enc-dec, VLM/audio-frontend variants)
behind one contract:

    params = model.init(key)            # or model.abstract() for dry-runs
    logits, _, metrics = model.apply(params, batch, mode="train")
    cache = model.init_cache(batch_size, max_len)
    logits, cache, _ = model.apply(params, batch, mode="decode", cache=cache)

``batch`` is a dict: tokens, labels, and (per family) extra_embeds /
audio_embeds.  `model.input_struct(shape)` produces the ShapeDtypeStruct
stand-ins the multi-pod dry-run lowers against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import InputShape, ModelConfig
from repro.models import encdec, transformer
from repro.models.base import abstract_params, axes_tree, init_params


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    def param_specs(self):
        if self.cfg.is_encoder_decoder:
            return encdec.param_specs(self.cfg)
        return transformer.param_specs(self.cfg)

    def init(self, key):
        return init_params(self.param_specs(), key, self.cfg.param_dtype)

    def abstract(self):
        return abstract_params(self.param_specs(), self.cfg.param_dtype)

    def axes(self):
        return axes_tree(self.param_specs())

    # ------------------------------------------------------------- forward
    def apply(self, params, batch: Dict[str, Any], *, mode: str,
              cache: Optional[Dict] = None, impl: str = "xla",
              prefill_max_len: Optional[int] = None,
              last_logit_only: bool = False):
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            return encdec.forward(params, cfg, batch["tokens"], mode=mode,
                                  audio_embeds=batch.get("audio_embeds"),
                                  cache=cache, impl=impl,
                                  last_logit_only=last_logit_only)
        return transformer.forward(params, cfg, batch["tokens"], mode=mode,
                                   cache=cache,
                                   extra_embeds=batch.get("extra_embeds"),
                                   impl=impl, prefill_max_len=prefill_max_len,
                                   last_logit_only=last_logit_only)

    def init_cache(self, batch: int, max_len: int):
        if self.cfg.is_encoder_decoder:
            return encdec.init_cache(self.cfg, batch, max_len)
        return transformer.init_cache(self.cfg, batch, max_len)

    # ------------------------------------------------------------- inputs
    def batch_keys(self, kind: str) -> Tuple[str, ...]:
        keys = ["tokens"]
        if kind == "train":
            keys.append("labels")
        if self.cfg.frontend == "vision" and kind != "decode":
            keys.append("extra_embeds")
        if self.cfg.is_encoder_decoder and kind != "decode":
            keys.append("audio_embeds")
        return tuple(keys)

    def input_struct(self, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        cfg = self.cfg
        B = shape.global_batch
        S = 1 if shape.kind == "decode" else shape.seq_len
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.frontend == "vision" and shape.kind != "decode":
            out["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_tokens, cfg.d_model), cfg.compute_dtype)
        if cfg.is_encoder_decoder and shape.kind != "decode":
            out["audio_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq or 1500, cfg.d_model), cfg.compute_dtype)
        return out

    def make_batch(self, shape_or_batch, seq_len: Optional[int] = None,
                   seed: int = 0) -> Dict[str, np.ndarray]:
        """Concrete random batch (smoke tests / examples)."""
        if isinstance(shape_or_batch, InputShape):
            B, S, kind = (shape_or_batch.global_batch, shape_or_batch.seq_len,
                          shape_or_batch.kind)
            S = 1 if kind == "decode" else S
        else:
            B, S, kind = shape_or_batch, seq_len, "train"
        rng = np.random.default_rng(seed)
        cfg = self.cfg
        out: Dict[str, np.ndarray] = {
            "tokens": rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)}
        if kind == "train":
            out["labels"] = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
        if cfg.frontend == "vision" and kind != "decode":
            out["extra_embeds"] = rng.normal(
                size=(B, cfg.num_prefix_tokens, cfg.d_model)).astype(np.float32)
        if cfg.is_encoder_decoder and kind != "decode":
            out["audio_embeds"] = rng.normal(
                size=(B, cfg.encoder_seq or 1500, cfg.d_model)).astype(np.float32)
        return out

    def param_count(self) -> int:
        specs = self.param_specs()
        from repro.models.base import ParamSpec

        return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec)))

    def active_param_count(self) -> int:
        """Active params per token (MoE discounts unused experts)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.moe.enabled:
            return total
        m = cfg.moe
        n_moe_layers = cfg.num_layers - m.first_dense_layers
        per_expert = 3 * cfg.d_model * m.d_ff
        inactive = n_moe_layers * (m.num_experts - m.experts_per_token) * per_expert
        return total - inactive


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)

from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    make_optimizer,
    sgd,
    momentum,
    adam,
    adamw,
    adafactor,
    clip_by_global_norm,
)
from repro.optim.schedules import (  # noqa: F401
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
)

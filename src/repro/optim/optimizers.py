"""Optimizers, implemented from scratch (optax is not available offline).

The interface mirrors optax closely enough to be familiar:

    opt = make_optimizer(train_cfg)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = jax.tree.map(lambda p, u: p + u, params, updates)

All states are pytrees shaped like the params, so the same PartitionSpec
tree shards both (DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_global_norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (grads, state, params, step) -> (updates, state)
    name: str = "opt"


def _cast_like(x, ref):
    return x.astype(ref.dtype) if hasattr(ref, "dtype") else x


def clip_by_global_norm(grads, max_norm: float):
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# SGD / momentum
# ---------------------------------------------------------------------------
def sgd(lr_fn: Callable) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        lr = lr_fn(step)
        updates = jax.tree.map(lambda g: (-lr * g.astype(jnp.float32)).astype(g.dtype), grads)
        return updates, state

    return Optimizer(init, update, "sgd")


def momentum(lr_fn: Callable, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)

    def update(grads, state, params, step):
        lr = lr_fn(step)
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        updates = jax.tree.map(lambda m, g: (-lr * m).astype(g.dtype), new_m, grads)
        return updates, new_m

    return Optimizer(init, update, "momentum")


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------
class AdamState(NamedTuple):
    mu: Any
    nu: Any


def adam(lr_fn, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    return adamw(lr_fn, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            u = -lr * (mhat / (jnp.sqrt(vhat) + eps)
                       + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), m, v

        flat_u, flat_m, flat_v = [], [], []
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_m = treedef.flatten_up_to(state.mu)
        leaves_v = treedef.flatten_up_to(state.nu)
        leaves_p = treedef.flatten_up_to(params)
        for g, m, v, p in zip(leaves_g, leaves_m, leaves_v, leaves_p):
            u, m2, v2 = upd(g, m, v, p)
            flat_u.append(u); flat_m.append(m2); flat_v.append(v2)
        updates = jax.tree.unflatten(treedef, flat_u)
        new_state = AdamState(mu=jax.tree.unflatten(treedef, flat_m),
                              nu=jax.tree.unflatten(treedef, flat_v))
        return updates, new_state

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — the memory-lean option for 34B+ dry runs)
# ---------------------------------------------------------------------------
class AdafactorState(NamedTuple):
    vr: Any   # row statistics (or full v for <2D tensors)
    vc: Any   # col statistics (or () placeholder)


def adafactor(lr_fn, eps=1e-30, clip_threshold=1.0, weight_decay=0.0) -> Optimizer:
    def init(params):
        def rows(p):
            if p.ndim < 2:
                return jnp.zeros(p.shape, jnp.float32)
            return jnp.zeros(p.shape[:-1], jnp.float32)

        def cols(p):
            if p.ndim < 2:
                return jnp.zeros((), jnp.float32)
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

        return AdafactorState(vr=jax.tree.map(rows, params),
                              vc=jax.tree.map(cols, params))

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-0.8)

        def upd(g, vr, vc, p):
            g32 = g.astype(jnp.float32)
            sq = jnp.square(g32) + eps
            if p.ndim < 2:
                vr = beta2 * vr + (1 - beta2) * sq
                u = g32 / (jnp.sqrt(vr) + eps)
            else:
                vr = beta2 * vr + (1 - beta2) * jnp.mean(sq, axis=-1)
                vc = beta2 * vc + (1 - beta2) * jnp.mean(sq, axis=-2)
                rfac = jnp.sqrt(vr / (jnp.mean(vr, axis=-1, keepdims=True) + eps))
                u = g32 / (rfac[..., None] * jnp.sqrt(vc)[..., None, :] + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr * (u + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), vr, vc

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_r = treedef.flatten_up_to(state.vr)
        leaves_c = treedef.flatten_up_to(state.vc)
        leaves_p = treedef.flatten_up_to(params)
        fu, fr, fc = [], [], []
        for g, r, c, p in zip(leaves_g, leaves_r, leaves_c, leaves_p):
            u, r2, c2 = upd(g, r, c, p)
            fu.append(u); fr.append(r2); fc.append(c2)
        return (jax.tree.unflatten(treedef, fu),
                AdafactorState(jax.tree.unflatten(treedef, fr),
                               jax.tree.unflatten(treedef, fc)))

    return Optimizer(init, update, "adafactor")


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------
def make_optimizer(train_cfg, lr_fn: Optional[Callable] = None) -> Optimizer:
    from repro.optim.schedules import linear_warmup_cosine

    lr_fn = lr_fn or linear_warmup_cosine(
        train_cfg.learning_rate, train_cfg.warmup_steps, train_cfg.total_steps)
    kind = train_cfg.optimizer
    if kind == "sgd":
        return sgd(lr_fn)
    if kind == "momentum":
        return momentum(lr_fn, beta=train_cfg.beta1)
    if kind == "adam":
        return adamw(lr_fn, b1=train_cfg.beta1, b2=train_cfg.beta2,
                     eps=train_cfg.eps, weight_decay=0.0)
    if kind == "adamw":
        return adamw(lr_fn, b1=train_cfg.beta1, b2=train_cfg.beta2,
                     eps=train_cfg.eps, weight_decay=train_cfg.weight_decay)
    if kind == "adafactor":
        return adafactor(lr_fn, weight_decay=train_cfg.weight_decay)
    raise ValueError(f"unknown optimizer {kind!r}")

from repro.runtime.transport import FaultSpec, Message, Network  # noqa: F401
from repro.runtime.reliable import ReliableMessenger, RequestTimeout  # noqa: F401
from repro.runtime.jobs import JobSpec, JobStatus  # noqa: F401
from repro.runtime.scp import FlareRuntime  # noqa: F401
from repro.runtime.streaming import MetricCollector, SummaryWriter  # noqa: F401
from repro.runtime.provision import Provisioner, StartupKit  # noqa: F401

"""Server Control Process + runtime facade (paper §3.1).

``FlareRuntime`` owns the transport, provisioning, the SCP scheduler and
the server-side job processes.  Per job it creates a *Job Network*: one
server job endpoint ``server/job/<id>`` plus one client job endpoint
``<site>/job/<id>`` per site (spawned by each site's CCP).  By default job
processes are NOT directly connected: client-side requests go to the SCP,
which relays to the server job process (and back) — exactly the message
path of Fig. 4.  ``direct_connections=True`` switches to P2P (the
"network policy permits" fast path), transparently to applications.
"""
from __future__ import annotations

import threading
import time
import traceback
from typing import Dict, List, Optional

from repro.runtime.ccp import CCP, JobContext
from repro.runtime.jobs import JobRecord, JobSpec, JobStatus, ResourcePool
from repro.runtime.provision import Provisioner, StartupKit
from repro.runtime.reliable import ReliableMessenger, RequestTimeout
from repro.runtime.streaming import MetricCollector
from repro.runtime.transport import FaultSpec, Message, Network

SCP_NAME = "scp"


class FlareRuntime:
    def __init__(self, project: str = "fl-project",
                 faults: Optional[FaultSpec] = None,
                 direct_connections: bool = False,
                 retry_interval: float = 0.02,
                 request_timeout: float = 60.0):
        self.network = Network(faults)
        self.provisioner = Provisioner(project)
        self.direct_connections = direct_connections
        self.request_timeout = request_timeout
        self.retry_interval = retry_interval
        self.scp = ReliableMessenger(self.network, SCP_NAME,
                                     retry_interval=retry_interval,
                                     default_timeout=request_timeout)
        self._jobs: Dict[str, JobRecord] = {}
        self._ccps: Dict[str, CCP] = {}
        self._pools: Dict[str, ResourcePool] = {}
        self._metrics: Dict[str, MetricCollector] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._sched = threading.Thread(target=self._scheduler, daemon=True,
                                       name="scp-scheduler")
        self._sched.start()
        # SCP relays client<->server job traffic (Fig. 4 hops 2/5)
        self.scp.register_handler("job/*", self._relay)

    # ------------------------------------------------------------ sites
    def provision_site(self, site: str, role: str = "client",
                       resources: Optional[Dict[str, float]] = None) -> StartupKit:
        kit = self.provisioner.issue(site, role)
        if role == "client":
            ccp = CCP(self, site, kit)
            with self._lock:
                self._ccps[site] = ccp
                self._pools[site] = ResourcePool(resources or {"gpu": 1.0})
        return kit

    def sites(self) -> List[str]:
        with self._lock:
            return sorted(self._ccps)

    # ------------------------------------------------------------ jobs API
    def submit_job(self, spec: JobSpec, kit: StartupKit) -> str:
        if not self.provisioner.authorize(kit, "submit_job"):
            raise PermissionError(f"{kit.site} ({kit.role}) may not submit jobs")
        rec = JobRecord(spec)
        with self._lock:
            self._jobs[spec.job_id] = rec
            self._metrics[spec.job_id] = MetricCollector()
        return spec.job_id

    def job(self, job_id: str) -> JobRecord:
        with self._lock:
            return self._jobs[job_id]

    def metrics(self, job_id: str) -> MetricCollector:
        with self._lock:
            return self._metrics[job_id]

    def wait(self, job_id: str, timeout: Optional[float] = None) -> JobRecord:
        rec = self.job(job_id)
        rec.done.wait(timeout)
        return rec

    def abort_job(self, job_id: str, kit: StartupKit) -> None:
        if not self.provisioner.authorize(kit, "abort_job"):
            raise PermissionError("not authorized to abort")
        rec = self.job(job_id)
        rec.status = JobStatus.ABORTED
        rec.done.set()

    # ------------------------------------------------------------ scheduler
    def _scheduler(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                pending = [r for r in self._jobs.values()
                           if r.status == JobStatus.SUBMITTED]
                sites = sorted(self._ccps)
            for rec in pending:
                if len(sites) < rec.spec.min_sites:
                    continue
                # resource check on every site (concurrent-job admission)
                acquired = []
                ok = True
                for s in sites:
                    if self._pools[s].try_acquire(rec.spec.resources):
                        acquired.append(s)
                    else:
                        ok = False
                        break
                if not ok or len(acquired) < rec.spec.min_sites:
                    for s in acquired:
                        self._pools[s].release(rec.spec.resources)
                    continue
                rec.sites = acquired
                rec.status = JobStatus.SCHEDULED
                t = threading.Thread(target=self._run_job, args=(rec,),
                                     daemon=True, name=f"job-{rec.job_id}")
                t.start()
            time.sleep(0.01)

    # ------------------------------------------------------------ job run
    def _run_job(self, rec: JobRecord) -> None:
        spec = rec.spec
        try:
            rec.status = JobStatus.DEPLOYING
            # server job endpoint + metric sink
            server_ep = f"server/job/{spec.job_id}"
            messenger = ReliableMessenger(self.network, server_ep,
                                          retry_interval=self.retry_interval,
                                          default_timeout=self.request_timeout)
            collector = self.metrics(spec.job_id)
            self.scp.register_handler(f"job/{spec.job_id}/metrics",
                                      collector.on_event)
            messenger.register_handler(f"job/{spec.job_id}/metrics",
                                       collector.on_event)
            ctx = JobContext(runtime=self, job_id=spec.job_id, site="server",
                             messenger=messenger, sites=list(rec.sites))
            server_job = spec.server_app_fn()

            # deploy to every CCP (startup kits / custom code / certs)
            for s in rec.sites:
                resp = self.scp.request(f"ccp/{s}", "ccp/deploy",
                                        spec.job_id.encode(),
                                        timeout=self.request_timeout)
                if resp != b"OK":
                    raise RuntimeError(f"deploy failed on {s}: {resp!r}")
            rec.status = JobStatus.RUNNING
            rec.result = server_job.run(ctx)
            rec.status = JobStatus.COMPLETED
        except Exception as e:  # noqa: BLE001
            rec.error = f"{e}\n{traceback.format_exc()}"
            rec.status = JobStatus.FAILED
        finally:
            for s in rec.sites:
                try:
                    self.scp.request(f"ccp/{s}", "ccp/stop",
                                     spec.job_id.encode(), timeout=5.0)
                except RequestTimeout:
                    pass
                self._pools[s].release(spec.resources)
            rec.done.set()

    # ------------------------------------------------------------ relay
    def _relay(self, msg: Message) -> bytes:
        """SCP-mediated Job-Network routing: job/<id>/relay/<dest>/<topic>."""
        parts = msg.topic.split("/")
        if len(parts) < 4 or parts[2] != "relay":
            return b""
        job_id, dest = parts[1], parts[3]
        inner_topic = "/".join(["job", job_id] + parts[4:])
        target = (f"server/job/{job_id}" if dest == "server"
                  else f"{dest}/job/{job_id}")
        return self.scp.request(target, inner_topic, msg.payload,
                                timeout=self.request_timeout)

    # ------------------------------------------------------------ lifecycle
    def shutdown(self) -> None:
        self._stop.set()
        for ccp in self._ccps.values():
            ccp.shutdown()
        self.network.close()

    # registry the CCPs use to fetch "deployed code" (single-process stand-in
    # for FLARE's custom-code distribution; documented in DESIGN.md)
    def _lookup_spec(self, job_id: str) -> JobSpec:
        return self.job(job_id).spec

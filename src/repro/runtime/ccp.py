"""Client Control Process + per-job client context (paper §3.1).

One CCP per site, long-running.  On DEPLOY it spawns a *client job process*
(thread) with its own Job-Network endpoint ``<site>/job/<id>`` and a
:class:`JobContext` handle; on STOP it tears the job process down.
"""
from __future__ import annotations

import threading
import traceback
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.runtime.provision import StartupKit
from repro.runtime.reliable import ReliableMessenger
from repro.runtime.streaming import SummaryWriter
from repro.runtime.transport import Message


@dataclass
class JobContext:
    """Everything an app (server- or client-side) may touch at runtime."""

    runtime: Any                 # FlareRuntime
    job_id: str
    site: str                    # "server" or the site name
    messenger: ReliableMessenger
    sites: List[str] = field(default_factory=list)
    extras: Dict[str, Any] = field(default_factory=dict)
    stop_event: threading.Event = field(default_factory=threading.Event)

    # --------------------------------------------------------- messaging
    def endpoint_of(self, who: str) -> str:
        return (f"server/job/{self.job_id}" if who == "server"
                else f"{who}/job/{self.job_id}")

    def request(self, dest: str, topic: str, payload: bytes,
                timeout: Optional[float] = None) -> bytes:
        """Reliable request to a Job-Network peer.

        Relayed through the SCP unless the runtime permits direct
        connections (paper §3.1's transparent communication path)."""
        full_topic = f"job/{self.job_id}/{topic}"
        if self.runtime.direct_connections:
            return self.messenger.request(self.endpoint_of(dest), full_topic,
                                          payload, timeout=timeout)
        relay_topic = f"job/{self.job_id}/relay/{dest}/{topic}"
        return self.messenger.request("scp", relay_topic, payload,
                                      timeout=timeout)

    def register_handler(self, topic: str, fn) -> None:
        self.messenger.register_handler(f"job/{self.job_id}/{topic}", fn)

    # --------------------------------------------------------- tracking
    def summary_writer(self) -> SummaryWriter:
        """FLARE experiment tracking (paper §5.2): metrics stream to the
        server whether or not direct connections are enabled."""
        return SummaryWriter(self.messenger, "scp", self.job_id, self.site)


class CCP:
    def __init__(self, runtime, site: str, kit: StartupKit):
        self.runtime = runtime
        self.site = site
        self.kit = kit
        self.messenger = ReliableMessenger(
            runtime.network, f"ccp/{site}",
            retry_interval=runtime.retry_interval,
            default_timeout=runtime.request_timeout)
        self.messenger.register_handler("ccp/deploy", self._on_deploy)
        self.messenger.register_handler("ccp/stop", self._on_stop)
        self._job_threads: Dict[str, threading.Thread] = {}
        self._job_messengers: Dict[str, ReliableMessenger] = {}
        self._job_ctxs: Dict[str, JobContext] = {}
        self._errors: Dict[str, str] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------ handlers
    def _on_deploy(self, msg: Message) -> bytes:
        job_id = msg.payload.decode()
        if not self.runtime.provisioner.verify(self.kit):
            return b"ERR: bad startup kit"
        try:
            spec = self.runtime._lookup_spec(job_id)
            client_app = spec.client_app_fn(self.site)
        except Exception as e:  # noqa: BLE001
            return f"ERR: {e}".encode()
        messenger = ReliableMessenger(
            self.runtime.network, f"{self.site}/job/{job_id}",
            retry_interval=self.runtime.retry_interval,
            default_timeout=self.runtime.request_timeout)
        ctx = JobContext(runtime=self.runtime, job_id=job_id, site=self.site,
                         messenger=messenger)

        def run():
            try:
                client_app.run(ctx)
            except Exception:  # noqa: BLE001
                with self._lock:
                    self._errors[job_id] = traceback.format_exc()

        t = threading.Thread(target=run, daemon=True,
                             name=f"{self.site}-job-{job_id}")
        with self._lock:
            self._job_threads[job_id] = t
            self._job_messengers[job_id] = messenger
            self._job_ctxs[job_id] = ctx
        t.start()
        return b"OK"

    def _on_stop(self, msg: Message) -> bytes:
        job_id = msg.payload.decode()
        with self._lock:
            t = self._job_threads.pop(job_id, None)
            messenger = self._job_messengers.pop(job_id, None)
            ctx = self._job_ctxs.pop(job_id, None)
        if ctx is not None:
            ctx.stop_event.set()
        if t is not None:
            t.join(timeout=2.0)
        if messenger is not None:
            messenger.close()
        return b"OK"

    def error(self, job_id: str) -> Optional[str]:
        with self._lock:
            return self._errors.get(job_id)

    def shutdown(self) -> None:
        self.messenger.close()

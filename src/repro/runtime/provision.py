"""Provisioning: startup kits, identity, authn/authz (paper §2 benefits).

Real FLARE provisioning issues signed certificates per site; here a
:class:`Provisioner` issues :class:`StartupKit` objects carrying an HMAC
token over (project, site, role).  The runtime rejects registration or job
submission whose token does not verify — the simulated equivalent of mutual
TLS + the authorization policy.
"""
from __future__ import annotations

import hashlib
import hmac
import os
from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class StartupKit:
    project: str
    site: str
    role: str                 # "server" | "client" | "admin"
    token: bytes

    def fingerprint(self) -> str:
        return hashlib.sha256(self.token).hexdigest()[:16]


class Provisioner:
    def __init__(self, project: str, secret: Optional[bytes] = None):
        self.project = project
        self._secret = secret or os.urandom(32)
        self._issued: Dict[str, StartupKit] = {}
        # authorization policy: role -> allowed actions
        self.policy = {
            "admin": {"submit_job", "abort_job", "list_jobs"},
            "server": {"aggregate", "relay"},
            "client": {"train", "relay"},
        }

    def _sign(self, site: str, role: str) -> bytes:
        msg = f"{self.project}|{site}|{role}".encode()
        return hmac.new(self._secret, msg, hashlib.sha256).digest()

    def issue(self, site: str, role: str) -> StartupKit:
        kit = StartupKit(self.project, site, role, self._sign(site, role))
        self._issued[site] = kit
        return kit

    def verify(self, kit: StartupKit) -> bool:
        if kit.project != self.project:
            return False
        return hmac.compare_digest(kit.token, self._sign(kit.site, kit.role))

    def authorize(self, kit: StartupKit, action: str) -> bool:
        return self.verify(kit) and action in self.policy.get(kit.role, set())

    # pairwise seeds for secure aggregation (derived from site identities —
    # in production this is a DH exchange; the HMAC stand-in is deterministic)
    def pairwise_seed(self, site_a: str, site_b: str) -> int:
        lo, hi = sorted([site_a, site_b])
        digest = hmac.new(self._secret, f"secagg|{lo}|{hi}".encode(),
                          hashlib.sha256).digest()
        return int.from_bytes(digest[:8], "big")

"""Metric streaming (paper §5.2): FLARE's experiment-tracking feature.

Clients create a :class:`SummaryWriter` inside their training code and call
``add_scalar``; scalars stream (fire-and-forget EVENTs over the runtime) to
the server-side :class:`MetricCollector`, which stores per-site series and
can export a TensorBoard-style JSON dump (the Fig. 6 artifact).
"""
from __future__ import annotations

import json
import struct
import threading
import time
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.runtime.reliable import ReliableMessenger
from repro.runtime.transport import Message

_FMT = "!d i"   # value, step


def _encode(tag: str, value: float, step: int) -> bytes:
    head = tag.encode()
    return struct.pack("!H", len(head)) + head + struct.pack(_FMT, value, step)


def _decode(b: bytes) -> Tuple[str, float, int]:
    (n,) = struct.unpack_from("!H", b, 0)
    tag = b[2:2 + n].decode()
    value, step = struct.unpack_from(_FMT, b, 2 + n)
    return tag, value, step


class SummaryWriter:
    """Client-side API mirroring ``nvflare.client.tracking.SummaryWriter``."""

    def __init__(self, messenger: ReliableMessenger, server: str, job_id: str,
                 site: str):
        self._m = messenger
        self._server = server
        self._topic = f"job/{job_id}/metrics"
        self._site = site

    def add_scalar(self, tag: str, value: float, global_step: int = 0) -> None:
        payload = _encode(f"{self._site}/{tag}", float(value), int(global_step))
        self._m.notify(self._server, self._topic, payload)


class MetricCollector:
    """Server-side sink; one per job. Thread-safe."""

    def __init__(self):
        self._series: Dict[str, List[Tuple[int, float, float]]] = defaultdict(list)
        self._lock = threading.Lock()

    def on_event(self, msg: Message) -> bytes:
        tag, value, step = _decode(msg.payload)
        with self._lock:
            self._series[tag].append((step, value, time.time()))
        return b""

    def series(self, tag: str) -> List[Tuple[int, float]]:
        with self._lock:
            return [(s, v) for s, v, _ in sorted(self._series.get(tag, []))]

    def tags(self) -> List[str]:
        with self._lock:
            return sorted(self._series)

    def export_tensorboard_json(self, path: Optional[str] = None) -> str:
        with self._lock:
            dump = {tag: [[t, s, v] for (s, v, t) in pts]
                    for tag, pts in self._series.items()}
        out = json.dumps(dump, indent=1)
        if path:
            with open(path, "w") as f:
                f.write(out)
        return out

"""Metric streaming (paper §5.2): FLARE's experiment-tracking feature.

Clients create a :class:`SummaryWriter` inside their training code and call
``add_scalar``; scalars stream (fire-and-forget EVENTs over the runtime) to
the server-side :class:`MetricCollector`, which stores per-site series and
can export a TensorBoard-style JSON dump (the Fig. 6 artifact).
"""
from __future__ import annotations

import json
import struct
import threading
import time
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.fl.flat import WIRE_MAGICS
from repro.runtime.reliable import ReliableMessenger
from repro.runtime.transport import Message

_FMT = "!d i"   # value, step
# legacy frames start with the high byte of a u16 tag length (below the
# reserved 0xF0 range for any sane tag), so the version byte — claimed
# in fl/flat.py's WIRE_MAGICS registry — is unambiguous
_BATCH_MAGIC = WIRE_MAGICS["metric_batch"]


def _encode(tag: str, value: float, step: int) -> bytes:
    head = tag.encode()
    return struct.pack("!H", len(head)) + head + struct.pack(_FMT, value, step)


def _decode(b: bytes) -> Tuple[str, float, int]:
    (n,) = struct.unpack_from("!H", b, 0)
    tag = b[2:2 + n].decode()
    value, step = struct.unpack_from(_FMT, b, 2 + n)
    return tag, value, step


def _encode_batch(items: List[Tuple[str, float, int]]) -> bytes:
    parts = [struct.pack("!BH", _BATCH_MAGIC, len(items))]
    for tag, value, step in items:
        parts.append(_encode(tag, float(value), int(step)))
    return b"".join(parts)


def _decode_batch(b: bytes) -> List[Tuple[str, float, int]]:
    (count,) = struct.unpack_from("!H", b, 1)
    off = 3
    out = []
    for _ in range(count):
        (n,) = struct.unpack_from("!H", b, off)
        tag = b[off + 2:off + 2 + n].decode()
        value, step = struct.unpack_from(_FMT, b, off + 2 + n)
        out.append((tag, value, step))
        off += 2 + n + struct.calcsize(_FMT)
    return out


class SummaryWriter:
    """Client-side API mirroring ``nvflare.client.tracking.SummaryWriter``."""

    def __init__(self, messenger: ReliableMessenger, server: str, job_id: str,
                 site: str):
        self._m = messenger
        self._server = server
        self._topic = f"job/{job_id}/metrics"
        self._site = site

    def add_scalar(self, tag: str, value: float, global_step: int = 0) -> None:
        payload = _encode(f"{self._site}/{tag}", float(value), int(global_step))
        self._m.notify(self._server, self._topic, payload)

    def add_scalars(self, tag_values: Dict[str, float],
                    global_step: int = 0) -> None:
        """Batched variant: one EVENT round-trip for a whole dict of
        per-step metrics instead of one ``notify`` per scalar."""
        if not tag_values:
            return
        items = [(f"{self._site}/{tag}", float(v), int(global_step))
                 for tag, v in tag_values.items()]
        self._m.notify(self._server, self._topic, _encode_batch(items))


class MetricCollector:
    """Server-side sink; one per job. Thread-safe."""

    def __init__(self):
        self._series: Dict[str, List[Tuple[int, float, float]]] = defaultdict(list)
        self._lock = threading.Lock()

    def on_event(self, msg: Message) -> bytes:
        if msg.payload and msg.payload[0] == _BATCH_MAGIC:
            items = _decode_batch(msg.payload)
        else:
            items = [_decode(msg.payload)]
        # TensorBoard-style wall_time: reported to humans, never compared
        # against deadlines (those are time.monotonic(), see INVARIANTS)
        now = time.time()  # repro: allow[monotonic-clock] reason=human-facing wall_time in the exported TensorBoard JSON
        with self._lock:
            for tag, value, step in items:
                self._series[tag].append((step, value, now))
        return b""

    def series(self, tag: str) -> List[Tuple[int, float]]:
        with self._lock:
            return [(s, v) for s, v, _ in sorted(self._series.get(tag, []))]

    def tags(self) -> List[str]:
        with self._lock:
            return sorted(self._series)

    def export_tensorboard_json(self, path: Optional[str] = None) -> str:
        with self._lock:
            dump = {tag: [[t, s, v] for (s, v, t) in pts]
                    for tag, pts in self._series.items()}
        out = json.dumps(dump, indent=1)
        if path:
            with open(path, "w") as f:
                f.write(out)
        return out

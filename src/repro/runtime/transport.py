"""In-process transport with deterministic fault injection.

The paper's FLARE deployment runs gRPC/HTTP/TCP/Redis between hosts; this
container is one process, so "the wire" is a byte-only boundary between
threads: every payload that crosses a :class:`Network` is ``bytes`` — no
live Python object (and certainly no jax array) sneaks across, which keeps
the simulation honest (DESIGN.md §2, changed assumptions).

Faults are *deterministic per (seed, msg_id, attempt)*: a retried message is
a new attempt and may get through even if the first was dropped.  That makes
ReliableMessage behaviour reproducible in tests regardless of thread timing.
"""
from __future__ import annotations

import hashlib
import queue
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Message:
    msg_id: str              # unique per logical request
    attempt: int             # retry counter (fault rng input)
    kind: str                # REQ | RESP | QUERY | EVENT
    sender: str
    receiver: str
    topic: str               # e.g. "job/<id>/relay"
    payload: bytes
    headers: Tuple[Tuple[str, str], ...] = ()

    def header(self, key: str, default: str = "") -> str:
        return dict(self.headers).get(key, default)


@dataclass(frozen=True)
class FaultSpec:
    drop_prob: float = 0.0
    dup_prob: float = 0.0
    max_delay_s: float = 0.0
    seed: int = 0

    def roll(self, msg: Message) -> Tuple[bool, bool, float]:
        """(dropped, duplicated, delay_s) — deterministic per msg+attempt."""
        h = hashlib.sha256(
            f"{self.seed}|{msg.msg_id}|{msg.attempt}|{msg.kind}".encode()
        ).digest()
        u1 = int.from_bytes(h[0:8], "big") / 2 ** 64
        u2 = int.from_bytes(h[8:16], "big") / 2 ** 64
        u3 = int.from_bytes(h[16:24], "big") / 2 ** 64
        return (u1 < self.drop_prob, u2 < self.dup_prob, u3 * self.max_delay_s)


class Network:
    """Central message switch: per-endpoint inboxes + fault injection."""

    def __init__(self, faults: Optional[FaultSpec] = None):
        self.faults = faults or FaultSpec()
        self._inboxes: Dict[str, "queue.Queue[Message]"] = {}
        self._lock = threading.Lock()
        self.stats = {"sent": 0, "delivered": 0, "dropped": 0, "duplicated": 0,
                      "bytes": 0}
        self._delay_timers: List[threading.Timer] = []
        self._closed = False

    # -- endpoints -----------------------------------------------------------
    def register(self, name: str) -> "queue.Queue[Message]":
        with self._lock:
            if name not in self._inboxes:
                self._inboxes[name] = queue.Queue()
            return self._inboxes[name]

    def inbox(self, name: str) -> "queue.Queue[Message]":
        return self._inboxes[name]

    # -- sending ----------------------------------------------------------------
    def send(self, msg: Message) -> None:
        if not isinstance(msg.payload, (bytes, bytearray)):
            raise TypeError(
                f"payload must be bytes, got {type(msg.payload).__name__} — "
                "serialize before crossing the wire")
        with self._lock:
            if self._closed:
                return
            self.stats["sent"] += 1
            self.stats["bytes"] += len(msg.payload)
        dropped, dup, delay = self.faults.roll(msg)
        if dropped:
            with self._lock:
                self.stats["dropped"] += 1
            return
        copies = 2 if dup else 1
        if dup:
            with self._lock:
                self.stats["duplicated"] += 1
        for _ in range(copies):
            if delay > 0:
                t = threading.Timer(delay, self._deliver, args=(msg,))
                t.daemon = True
                with self._lock:
                    self._delay_timers.append(t)
                t.start()
            else:
                self._deliver(msg)

    def _deliver(self, msg: Message) -> None:
        with self._lock:
            if self._closed:
                return
            box = self._inboxes.get(msg.receiver)
            self.stats["delivered"] += 1
        if box is None:
            raise KeyError(f"unknown endpoint {msg.receiver!r}")
        box.put(msg)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            timers = list(self._delay_timers)
        for t in timers:
            t.cancel()

"""ReliableMessage (paper §4.1), faithfully:

  1. the requester sends the request; if delivery fails it retries a moment
     later, repeating until sent or until the timeout elapses (=> abort);
  2. once sent, the requester waits for the response; the peer pushes the
     result as soon as processing finishes; *in parallel* the requester
     periodically sends QUERY messages to pull the result, so the response
     arrives through whichever path survives (push or query-pull);
  3. the receiver deduplicates by msg_id — a request is executed exactly
     once no matter how many retries/duplicates arrive — and keeps the
     result cached so late queries can still fetch it.
"""
from __future__ import annotations

import itertools
import threading
import time
import uuid
from typing import Callable, Dict, Optional, Tuple

from repro.runtime.transport import Message, Network


class RequestTimeout(RuntimeError):
    """Raised when a reliable exchange exceeds its deadline.

    Carries the exchange coordinates so callers can *demote* the timeout
    to a recorded per-node failure (the FL fault-tolerance contract)
    instead of aborting the job — e.g. the SuperNode keeps serving and
    the server logs ``(node, "timeout")`` for the round.
    """

    def __init__(self, message: str, *, target: Optional[str] = None,
                 topic: Optional[str] = None,
                 timeout: Optional[float] = None):
        super().__init__(message)
        self.target = target
        self.topic = topic
        self.timeout = timeout


_PENDING = b"\x00__PENDING__"
_counter = itertools.count()


class ResultCache:
    """Responder-side execute-once dedup (the ReliableMessage receiver
    role, §4.1(3)), reusable by any transport: :meth:`begin` marks a
    msg_id before its handler runs, :meth:`finish` caches the result for
    ``ttl`` seconds so retries — and the socket transport's
    reconnect-resends — fetch the cached response instead of re-executing
    a possibly non-idempotent operation.

    Lifecycle per msg_id: unseen -> executing -> done(result, ts) ->
    result reaped at ``ttl`` (the tiny dedup mark survives 10x longer,
    so a straggling duplicate is still recognized as seen: the handler
    never re-executes, the requester just times out — safe) -> unseen.
    """

    def __init__(self, ttl: float = 60.0,
                 lock: Optional[threading.Lock] = None):
        self.ttl = ttl
        self._lock = lock if lock is not None else threading.Lock()
        self._seen: Dict[str, float] = {}            # guarded-by: _lock
        self._executing: set = set()                 # guarded-by: _lock
        self._results: Dict[str, Tuple[float, object]] = {}  # guarded-by: _lock

    def begin(self, msg_id: str) -> Tuple[str, Optional[object]]:
        """Claim ``msg_id`` for execution.  Returns one of
        ``("new", None)`` — caller executes and must :meth:`finish`,
        :meth:`fail`, or :meth:`forget`; ``("executing", None)`` — the
        first arrival is still running (its completion will answer);
        ``("done", result)`` — cached; ``("seen", None)`` — executed but
        the payload is already reaped (never re-execute)."""
        with self._lock:
            if msg_id in self._seen:
                if msg_id in self._executing:
                    return "executing", None
                cached = self._results.get(msg_id)
                if cached is not None:
                    return "done", cached[1]
                return "seen", None
            # pin the mark while the handler runs: a long-running handler
            # must not have its dedup mark reaped mid-flight (a retry
            # would then re-execute)
            self._seen[msg_id] = time.monotonic()
            self._executing.add(msg_id)
            return "new", None

    def finish(self, msg_id: str, result: object) -> None:
        with self._lock:
            self._results[msg_id] = (time.monotonic(), result)
            self._executing.discard(msg_id)
            self._reap()

    def fail(self, msg_id: str) -> None:
        """Handler raised: unpin, but keep the dedup mark — retries of a
        request whose execution blew up mid-flight must not re-execute
        (the requester times out instead)."""
        with self._lock:
            self._executing.discard(msg_id)

    def forget(self, msg_id: str) -> None:
        """Undo :meth:`begin` entirely (e.g. no handler registered yet):
        a retry gets to execute from scratch."""
        with self._lock:
            self._executing.discard(msg_id)
            self._seen.pop(msg_id, None)

    def get(self, msg_id: str) -> Optional[object]:
        """Cached result for a QUERY-style pull, or None."""
        with self._lock:
            cached = self._results.get(msg_id)
            self._reap()
            return None if cached is None else cached[1]

    def reap(self) -> None:
        """Idle-tick reap, so an endpoint that goes quiet still releases
        its cached payloads."""
        with self._lock:
            self._reap()

    def _reap(self) -> None:  # guarded-by: _lock
        """Drop cached result payloads past ``ttl``; keep the (tiny)
        dedup marks 10x longer.  Caller holds the lock."""
        now = time.monotonic()
        cutoff = now - self.ttl
        for mid in [m for m, (ts, _) in self._results.items()
                    if ts < cutoff]:
            del self._results[mid]
        mark_cutoff = now - 10 * self.ttl
        for mid in [m for m, ts in self._seen.items()
                    if ts < mark_cutoff and m not in self._results
                    and m not in self._executing]:
            del self._seen[mid]


class ReliableMessenger:
    """One per endpoint; handles both the requester and responder roles."""

    def __init__(self, network: Network, me: str,
                 retry_interval: float = 0.02, default_timeout: float = 10.0,
                 result_ttl: float = 60.0):
        self.net = network
        self.me = me
        self.retry_interval = retry_interval
        self.default_timeout = default_timeout
        # how long a responder keeps a computed result for late QUERYs /
        # duplicate REQs; afterwards the entry (and its dedup mark) is
        # reaped so a long-lived endpoint's cache stays bounded
        self.result_ttl = result_ttl
        self.inbox = network.register(me)
        self._inflight: Dict[str, threading.Event] = {}
        self._responses: Dict[str, bytes] = {}        # requester: msg_id -> resp
        self._handlers: Dict[str, Callable[[Message], bytes]] = {}
        self._lock = threading.Lock()
        # responder dedup + result cache shares this object's lock, so
        # holding ``_lock`` snapshots the cache consistently (tests rely
        # on that) — never call cache methods while already holding it
        self._cache = ResultCache(result_ttl, lock=self._lock)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name=f"rm-{me}")
        self._thread.start()

    @property
    def _results(self) -> Dict[str, Tuple[float, object]]:
        return self._cache._results

    @property
    def _seen(self) -> Dict[str, float]:
        return self._cache._seen

    def _send(self, msg_id: str, kind: str, receiver: str, topic: str,
              payload: bytes, attempt: int = 0) -> None:
        self.net.send(Message(msg_id, attempt, kind, self.me, receiver, topic,
                              payload))

    # ------------------------------------------------------------ responder
    def register_handler(self, topic: str, fn: Callable[[Message], bytes]) -> None:
        with self._lock:
            self._handlers[topic] = fn

    def _handle_request(self, msg: Message) -> None:
        state, cached = self._cache.begin(msg.msg_id)
        if state != "new":                           # dedup: execute once
            if state == "done":                      # re-push cached result
                self._send(msg.msg_id, "RESP", msg.sender, msg.topic,
                           cached, attempt=msg.attempt)
            return
        with self._lock:
            handler = self._match_handler(msg.topic)
        if handler is None:
            # no handler *yet* (job process still starting): stay unseen
            # so a retry executes once the handler is registered
            self._cache.forget(msg.msg_id)
            return
        try:
            result = handler(msg)                    # may take a while
        except BaseException:
            self._cache.fail(msg.msg_id)
            raise
        self._cache.finish(msg.msg_id, result)
        self._send(msg.msg_id, "RESP", msg.sender, msg.topic, result,
                   attempt=msg.attempt)

    def _match_handler(self, topic: str):
        if topic in self._handlers:
            return self._handlers[topic]
        for t, fn in self._handlers.items():
            if t.endswith("*") and topic.startswith(t[:-1]):
                return fn
        return None

    def _handle_query(self, msg: Message) -> None:
        cached = self._cache.get(msg.msg_id)
        self._send(msg.msg_id, "RESP", msg.sender, msg.topic,
                   cached if cached is not None else _PENDING,
                   attempt=msg.attempt)

    # ------------------------------------------------------------ requester
    def request(self, target: str, topic: str, payload: bytes,
                timeout: Optional[float] = None) -> bytes:
        """Blocking reliable exchange. Raises RequestTimeout on deadline."""
        timeout = timeout or self.default_timeout
        msg_id = f"{self.me}-{next(_counter)}-{uuid.uuid4().hex[:8]}"
        ev = threading.Event()
        with self._lock:
            self._inflight[msg_id] = ev
        deadline = time.monotonic() + timeout
        attempt = 0
        try:
            while time.monotonic() < deadline:
                # (re)send the request — receiver-side dedup makes this safe
                self.net.send(Message(msg_id, attempt, "REQ", self.me, target,
                                      topic, payload))
                attempt += 1
                if ev.wait(self.retry_interval):
                    break
                # pull path: query for a result the push may have lost
                self.net.send(Message(msg_id, attempt, "QUERY", self.me,
                                      target, topic, b""))
                attempt += 1
                if ev.wait(self.retry_interval):
                    break
            else:
                raise RequestTimeout(
                    f"{self.me} -> {target} [{topic}] timed out after "
                    f"{timeout}s", target=target, topic=topic,
                    timeout=timeout)
            with self._lock:
                return self._responses.pop(msg_id)
        finally:
            with self._lock:
                self._inflight.pop(msg_id, None)
                self._responses.pop(msg_id, None)

    def notify(self, target: str, topic: str, payload: bytes) -> None:
        """Fire-and-forget EVENT (metric streaming uses this)."""
        msg_id = f"{self.me}-ev-{next(_counter)}-{uuid.uuid4().hex[:8]}"
        self.net.send(Message(msg_id, 0, "EVENT", self.me, target, topic,
                              payload))

    # ------------------------------------------------------------ pump
    def _pump(self) -> None:
        last_reap = time.monotonic()
        while not self._stop.is_set():
            try:
                msg = self.inbox.get(timeout=0.05)
            except Exception:
                # idle tick: reap even when no requests arrive, so an
                # endpoint that goes quiet releases its cached payloads
                if time.monotonic() - last_reap > 1.0:
                    self._cache.reap()
                    last_reap = time.monotonic()
                continue
            if msg.kind == "REQ":
                # handlers run off-pump: a relaying handler (LGS/LGC) issues
                # its own reliable request and must not block RESP delivery
                t = threading.Thread(target=self._handle_request, args=(msg,),
                                     daemon=True)
                t.start()
            elif msg.kind == "QUERY":
                self._handle_query(msg)
            elif msg.kind == "RESP":
                if msg.payload == _PENDING:
                    continue
                with self._lock:
                    ev = self._inflight.get(msg.msg_id)
                    if ev is not None and msg.msg_id not in self._responses:
                        self._responses[msg.msg_id] = msg.payload
                        ev.set()
            elif msg.kind == "EVENT":
                handler = self._match_handler(msg.topic)
                if handler is not None:
                    handler(msg)

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=1.0)

"""ReliableMessage (paper §4.1), faithfully:

  1. the requester sends the request; if delivery fails it retries a moment
     later, repeating until sent or until the timeout elapses (=> abort);
  2. once sent, the requester waits for the response; the peer pushes the
     result as soon as processing finishes; *in parallel* the requester
     periodically sends QUERY messages to pull the result, so the response
     arrives through whichever path survives (push or query-pull);
  3. the receiver deduplicates by msg_id — a request is executed exactly
     once no matter how many retries/duplicates arrive — and keeps the
     result cached so late queries can still fetch it.
"""
from __future__ import annotations

import itertools
import threading
import time
import uuid
from typing import Callable, Dict, Optional, Tuple

from repro.runtime.transport import Message, Network


class RequestTimeout(RuntimeError):
    """Raised when a reliable exchange exceeds its deadline.

    Carries the exchange coordinates so callers can *demote* the timeout
    to a recorded per-node failure (the FL fault-tolerance contract)
    instead of aborting the job — e.g. the SuperNode keeps serving and
    the server logs ``(node, "timeout")`` for the round.
    """

    def __init__(self, message: str, *, target: Optional[str] = None,
                 topic: Optional[str] = None,
                 timeout: Optional[float] = None):
        super().__init__(message)
        self.target = target
        self.topic = topic
        self.timeout = timeout


_PENDING = b"\x00__PENDING__"
_counter = itertools.count()


class ReliableMessenger:
    """One per endpoint; handles both the requester and responder roles."""

    def __init__(self, network: Network, me: str,
                 retry_interval: float = 0.02, default_timeout: float = 10.0,
                 result_ttl: float = 60.0):
        self.net = network
        self.me = me
        self.retry_interval = retry_interval
        self.default_timeout = default_timeout
        # how long a responder keeps a computed result for late QUERYs /
        # duplicate REQs; afterwards the entry (and its dedup mark) is
        # reaped so a long-lived endpoint's cache stays bounded
        self.result_ttl = result_ttl
        self.inbox = network.register(me)
        self._results: Dict[str, Tuple[float, bytes]] = {}   # responder cache
        self._inflight: Dict[str, threading.Event] = {}
        self._responses: Dict[str, bytes] = {}        # requester: msg_id -> resp
        self._seen: Dict[str, float] = {}             # responder dedup (ts)
        self._executing: set = set()                  # handlers in flight
        self._handlers: Dict[str, Callable[[Message], bytes]] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name=f"rm-{me}")
        self._thread.start()

    def _send(self, msg_id: str, kind: str, receiver: str, topic: str,
              payload: bytes, attempt: int = 0) -> None:
        self.net.send(Message(msg_id, attempt, kind, self.me, receiver, topic,
                              payload))

    # ------------------------------------------------------------ responder
    def register_handler(self, topic: str, fn: Callable[[Message], bytes]) -> None:
        with self._lock:
            self._handlers[topic] = fn

    def _reap_results(self) -> None:  # guarded-by: _lock
        """Drop cached result payloads past result_ttl; keep the (tiny)
        dedup marks 10x longer.  Caller holds the lock.  A duplicate REQ
        arriving after the payload is reaped but within the mark's
        lifetime is still recognized as seen — the handler never
        re-executes, the requester just times out (safe) instead of
        triggering a second, possibly non-idempotent, execution."""
        now = time.monotonic()
        cutoff = now - self.result_ttl
        for mid in [m for m, (ts, _) in self._results.items() if ts < cutoff]:
            del self._results[mid]
        mark_cutoff = now - 10 * self.result_ttl
        for mid in [m for m, ts in self._seen.items()
                    if isinstance(ts, float) and ts < mark_cutoff
                    and m not in self._results
                    and m not in self._executing]:
            del self._seen[mid]

    def _handle_request(self, msg: Message) -> None:
        with self._lock:
            if msg.msg_id in self._seen:            # dedup: execute once
                cached = self._results.get(msg.msg_id)
                if cached is not None:              # re-push cached result
                    self._send(msg.msg_id, "RESP", msg.sender, msg.topic,
                               cached[1], attempt=msg.attempt)
                return
            handler = self._match_handler(msg.topic)
            if handler is None:
                # no handler *yet* (job process still starting): stay unseen
                # so a retry executes once the handler is registered
                return
            self._seen[msg.msg_id] = time.monotonic()
            # pin the mark while the handler runs: a long-running handler
            # must not have its dedup mark reaped mid-flight (a retry REQ
            # would then re-execute a non-idempotent operation)
            self._executing.add(msg.msg_id)
        try:
            result = handler(msg)                    # may take a while
        except BaseException:
            with self._lock:
                self._executing.discard(msg.msg_id)
            raise
        with self._lock:
            self._results[msg.msg_id] = (time.monotonic(), result)
            self._executing.discard(msg.msg_id)
            self._reap_results()
        self._send(msg.msg_id, "RESP", msg.sender, msg.topic, result,
                   attempt=msg.attempt)

    def _match_handler(self, topic: str):
        if topic in self._handlers:
            return self._handlers[topic]
        for t, fn in self._handlers.items():
            if t.endswith("*") and topic.startswith(t[:-1]):
                return fn
        return None

    def _handle_query(self, msg: Message) -> None:
        with self._lock:
            cached = self._results.get(msg.msg_id)
            self._reap_results()
        self._send(msg.msg_id, "RESP", msg.sender, msg.topic,
                   cached[1] if cached is not None else _PENDING,
                   attempt=msg.attempt)

    # ------------------------------------------------------------ requester
    def request(self, target: str, topic: str, payload: bytes,
                timeout: Optional[float] = None) -> bytes:
        """Blocking reliable exchange. Raises RequestTimeout on deadline."""
        timeout = timeout or self.default_timeout
        msg_id = f"{self.me}-{next(_counter)}-{uuid.uuid4().hex[:8]}"
        ev = threading.Event()
        with self._lock:
            self._inflight[msg_id] = ev
        deadline = time.monotonic() + timeout
        attempt = 0
        try:
            while time.monotonic() < deadline:
                # (re)send the request — receiver-side dedup makes this safe
                self.net.send(Message(msg_id, attempt, "REQ", self.me, target,
                                      topic, payload))
                attempt += 1
                if ev.wait(self.retry_interval):
                    break
                # pull path: query for a result the push may have lost
                self.net.send(Message(msg_id, attempt, "QUERY", self.me,
                                      target, topic, b""))
                attempt += 1
                if ev.wait(self.retry_interval):
                    break
            else:
                raise RequestTimeout(
                    f"{self.me} -> {target} [{topic}] timed out after "
                    f"{timeout}s", target=target, topic=topic,
                    timeout=timeout)
            with self._lock:
                return self._responses.pop(msg_id)
        finally:
            with self._lock:
                self._inflight.pop(msg_id, None)
                self._responses.pop(msg_id, None)

    def notify(self, target: str, topic: str, payload: bytes) -> None:
        """Fire-and-forget EVENT (metric streaming uses this)."""
        msg_id = f"{self.me}-ev-{next(_counter)}-{uuid.uuid4().hex[:8]}"
        self.net.send(Message(msg_id, 0, "EVENT", self.me, target, topic,
                              payload))

    # ------------------------------------------------------------ pump
    def _pump(self) -> None:
        last_reap = time.monotonic()
        while not self._stop.is_set():
            try:
                msg = self.inbox.get(timeout=0.05)
            except Exception:
                # idle tick: reap even when no requests arrive, so an
                # endpoint that goes quiet releases its cached payloads
                if time.monotonic() - last_reap > 1.0:
                    with self._lock:
                        self._reap_results()
                    last_reap = time.monotonic()
                continue
            if msg.kind == "REQ":
                # handlers run off-pump: a relaying handler (LGS/LGC) issues
                # its own reliable request and must not block RESP delivery
                t = threading.Thread(target=self._handle_request, args=(msg,),
                                     daemon=True)
                t.start()
            elif msg.kind == "QUERY":
                self._handle_query(msg)
            elif msg.kind == "RESP":
                if msg.payload == _PENDING:
                    continue
                with self._lock:
                    ev = self._inflight.get(msg.msg_id)
                    if ev is not None and msg.msg_id not in self._responses:
                        self._responses[msg.msg_id] = msg.payload
                        ev.set()
            elif msg.kind == "EVENT":
                handler = self._match_handler(msg.topic)
                if handler is not None:
                    handler(msg)

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=1.0)

"""Job model + resource-aware multi-job scheduler state (paper §3.1).

A job is deployed to all participating sites; its processes form a Job
Network that exists only for the job's lifetime.  Multiple jobs run
concurrently over the same server/clients without extra "ports" — topics
are namespaced ``job/<job_id>/...`` on the shared transport.
"""
from __future__ import annotations

import threading
import uuid
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional


class JobStatus(str, Enum):
    SUBMITTED = "SUBMITTED"
    SCHEDULED = "SCHEDULED"
    DEPLOYING = "DEPLOYING"
    RUNNING = "RUNNING"
    COMPLETED = "COMPLETED"
    ABORTED = "ABORTED"
    FAILED = "FAILED"


@dataclass
class JobSpec:
    name: str
    # the application bundle ("custom code deployment"): opaque factory
    # callables the CCP/SCP instantiate at deploy time.
    server_app_fn: Callable[[], Any]
    client_app_fn: Callable[[str], Any]     # site name -> ClientApp
    min_sites: int = 1
    resources: Dict[str, float] = field(default_factory=lambda: {"gpu": 1.0})
    timeout_s: float = 120.0
    job_id: str = field(default_factory=lambda: uuid.uuid4().hex[:10])


@dataclass
class JobRecord:
    spec: JobSpec
    status: JobStatus = JobStatus.SUBMITTED
    sites: List[str] = field(default_factory=list)
    result: Any = None
    error: Optional[str] = None
    done: threading.Event = field(default_factory=threading.Event)

    @property
    def job_id(self) -> str:
        return self.spec.job_id


class ResourcePool:
    """Per-site resource accounting for the concurrent-job scheduler."""

    def __init__(self, capacity: Dict[str, float]):
        self.capacity = dict(capacity)
        self.used: Dict[str, float] = {k: 0.0 for k in capacity}
        self._lock = threading.Lock()

    def try_acquire(self, req: Dict[str, float]) -> bool:
        with self._lock:
            for k, v in req.items():
                if self.used.get(k, 0.0) + v > self.capacity.get(k, 0.0) + 1e-9:
                    return False
            for k, v in req.items():
                self.used[k] = self.used.get(k, 0.0) + v
            return True

    def release(self, req: Dict[str, float]) -> None:
        with self._lock:
            for k, v in req.items():
                self.used[k] = max(0.0, self.used.get(k, 0.0) - v)

"""Architecture registry — one module per assigned architecture.

Importing this package populates ``repro.config.ARCH_REGISTRY`` /
``SMOKE_REGISTRY``; select with ``--arch <id>`` anywhere.
"""
from repro.configs import (  # noqa: F401
    deepseek_v2_236b,
    flower_quickstart,
    granite_34b,
    granite_moe_1b_a400m,
    h2o_danube_1_8b,
    internvl2_1b,
    qwen3_32b,
    recurrentgemma_2b,
    whisper_medium,
    xlstm_350m,
    yi_34b,
)

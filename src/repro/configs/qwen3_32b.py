"""Qwen3-32B [hf:Qwen/Qwen3-8B family scaling].

64L d_model=5120 64H (GQA kv=8, head_dim=128) d_ff=25600 vocab=151936,
per-head q/k RMSNorm (qk_norm), full attention.
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "qwen3-32b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="hf:Qwen/Qwen3-8B",
        num_layers=64,
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        qk_norm=True,
    )


register_arch(ARCH_ID, full, smoke)

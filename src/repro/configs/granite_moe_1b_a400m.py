"""Granite-3.0 1B-A400M base [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) vocab=49155, MoE 32 experts top-8,
per-expert d_ff=512.
"""
from repro.config import ModelConfig, MoEConfig, register_arch

ARCH_ID = "granite-moe-1b-a400m"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=49155,
        moe=MoEConfig(num_experts=32, experts_per_token=8, d_ff=512,
                      capacity_factor=1.25),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff=64,
                      capacity_factor=1.5),
        tie_embeddings=True,
    )


register_arch(ARCH_ID, full, smoke)

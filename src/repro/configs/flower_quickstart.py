"""The paper's own demo workload (§5.1): Flower PyTorch-Quickstart analogue.

The paper runs a small CIFAR CNN through Flower-on-FLARE.  Our JAX analogue
is a small MLP-classifier config used by the FL examples/benchmarks — it is
*not* one of the 10 assigned architectures but reproduces the paper's own
experiment at its original scale.  Registered as ``flower-quickstart`` with
a transformer-shaped smoke twin so every registry entry supports the same
tooling.
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "flower-quickstart"


def full() -> ModelConfig:
    # a deliberately small decoder (the paper's demo model is ~100k params);
    # FL benchmarks use repro.fl.quickstart_model instead for the CNN-like MLP
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="paper §5.1 (PyTorch quickstart analogue)",
        num_layers=4,
        d_model=256,
        num_heads=8,
        num_kv_heads=8,
        d_ff=1024,
        vocab_size=4096,
        remat=False,
        fsdp_hint=False,
    )


def smoke() -> ModelConfig:
    return full().replace(name=ARCH_ID + "-smoke", num_layers=2, d_model=128,
                          num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512)


register_arch(ARCH_ID, full, smoke)

"""Yi-34B [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 — llama-style GQA,
full attention (long_500k skipped per DESIGN.md).
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "yi-34b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="arXiv:2403.04652",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        rope_theta=5000000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
    )


register_arch(ARCH_ID, full, smoke)

"""Whisper-medium [arXiv:2212.04356] — transformer backbone.

Enc-dec, 24+24L d_model=1024 16H d_ff=4096 vocab=51865.  The mel-spectrogram
+ conv feature extractor is the STUB frontend: ``input_specs()`` provides
1500 precomputed frame embeddings (30 s of audio after the conv stack's 2x
downsampling).  GeLU MLPs, LayerNorm (as in the original), MHA (kv=16).
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "whisper-medium"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        source="arXiv:2212.04356",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        act="gelu",
        gated_mlp=False,   # Whisper uses plain GELU MLPs => ~769M as published
        norm="layernorm",
        is_encoder_decoder=True,
        num_encoder_layers=24,
        encoder_seq=1500,
        frontend="audio",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        act="gelu",
        norm="layernorm",
        is_encoder_decoder=True,
        num_encoder_layers=2,
        encoder_seq=32,
        frontend="audio",
    )


register_arch(ARCH_ID, full, smoke)

"""InternVL2-1B [arXiv:2404.16821] — language backbone (Qwen2-0.5B class).

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The InternViT
vision encoder + MLP projector are the STUB frontend (per the assignment
carve-out): ``input_specs()`` provides 256 precomputed patch embeddings.
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "internvl2-1b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        source="arXiv:2404.16821",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        frontend="vision",
        num_prefix_tokens=256,
        tie_embeddings=True,
        rope_theta=1000000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        frontend="vision",
        num_prefix_tokens=8,
        tie_embeddings=True,
    )


register_arch(ARCH_ID, full, smoke)

"""Granite 34B Code [arXiv:2405.04324].

88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152 — code model,
llama-arch trunk with multi-query attention.  Deepest assigned arch; the
scan-over-layers trunk keeps its HLO the same size as a 2-layer model's.
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "granite-34b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="arXiv:2405.04324",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        d_ff=24576,
        vocab_size=49152,
        act="gelu",
        gated_mlp=False,   # GPTBigCode-style 2-matrix MLP => ~34B as published
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        d_ff=256,
        vocab_size=512,
    )


register_arch(ARCH_ID, full, smoke)

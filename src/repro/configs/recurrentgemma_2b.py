"""RecurrentGemma-2B [arXiv:2402.19427] (Griffin architecture).

26L d_model=2560, pattern (rglru, rglru, local-attention) 1:2 attention to
recurrence, 10H MQA (kv=1) local window 2048, GeGLU d_ff=7680,
vocab=256000, RG-LRU width 2560.  Sub-quadratic => runs long_500k.
"""
from repro.config import LOCAL_ATTN, RGLRU, ModelConfig, register_arch

ARCH_ID = "recurrentgemma-2b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        source="arXiv:2402.19427",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        act="gelu",
        block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
        window=2048,
        lru_width=2560,
        conv_width=4,
        tie_embeddings=True,
        logits_softcap=30.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        num_layers=3,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        act="gelu",
        block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
        window=16,
        lru_width=128,
        tie_embeddings=True,
        logits_softcap=30.0,
    )


register_arch(ARCH_ID, full, smoke)

"""DeepSeek-V2 236B [arXiv:2405.04434].

60L d_model=5120 128H, MLA (kv_lora=512, q_lora=1536, nope=128, rope=64),
MoE: 2 shared + 160 routed top-6, per-expert d_ff=1536, first layer dense
(d_ff=12288), vocab 102400.  ~236B total / ~21B active params.
"""
from repro.config import MLAConfig, ModelConfig, MoEConfig, register_arch

ARCH_ID = "deepseek-v2-236b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        source="arXiv:2405.04434",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=12288,                     # dense first layer
        vocab_size=102400,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=160, experts_per_token=6,
                      num_shared_experts=2, d_ff=1536,
                      first_dense_layers=1, capacity_factor=1.25),
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=64,
                      qk_nope_head_dim=32, qk_rope_head_dim=16,
                      v_head_dim=32),
        moe=MoEConfig(num_experts=4, experts_per_token=2,
                      num_shared_experts=1, d_ff=64,
                      first_dense_layers=1, capacity_factor=1.5),
    )


register_arch(ARCH_ID, full, smoke)

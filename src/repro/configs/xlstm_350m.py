"""xLSTM 350M [arXiv:2405.04517].

24L d_model=1024 4H vocab=50304, d_ff=0 (blocks carry their own
projections).  Block ratio mLSTM:sLSTM = 7:1 (the paper's xLSTM[7:1]).
Pure recurrent => runs long_500k.
"""
from repro.config import MLSTM, SLSTM, ModelConfig, register_arch

ARCH_ID = "xlstm-350m"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        source="arXiv:2405.04517",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block_pattern=(MLSTM,) * 7 + (SLSTM,),
        rope=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        block_pattern=(MLSTM, SLSTM),
        rope=False,
    )


register_arch(ARCH_ID, full, smoke)

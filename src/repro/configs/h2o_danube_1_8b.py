"""H2O-Danube 1.8B [arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000; llama+mistral mix
with sliding-window attention (window 4096).  Sub-quadratic at decode =>
runs the long_500k shape.
"""
from repro.config import ModelConfig, register_arch

ARCH_ID = "h2o-danube-1.8b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="arXiv:2401.16818",
        num_layers=24,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        window=4096,
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        window=16,
    )


register_arch(ARCH_ID, full, smoke)

"""Local gRPC Server analogue (paper Fig. 4, client side).

In the paper, each Flower SuperNode is re-pointed at a *Local gRPC Server*
(LGS) inside the FLARE client instead of the remote SuperLink; the LGS
forwards each gRPC unary call over FLARE's ReliableMessage to the FLARE
server, whose LGC completes the call against the real SuperLink.

Here the LGS is a :class:`FleetConnection` whose ``unary`` serializes the
call and sends it through the Job-Network (hops 1–3 of the six-hop path);
the response retraces hops 4–6.  The SuperNode is *unchanged* — it just
received a different connection object, exactly like pointing gRPC at
localhost.
"""
from __future__ import annotations

import msgpack

from repro.core.framing import pack_unary
from repro.core.superlink import FleetConnection
from repro.runtime.ccp import JobContext
from repro.runtime.reliable import RequestTimeout


class LGSConnection(FleetConnection):
    def __init__(self, ctx: JobContext):
        self.ctx = ctx

    def unary(self, method: str, request: bytes) -> bytes:
        # the canonical unary envelope (shared with repro.core.framing's
        # socket transport tooling, which carries the same call as a
        # typed REQ header + raw body instead)
        payload = pack_unary(method, request)
        # hop 1: SuperNode -> LGS (this call); hops 2-3: FLARE client ->
        # FLARE server (reliable, SCP-relayed) -> LGC.  A ReliableMessage
        # RequestTimeout propagates as-is: the SuperNode treats it as
        # retryable and the server's round deadline records the miss as a
        # per-node failure — the round itself never aborts.
        resp = self.ctx.request("server", "flower/unary", payload)
        d = msgpack.unpackb(resp, raw=False)
        if d.get("e"):
            if d.get("k") == "timeout":
                raise RequestTimeout(f"LGC timeout: {d['e']}")
            raise RuntimeError(f"LGC error: {d['e']}")
        return d["r"]

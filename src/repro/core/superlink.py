"""Flower Next long-running components (paper §3.2, Fig. 3).

:class:`SuperLink` decouples the communication layer from the ServerApp:
the ServerApp drives rounds through the Driver API, SuperNodes pull TaskIns
and push TaskRes through the Fleet API.  Both APIs are **byte-level,
gRPC-shaped** (unary method name + request bytes -> response bytes), so a
connection can be the in-process :class:`NativeConnection` *or* the
FLARE-routed LGS/LGC pair — with identical semantics (Fig. 5 claim).

Fleet methods:   register, pull_task_ins, push_task_res

Timeout semantics (the fault-tolerance contract):

- The result store is a **completion queue**: :meth:`SuperLink.pull_any`
  blocks on the shared condition variable until *any* of a set of tasks
  completes, so one slow node never serializes the others behind it.
- All pulls of a round share **one deadline**.  When it passes, the
  un-arrived tasks are :meth:`discard`-ed: never-delivered TaskIns are
  dropped from the node queues, in-flight tasks leave a tombstone so a
  late TaskRes is silently dropped instead of leaking into (and possibly
  corrupting) a later round.
- :class:`SuperNode` treats transport errors (e.g. a ReliableMessage
  :class:`~repro.runtime.reliable.RequestTimeout` on the FLARE-bridged
  path) as retryable: the node keeps serving and the *server's* per-round
  deadline demotes the miss to a per-node failure.
"""
from __future__ import annotations

import threading
import time
import uuid
from collections import deque
from typing import Deque, Dict, Iterable, Iterator, List, Optional, Set, Tuple

import msgpack

from repro.fl.client import ClientApp
from repro.fl.messages import TaskRes, encode_task_res
from repro.fl.server import Driver
from repro.runtime.reliable import RequestTimeout

# Tombstones for in-flight tasks whose round already gave up on them are
# pruned after this many seconds; a responsive-but-slow node clears its own
# tombstone the moment its late result arrives (and is dropped).
_TOMBSTONE_TTL = 120.0


class SuperLink:
    """Hub: per-node task queues + completion queue. Thread-safe."""

    def __init__(self):
        self._task_queues: Dict[str, Deque[Tuple[str, bytes]]] = {}  # guarded-by: _lock
        self._results: Dict[str, bytes] = {}                 # guarded-by: _results_cv
        self._expired: Dict[str, float] = {}                 # guarded-by: _results_cv
        self._results_cv = threading.Condition()
        self._nodes: Dict[str, float] = {}                   # guarded-by: _lock
        self._lock = threading.Lock()
        self.stats = {"late_dropped": 0, "discarded_ins": 0}  # guarded-by: _results_cv

    # ------------------------------------------------------------ fleet API
    def fleet_unary(self, method: str, request: bytes) -> bytes:
        if method == "register":
            node_id = request.decode()
            with self._lock:
                # monotonic: the heartbeat feeds liveness arithmetic and
                # must not jump with the wall clock (NTP steps)
                self._nodes[node_id] = time.monotonic()
                self._task_queues.setdefault(node_id, deque())
            return b"OK"
        if method == "pull_task_ins":
            node_id = request.decode()
            with self._lock:
                q = self._task_queues.setdefault(node_id, deque())
                task_id, task = q.popleft() if q else ("", b"")
            return msgpack.packb({"id": task_id, "task": task},
                                 use_bin_type=True)
        if method == "push_task_res":
            d = msgpack.unpackb(request, raw=False)
            with self._results_cv:
                if d["id"] in self._expired:
                    # round already gave up on this task: drop the late
                    # result so it cannot leak into a later round
                    del self._expired[d["id"]]
                    self.stats["late_dropped"] += 1
                    return b"LATE"
                self._results[d["id"]] = d["res"]
                self._results_cv.notify_all()
            return b"OK"
        raise ValueError(f"unknown fleet method {method!r}")

    # ------------------------------------------------------------ driver API
    def node_ids(self) -> List[str]:
        with self._lock:
            return sorted(self._nodes)

    def push_task_ins(self, node_id: str, task: bytes) -> str:
        task_id = uuid.uuid4().hex
        with self._lock:
            self._task_queues.setdefault(node_id, deque()).append(
                (task_id, task))
        return task_id

    def pull_any(self, task_ids: Iterable[str],
                 deadline: float) -> Optional[Tuple[str, bytes]]:
        """Completion queue: block until any of ``task_ids`` has a result
        or ``deadline`` (``time.monotonic()`` timestamp) passes.

        Returns ``(task_id, res_bytes)`` — the result is popped — or
        ``None`` on deadline.  The caller owns the remaining ids and must
        eventually :meth:`discard` the ones it gives up on.
        """
        ids = list(task_ids)
        with self._results_cv:
            while True:
                for tid in ids:
                    if tid in self._results:
                        return tid, self._results.pop(tid)
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                self._results_cv.wait(min(remaining, 0.1))

    def pull_task_res(self, task_id: str, timeout: float) -> bytes:
        got = self.pull_any([task_id], time.monotonic() + timeout)
        if got is None:
            self.discard([task_id])
            raise TimeoutError(f"task {task_id} timed out")
        return got[1]

    def discard(self, task_ids: Iterable[str]) -> None:
        """Give up on tasks: reap undelivered TaskIns from the node queues
        and tombstone in-flight ones so their late TaskRes is dropped."""
        ids = set(task_ids)
        if not ids:
            return
        undelivered: Set[str] = set()
        with self._lock:
            for node, q in self._task_queues.items():
                if any(tid in ids for tid, _ in q):
                    kept = deque(e for e in q if e[0] not in ids)
                    undelivered.update(tid for tid, _ in q if tid in ids)
                    self._task_queues[node] = kept
        now = time.monotonic()
        with self._results_cv:
            self.stats["discarded_ins"] += len(undelivered)
            for tid in ids:
                if self._results.pop(tid, None) is not None:
                    continue                     # landed but unwanted: done
                if tid not in undelivered:
                    self._expired[tid] = now     # delivered, still in flight
            cutoff = now - _TOMBSTONE_TTL
            for tid in [t for t, ts in self._expired.items() if ts < cutoff]:
                del self._expired[tid]


class SuperLinkDriver(Driver):
    """Driver API implementation over a SuperLink instance.

    ``send_and_receive_iter`` is a **native streaming transport**: results
    yield in arrival order the moment they land on the completion queue,
    so decode+accumulate overlaps the stragglers' compute, and the whole
    batch shares a single deadline.
    """

    def __init__(self, superlink: SuperLink, expected_nodes: int = 0,
                 join_timeout: float = 30.0):
        self.link = superlink
        if expected_nodes:
            deadline = time.monotonic() + join_timeout
            while (len(self.link.node_ids()) < expected_nodes
                   and time.monotonic() < deadline):
                time.sleep(0.005)

    def node_ids(self) -> List[str]:
        return self.link.node_ids()

    def send_and_receive_iter(self, tasks: Dict[str, bytes],
                              timeout: float) -> Iterator[Tuple[str, bytes]]:
        ids = {self.link.push_task_ins(node, t): node
               for node, t in sorted(tasks.items())}
        deadline = time.monotonic() + timeout
        pending = set(ids)
        try:
            while pending:
                got = self.link.pull_any(pending, deadline)
                if got is None:
                    break                      # deadline: pending are lost
                tid, res = got
                pending.discard(tid)
                yield ids[tid], res
        finally:
            # also runs on generator close: never strand orphaned state
            if pending:
                self.link.discard(pending)

    def send_and_receive(self, tasks: Dict[str, bytes],
                         timeout: float) -> Dict[str, bytes]:
        """Blocking batch API: all pulls share ONE deadline, so the total
        wait is <= timeout (+ scheduling ε), never N x timeout."""
        out = {node: res for node, res in
               self.send_and_receive_iter(tasks, timeout)}
        if len(out) < len(tasks):
            missing = sorted(set(tasks) - set(out))
            raise TimeoutError(
                f"tasks for nodes {missing} timed out after {timeout}s")
        return out


# ---------------------------------------------------------------------------
# connections (the pluggable wire)
# ---------------------------------------------------------------------------
class FleetConnection:
    """gRPC-shaped unary interface a SuperNode talks through."""

    def unary(self, method: str, request: bytes) -> bytes:
        raise NotImplementedError


class NativeConnection(FleetConnection):
    """Direct in-process connection (Flower running 'alone')."""

    def __init__(self, superlink: SuperLink):
        self.link = superlink

    def unary(self, method: str, request: bytes) -> bytes:
        return self.link.fleet_unary(method, request)


class SuperNode:
    """Long-running client host: polls for tasks, runs the ClientApp.

    Transport failures (a dropped fleet call, a ReliableMessage timeout on
    the FLARE-bridged path) do NOT kill the node: the loop records them in
    ``transport_errors``, backs off briefly, and keeps serving — the
    server's round deadline turns any miss into a per-node failure.
    """

    def __init__(self, node_id: str, client_app: ClientApp,
                 connection: FleetConnection, poll_interval: float = 0.005):
        self.node_id = node_id
        self.app = client_app
        self.conn = connection
        self.poll_interval = poll_interval
        self.transport_errors = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        self.conn.unary("register", self.node_id.encode())
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"supernode-{self.node_id}")
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                resp = self.conn.unary("pull_task_ins", self.node_id.encode())
            except (RequestTimeout, ConnectionError, OSError):
                self.transport_errors += 1
                self._stop.wait(10 * self.poll_interval)
                continue
            d = msgpack.unpackb(resp, raw=False)
            if not d["id"]:
                self._stop.wait(self.poll_interval)
                continue
            try:
                res = self.app.handle(d["task"], cid=self.node_id)
            except Exception as e:  # noqa: BLE001 — mod/decode blew up
                # outside ClientApp.handle's own guard: report the real
                # error instead of dying and ghosting as (node, "timeout")
                res = encode_task_res(TaskRes("error", 0, b"",
                                              error=repr(e)))
            try:
                self.conn.unary("push_task_res",
                                msgpack.packb({"id": d["id"], "res": res},
                                              use_bin_type=True))
            except (RequestTimeout, ConnectionError, OSError):
                # undeliverable result: the server's deadline records the
                # miss as (node, "timeout"); keep serving later rounds
                self.transport_errors += 1
                self._stop.wait(10 * self.poll_interval)

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

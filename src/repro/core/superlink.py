"""Flower Next long-running components (paper §3.2, Fig. 3).

:class:`SuperLink` decouples the communication layer from the ServerApp:
the ServerApp drives rounds through the Driver API, SuperNodes pull TaskIns
and push TaskRes through the Fleet API.  Both APIs are **byte-level,
gRPC-shaped** (unary method name + request bytes -> response bytes), so a
connection can be the in-process :class:`NativeConnection` *or* the
FLARE-routed LGS/LGC pair — with identical semantics (Fig. 5 claim).

Fleet methods:   register, pull_task_ins, push_task_res
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
import uuid
from typing import Callable, Dict, List, Optional, Tuple

import msgpack

from repro.fl.client import ClientApp
from repro.fl.server import Driver


class SuperLink:
    """Hub: per-node task queues + result store. Thread-safe."""

    def __init__(self):
        self._task_queues: Dict[str, "queue.Queue[Tuple[str, bytes]]"] = {}
        self._results: Dict[str, bytes] = {}
        self._results_cv = threading.Condition()
        self._nodes: Dict[str, float] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------ fleet API
    def fleet_unary(self, method: str, request: bytes) -> bytes:
        if method == "register":
            node_id = request.decode()
            with self._lock:
                self._nodes[node_id] = time.time()
                self._task_queues.setdefault(node_id, queue.Queue())
            return b"OK"
        if method == "pull_task_ins":
            node_id = request.decode()
            with self._lock:
                q = self._task_queues.setdefault(node_id, queue.Queue())
            try:
                task_id, task = q.get_nowait()
                return msgpack.packb({"id": task_id, "task": task},
                                     use_bin_type=True)
            except queue.Empty:
                return msgpack.packb({"id": "", "task": b""}, use_bin_type=True)
        if method == "push_task_res":
            d = msgpack.unpackb(request, raw=False)
            with self._results_cv:
                self._results[d["id"]] = d["res"]
                self._results_cv.notify_all()
            return b"OK"
        raise ValueError(f"unknown fleet method {method!r}")

    # ------------------------------------------------------------ driver API
    def node_ids(self) -> List[str]:
        with self._lock:
            return sorted(self._nodes)

    def push_task_ins(self, node_id: str, task: bytes) -> str:
        task_id = uuid.uuid4().hex
        with self._lock:
            q = self._task_queues.setdefault(node_id, queue.Queue())
        q.put((task_id, task))
        return task_id

    def pull_task_res(self, task_id: str, timeout: float) -> bytes:
        deadline = time.monotonic() + timeout
        with self._results_cv:
            while task_id not in self._results:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"task {task_id} timed out")
                self._results_cv.wait(min(remaining, 0.1))
            return self._results.pop(task_id)


class SuperLinkDriver(Driver):
    """Driver API implementation over a SuperLink instance."""

    def __init__(self, superlink: SuperLink, expected_nodes: int = 0,
                 join_timeout: float = 30.0):
        self.link = superlink
        if expected_nodes:
            deadline = time.monotonic() + join_timeout
            while (len(self.link.node_ids()) < expected_nodes
                   and time.monotonic() < deadline):
                time.sleep(0.005)

    def node_ids(self) -> List[str]:
        return self.link.node_ids()

    def send_and_receive(self, tasks: Dict[str, bytes],
                         timeout: float) -> Dict[str, bytes]:
        ids = {node: self.link.push_task_ins(node, t)
               for node, t in sorted(tasks.items())}
        return {node: self.link.pull_task_res(tid, timeout)
                for node, tid in ids.items()}


# ---------------------------------------------------------------------------
# connections (the pluggable wire)
# ---------------------------------------------------------------------------
class FleetConnection:
    """gRPC-shaped unary interface a SuperNode talks through."""

    def unary(self, method: str, request: bytes) -> bytes:
        raise NotImplementedError


class NativeConnection(FleetConnection):
    """Direct in-process connection (Flower running 'alone')."""

    def __init__(self, superlink: SuperLink):
        self.link = superlink

    def unary(self, method: str, request: bytes) -> bytes:
        return self.link.fleet_unary(method, request)


class SuperNode:
    """Long-running client host: polls for tasks, runs the ClientApp."""

    def __init__(self, node_id: str, client_app: ClientApp,
                 connection: FleetConnection, poll_interval: float = 0.005):
        self.node_id = node_id
        self.app = client_app
        self.conn = connection
        self.poll_interval = poll_interval
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        self.conn.unary("register", self.node_id.encode())
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"supernode-{self.node_id}")
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            resp = self.conn.unary("pull_task_ins", self.node_id.encode())
            d = msgpack.unpackb(resp, raw=False)
            if not d["id"]:
                time.sleep(self.poll_interval)
                continue
            res = self.app.handle(d["task"], cid=self.node_id)
            self.conn.unary("push_task_res",
                            msgpack.packb({"id": d["id"], "res": res},
                                          use_bin_type=True))

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

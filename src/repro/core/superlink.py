"""Flower Next long-running components (paper §3.2, Fig. 3).

:class:`SuperLink` decouples the communication layer from the ServerApp:
the ServerApp drives rounds through the Driver API, SuperNodes pull TaskIns
and push TaskRes through the Fleet API.  Both APIs are **byte-level,
gRPC-shaped** (unary method name + request bytes -> response bytes), so a
connection can be the in-process :class:`NativeConnection` *or* the
FLARE-routed LGS/LGC pair — with identical semantics (Fig. 5 claim).

Fleet methods:   register, pull_task_ins, push_task_res

Timeout semantics (the fault-tolerance contract):

- The result store is a **completion queue**: :meth:`SuperLink.pull_any`
  blocks on the shared condition variable until *any* of a set of tasks
  completes, so one slow node never serializes the others behind it.
- All pulls of a round share **one deadline**.  When it passes, the
  un-arrived tasks are :meth:`discard`-ed: never-delivered TaskIns are
  dropped from the node queues, in-flight tasks leave a tombstone so a
  late TaskRes is silently dropped instead of leaking into (and possibly
  corrupting) a later round.
- :class:`SuperNode` treats transport errors (e.g. a ReliableMessage
  :class:`~repro.runtime.reliable.RequestTimeout` on the FLARE-bridged
  path) as retryable: the node keeps serving and the *server's* per-round
  deadline demotes the miss to a per-node failure.
"""
from __future__ import annotations

import threading
import time
import uuid
from collections import deque
from typing import Deque, Dict, Iterable, Iterator, List, Optional, Set, Tuple

import msgpack

from repro.fl import agg_kernels as kernels
from repro.fl.client import ClientApp
from repro.fl.flat import PartialSum
from repro.fl.messages import (EvaluateRes, FitRes, TaskIns, TaskRes,
                               decode_evaluate_res, decode_fit_res,
                               decode_properties_res, decode_task_ins,
                               decode_task_res, encode_evaluate_res,
                               encode_fit_res, encode_partial_fit_res,
                               encode_properties_res, encode_task_ins,
                               encode_task_res, peek_config, peek_params)
from repro.fl.server import Driver
from repro.fl.strategy import _flat_of
from repro.runtime.reliable import RequestTimeout

# Tombstones for in-flight tasks whose round already gave up on them are
# pruned after this many seconds; a responsive-but-slow node clears its own
# tombstone the moment its late result arrives (and is dropped).
_TOMBSTONE_TTL = 120.0


class _Waiter:
    """One consumer's cursor over the completion queue: results for its
    registered task ids are routed straight to ``ready`` by
    ``push_task_res`` — O(1) per arrival — instead of every blocked
    consumer rescanning its full outstanding id set on each wakeup
    (quadratic per round at 10k in-flight tasks)."""

    __slots__ = ("ready",)

    def __init__(self):
        self.ready: Deque[Tuple[str, bytes]] = deque()  # guarded-by: link._results_cv


class SuperLink:
    """Hub: per-node task queues + completion queue. Thread-safe."""

    def __init__(self):
        self._task_queues: Dict[str, Deque[Tuple[str, bytes]]] = {}  # guarded-by: _lock
        self._results: Dict[str, bytes] = {}                 # guarded-by: _results_cv
        self._waiters: Dict[str, _Waiter] = {}               # guarded-by: _results_cv
        self._expired: Dict[str, float] = {}                 # guarded-by: _results_cv
        self._results_cv = threading.Condition()
        self._nodes: Dict[str, float] = {}                   # guarded-by: _lock
        self._lock = threading.Lock()
        # long-poll wakeup for pull_task_wait; wraps the SAME lock, so
        # every ``with self._lock`` block may wait/notify on it directly
        self._tasks_cv = threading.Condition(self._lock)
        self.stats = {"late_dropped": 0, "discarded_ins": 0}  # guarded-by: _results_cv

    # ------------------------------------------------------------ fleet API
    def fleet_unary(self, method: str, request: bytes) -> bytes:
        if method == "register":
            node_id = request.decode()
            with self._lock:
                # monotonic: the heartbeat feeds liveness arithmetic and
                # must not jump with the wall clock (NTP steps)
                self._nodes[node_id] = time.monotonic()
                self._task_queues.setdefault(node_id, deque())
            return b"OK"
        if method == "pull_task_ins":
            node_id = request.decode()
            with self._lock:
                q = self._task_queues.setdefault(node_id, deque())
                task_id, task = q.popleft() if q else ("", b"")
            return msgpack.packb({"id": task_id, "task": task},
                                 use_bin_type=True)
        if method == "push_task_res":
            d = msgpack.unpackb(request, raw=False)
            return b"OK" if self.push_task_result(d["id"], d["res"]) \
                else b"LATE"
        raise ValueError(f"unknown fleet method {method!r}")

    def pull_task_wait(self, node_id: str, timeout: float
                       ) -> Tuple[str, bytes]:
        """Long-poll variant of the fleet ``pull_task_ins``: block up to
        ``timeout`` seconds for a task instead of returning empty
        immediately.  The socket transport serves pulls with this so idle
        peers park server-side instead of generating poll chatter; the
        in-proc path keeps the instant (empty-capable) ``fleet_unary``.
        Returns ``("", b"")`` on timeout."""
        deadline = time.monotonic() + timeout
        with self._lock:
            q = self._task_queues.setdefault(node_id, deque())
            while not q:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return "", b""
                self._tasks_cv.wait(remaining)
            return q.popleft()

    def push_task_result(self, task_id: str, res: bytes) -> bool:
        """Complete ``task_id`` with ``res``; False if the round already
        gave up on it (tombstoned — the late result is dropped so it
        cannot leak into a later round).  This is the raw-body seam the
        socket transport pushes through: its TaskRes bytes arrive as
        read-only memoryviews over the receive buffer and are stored
        as-is, zero-copy."""
        dropped = False
        with self._results_cv:
            if task_id in self._expired:
                del self._expired[task_id]
                self.stats["late_dropped"] += 1
                dropped = True
            else:
                w = self._waiters.pop(task_id, None)
                if w is not None:
                    w.ready.append((task_id, res))   # O(1) routing
                else:
                    self._results[task_id] = res
                self._results_cv.notify_all()
        if dropped:
            self._result_released(task_id)
        return not dropped

    def mark_node_dead(self, node_id: str) -> bool:
        """Heartbeat expiry (socket transport): drop the node from the
        roster so the next round's ``node_ids`` excludes it.  Tasks it
        already pulled keep their normal fate — the round deadline
        demotes them to ``(node, "timeout")`` failure records — and
        queued-but-undelivered TaskIns stay queued, so a reconnect
        (re-register) resumes service where it left off.  Returns whether
        the node was actually in the roster (idempotent)."""
        with self._lock:
            return self._nodes.pop(node_id, None) is not None

    def _result_released(self, task_id: str) -> None:
        """Subclass hook: ``task_id``'s result bytes permanently left the
        completion queue (consumed by a waiter, dropped LATE, or
        discarded).  The socket transport returns the pushing peer's
        flow-control credits here.  Always invoked with no link locks
        held, so overrides may take their own locks or perform I/O."""

    # ------------------------------------------------------------ driver API
    def node_ids(self) -> List[str]:
        with self._lock:
            return sorted(self._nodes)

    def push_task_ins(self, node_id: str, task: bytes) -> str:
        task_id = uuid.uuid4().hex
        with self._lock:
            self._task_queues.setdefault(node_id, deque()).append(
                (task_id, task))
            self._tasks_cv.notify_all()     # wake long-poll pulls
        return task_id

    def register_waiter(self, task_ids: Iterable[str]) -> _Waiter:
        """Open a completion-queue cursor over ``task_ids``: results for
        those ids are routed to it in O(1) as they land (results that
        already landed are moved in).  Pair with :meth:`release_waiter`
        — an abandoned waiter strands its routed results."""
        w = _Waiter()
        self._attach(w, task_ids)
        return w

    def add_to_waiter(self, w: _Waiter, task_ids: Iterable[str]) -> None:
        """Route additional task ids to an open waiter (streaming use)."""
        self._attach(w, task_ids)

    def _attach(self, w: _Waiter, task_ids: Iterable[str]) -> None:
        # the Condition's lock is an RLock, so this nests under callers
        # that already hold it
        with self._results_cv:
            for tid in task_ids:
                res = self._results.pop(tid, None)
                if res is not None:
                    w.ready.append((tid, res))   # landed before we waited
                else:
                    self._waiters[tid] = w
            if w.ready:
                self._results_cv.notify_all()

    def waiter_next(self, w: _Waiter,
                    deadline: float) -> Optional[Tuple[str, bytes]]:
        """Block until a result routed to ``w`` is available or
        ``deadline`` (``time.monotonic()`` timestamp) passes; returns
        ``(task_id, res_bytes)`` or ``None``.  Full-duration CV wait —
        no periodic polling, no per-wakeup id scan."""
        got: Optional[Tuple[str, bytes]] = None
        with self._results_cv:
            while not w.ready:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._results_cv.wait(remaining)
            if w.ready:
                got = w.ready.popleft()
        if got is not None:
            # outside the CV: the hook may take transport locks / do I/O
            self._result_released(got[0])
        return got

    def release_waiter(self, w: _Waiter,
                       task_ids: Iterable[str]) -> None:
        """Detach ``task_ids`` from ``w`` and return its undelivered
        routed results to the shared store, so a subsequent
        :meth:`discard` keeps the tombstone accounting exact."""
        with self._results_cv:
            for tid in task_ids:
                if self._waiters.get(tid) is w:
                    del self._waiters[tid]
            while w.ready:
                tid, res = w.ready.popleft()
                self._results[tid] = res

    def pull_any(self, task_ids: Iterable[str],
                 deadline: float) -> Optional[Tuple[str, bytes]]:
        """Completion queue: block until any of ``task_ids`` has a result
        or ``deadline`` (``time.monotonic()`` timestamp) passes.

        Returns ``(task_id, res_bytes)`` — the result is popped — or
        ``None`` on deadline.  The caller owns the remaining ids and must
        eventually :meth:`discard` the ones it gives up on.

        Compatibility wrapper: registers a throwaway waiter per call, so
        long-lived consumers (drivers, streams) should hold one waiter
        for their whole exchange instead.
        """
        ids = list(task_ids)
        w = self.register_waiter(ids)
        try:
            return self.waiter_next(w, deadline)
        finally:
            self.release_waiter(w, ids)

    def pull_task_res(self, task_id: str, timeout: float) -> bytes:
        got = self.pull_any([task_id], time.monotonic() + timeout)
        if got is None:
            self.discard([task_id])
            raise TimeoutError(f"task {task_id} timed out")
        return got[1]

    def discard(self, task_ids: Iterable[str]) -> None:
        """Give up on tasks: reap undelivered TaskIns from the node queues
        and tombstone in-flight ones so their late TaskRes is dropped."""
        ids = set(task_ids)
        if not ids:
            return
        undelivered: Set[str] = set()
        with self._lock:
            for node, q in self._task_queues.items():
                if any(tid in ids for tid, _ in q):
                    kept = deque(e for e in q if e[0] not in ids)
                    undelivered.update(tid for tid, _ in q if tid in ids)
                    self._task_queues[node] = kept
        now = time.monotonic()
        dropped: List[str] = []
        with self._results_cv:
            self.stats["discarded_ins"] += len(undelivered)
            for tid in ids:
                self._waiters.pop(tid, None)     # stop routing to cursors
                if self._results.pop(tid, None) is not None:
                    dropped.append(tid)          # landed but unwanted: done
                    continue
                if tid not in undelivered:
                    self._expired[tid] = now     # delivered, still in flight
            cutoff = now - _TOMBSTONE_TTL
            for tid in [t for t, ts in self._expired.items() if ts < cutoff]:
                del self._expired[tid]
        for tid in dropped:
            self._result_released(tid)


class TaskStream:
    """Persistent send/recv channel over the SuperLink completion queue —
    the async (FedBuff) transport: tasks go out at any time, results come
    back one at a time in arrival order, with no round barrier.  Holds
    ONE waiter for its whole lifetime (O(1) wakeups).  Not thread-safe;
    one stream per consumer."""

    def __init__(self, link: SuperLink):
        self.link = link
        self._waiter = link.register_waiter(())
        self._pending: Dict[str, str] = {}       # task_id -> node
        self._closed = False

    def send(self, tasks: Dict[str, bytes]) -> Dict[str, str]:
        """Push TaskIns bytes per node; returns ``node -> task_id``."""
        if self._closed:
            raise RuntimeError("send() on a closed TaskStream")
        out: Dict[str, str] = {}
        for node, t in sorted(tasks.items()):
            out[node] = tid = self.link.push_task_ins(node, t)
            self._pending[tid] = node
        self.link.add_to_waiter(self._waiter, list(out.values()))
        return out

    def recv(self, timeout: float
             ) -> Optional[Tuple[str, str, bytes]]:
        """Next arriving result as ``(node, task_id, res_bytes)``, or
        ``None`` if nothing lands within ``timeout`` seconds."""
        if self._closed:
            raise RuntimeError("recv() on a closed TaskStream")
        got = self.link.waiter_next(self._waiter,
                                    time.monotonic() + timeout)
        if got is None:
            return None
        tid, res = got
        return self._pending.pop(tid, ""), tid, res

    def close(self) -> None:
        """Give up on everything still in flight: undelivered TaskIns are
        reaped, in-flight tasks tombstoned so late results are dropped."""
        if self._closed:
            return
        self._closed = True
        pending = set(self._pending)
        self._pending.clear()
        self.link.release_waiter(self._waiter, pending)
        if pending:
            self.link.discard(pending)


class SuperLinkDriver(Driver):
    """Driver API implementation over a SuperLink instance.

    ``send_and_receive_iter`` is a **native streaming transport**: results
    yield in arrival order the moment they land on the completion queue,
    so decode+accumulate overlaps the stragglers' compute, and the whole
    batch shares a single deadline.
    """

    def __init__(self, superlink: SuperLink, expected_nodes: int = 0,
                 join_timeout: float = 30.0):
        self.link = superlink
        if expected_nodes:
            deadline = time.monotonic() + join_timeout
            while (len(self.link.node_ids()) < expected_nodes
                   and time.monotonic() < deadline):
                time.sleep(0.005)

    def node_ids(self) -> List[str]:
        return self.link.node_ids()

    def open_stream(self) -> TaskStream:
        """Streaming channel for the async server loop (ServerApp
        ``run_async``): no round barrier, one result per recv."""
        return TaskStream(self.link)

    def send_and_receive_iter(self, tasks: Dict[str, bytes],
                              timeout: float) -> Iterator[Tuple[str, bytes]]:
        ids = {self.link.push_task_ins(node, t): node
               for node, t in sorted(tasks.items())}
        deadline = time.monotonic() + timeout
        pending = set(ids)
        # one waiter for the whole round: each arrival is routed to it in
        # O(1), instead of rescanning all pending ids per wakeup
        w = self.link.register_waiter(ids)
        try:
            while pending:
                got = self.link.waiter_next(w, deadline)
                if got is None:
                    break                      # deadline: pending are lost
                tid, res = got
                pending.discard(tid)
                yield ids[tid], res
        finally:
            # also runs on generator close: never strand orphaned state
            self.link.release_waiter(w, pending)
            if pending:
                self.link.discard(pending)

    def send_and_receive(self, tasks: Dict[str, bytes],
                         timeout: float) -> Dict[str, bytes]:
        """Blocking batch API: all pulls share ONE deadline, so the total
        wait is <= timeout (+ scheduling ε), never N x timeout."""
        out = {node: res for node, res in
               self.send_and_receive_iter(tasks, timeout)}
        if len(out) < len(tasks):
            missing = sorted(set(tasks) - set(out))
            raise TimeoutError(
                f"tasks for nodes {missing} timed out after {timeout}s")
        return out


# ---------------------------------------------------------------------------
# connections (the pluggable wire)
# ---------------------------------------------------------------------------
class FleetConnection:
    """gRPC-shaped unary interface a SuperNode talks through.

    The typed wrappers are what the :class:`SuperNode` loop calls; their
    defaults ride :meth:`unary` with the in-proc msgpack envelopes, so
    existing connections (native, LGS) inherit them unchanged while the
    socket transport (:class:`repro.core.transport.TcpFleetConnection`)
    overrides them with zero-copy framed calls.
    """

    def unary(self, method: str, request: bytes) -> bytes:
        raise NotImplementedError

    def register(self, node_id: str) -> None:
        self.unary("register", node_id.encode())

    def pull_task(self, node_id: str) -> Tuple[str, bytes]:
        """Next queued TaskIns as ``(task_id, task_bytes)`` —
        ``("", b"")`` when the queue is empty."""
        d = msgpack.unpackb(self.unary("pull_task_ins", node_id.encode()),
                            raw=False)
        return d["id"], d["task"]

    def push_result(self, task_id: str, res: bytes) -> None:
        self.unary("push_task_res",
                   msgpack.packb({"id": task_id, "res": res},
                                 use_bin_type=True))

    def close(self) -> None:
        """Release transport resources (sockets, threads); in-proc
        connections have none."""


class NativeConnection(FleetConnection):
    """Direct in-process connection (Flower running 'alone')."""

    def __init__(self, superlink: SuperLink):
        self.link = superlink

    def unary(self, method: str, request: bytes) -> bytes:
        return self.link.fleet_unary(method, request)


class SuperNode:
    """Long-running client host: polls for tasks, runs the ClientApp.

    Transport failures (a dropped fleet call, a ReliableMessage timeout on
    the FLARE-bridged path) do NOT kill the node: the loop records them in
    ``transport_errors``, backs off briefly, and keeps serving — the
    server's round deadline turns any miss into a per-node failure.
    """

    def __init__(self, node_id: str, client_app: ClientApp,
                 connection: FleetConnection, poll_interval: float = 0.005):
        self.node_id = node_id
        self.app = client_app
        self.conn = connection
        self.poll_interval = poll_interval
        self.transport_errors = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        self.conn.register(self.node_id)
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"supernode-{self.node_id}")
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                task_id, task = self.conn.pull_task(self.node_id)
            except (RequestTimeout, ConnectionError, OSError):
                self.transport_errors += 1
                self._stop.wait(10 * self.poll_interval)
                continue
            if not task_id:
                self._stop.wait(self.poll_interval)
                continue
            try:
                res = self.app.handle(task, cid=self.node_id)
            except Exception as e:  # noqa: BLE001 — mod/decode blew up
                # outside ClientApp.handle's own guard: report the real
                # error instead of dying and ghosting as (node, "timeout")
                res = encode_task_res(TaskRes("error", 0, b"",
                                              error=repr(e)))
            try:
                self.conn.push_result(task_id, res)
            except (RequestTimeout, ConnectionError, OSError):
                # undeliverable result: the server's deadline records the
                # miss as (node, "timeout"); keep serving later rounds
                self.transport_errors += 1
                self._stop.wait(10 * self.poll_interval)

    def stop(self) -> None:
        self._stop.set()
        # closing first unblocks a pull parked in a socket long-poll, so
        # the join below is prompt on the TCP transport too
        self.conn.close()
        if self._thread:
            self._thread.join(timeout=2.0)


# ---------------------------------------------------------------------------
# hierarchical edge tier
# ---------------------------------------------------------------------------
class InlineFleetDriver(Driver):
    """Zero-thread Driver over in-process ClientApps: each task runs the
    child's ``handle`` synchronously, in sorted node order, honoring the
    shared deadline.  This is the 10k-simulated-client substrate — an
    edge tier mounts a handful of these (1250 inline clients each)
    instead of 10k polling SuperNode threads."""

    def __init__(self, apps: Dict[str, ClientApp]):
        self.apps = dict(apps)

    def node_ids(self) -> List[str]:
        return sorted(self.apps)

    def send_and_receive_iter(self, tasks: Dict[str, bytes],
                              timeout: float) -> Iterator[Tuple[str, bytes]]:
        deadline = time.monotonic() + timeout
        for node in sorted(tasks):
            if time.monotonic() > deadline:
                return             # remaining nodes become (node, timeout)
            yield node, self.apps[node].handle(tasks[node], cid=node)

    def send_and_receive(self, tasks: Dict[str, bytes],
                         timeout: float) -> Dict[str, bytes]:
        out = {node: res for node, res in
               self.send_and_receive_iter(tasks, timeout)}
        if len(out) < len(tasks):
            missing = sorted(set(tasks) - set(out))
            raise TimeoutError(f"tasks for nodes {missing} timed out")
        return out


class EdgeAggregatorApp:
    """Intermediate aggregation tier (hierarchical FL): mounts on a
    parent SuperNode exactly like a ClientApp, but fans every task out to
    its OWN child fleet and pre-reduces the subtree's fit results, so the
    root folds **O(#edges)** payloads instead of O(#clients).

    - fit with ``config["partial"]`` (set by the root when its strategy
      ``supports_partial()``): forward the pristine downlink bytes,
      fold child results through :class:`~repro.fl.agg_kernels
      .StreamingWeightedSum` in sorted node order — the root's own
      canonical fold arithmetic, which is what makes the sync
      hierarchical aggregate bitwise-equal to the flat topology — and
      ship one ``Σw·x`` partial-sum frame (0xF4) carrying the subtree
      total weight, contributing ids, and absorbed per-node failures.
    - fit without the flag (root predates 0xF4, or runs a non-weighted-
      sum strategy): same fold, downgraded to a plain weighted-mean
      FitRes whose ``num_examples`` is the subtree's combined count, so
      the root's ordinary weighted average stays exact.
    - evaluate: example-weighted mean of child losses/metrics.
    - get_properties: intersection of the children's codec sets.
    - get_parameters: first child success (probed one at a time).

    A nested edge below this one is folded via ``add_partial`` — tiers
    compose.
    """

    def __init__(self, child_driver: Driver, edge_id: str = "edge",
                 timeout: float = 60.0):
        self.driver = child_driver
        self.edge_id = edge_id
        self.timeout = timeout

    # ------------------------------------------------------------ dispatch
    def handle(self, task_ins_bytes: bytes, cid: str = "0") -> bytes:
        task = decode_task_ins(task_ins_bytes)
        try:
            if task.task_type == "fit":
                return encode_task_res(self._fit(task))
            if task.task_type == "evaluate":
                return encode_task_res(self._evaluate(task))
            if task.task_type == "get_parameters":
                return encode_task_res(self._get_parameters(task))
            if task.task_type == "get_properties":
                return encode_task_res(self._get_properties(task))
            return encode_task_res(
                TaskRes(task.task_type, task.round, b"",
                        task_id=task.task_id, error="unknown task type"))
        except Exception as e:  # noqa: BLE001 — a broken subtree must
            # surface as this edge's per-node failure, not kill the host
            return encode_task_res(
                TaskRes(task.task_type, task.round, b"",
                        task_id=task.task_id, error=repr(e)))

    def _scatter(self, task: TaskIns
                 ) -> Tuple[List[Tuple[str, TaskRes]],
                            List[Tuple[str, str]]]:
        """Forward the pristine TaskIns bytes to every child under one
        shared deadline.  Returns (sorted successes, sorted failures) —
        sorted so the fold order is canonical regardless of arrival."""
        nodes = sorted(self.driver.node_ids())
        raw = encode_task_ins(task)
        results: List[Tuple[str, TaskRes]] = []
        failures: List[Tuple[str, str]] = []
        received = set()
        for node, tr_bytes in self.driver.send_and_receive_iter(
                {node: raw for node in nodes}, self.timeout):
            received.add(node)
            try:
                tr = decode_task_res(tr_bytes)
            except Exception as e:  # noqa: BLE001 — byzantine child
                failures.append((node, f"malformed response: {e!r}"))
                continue
            if tr.error:
                failures.append((node, tr.error))
            else:
                results.append((node, tr))
        failures.extend((n, "timeout") for n in nodes if n not in received)
        results.sort(key=lambda kv: kv[0])
        failures.sort()
        return results, failures

    # ------------------------------------------------------------- phases
    def _fit(self, task: TaskIns) -> TaskRes:
        want_partial = bool(peek_config(task.payload).get("partial"))
        results, failures = self._scatter(task)
        if not results:
            return TaskRes("fit", task.round, b"", task_id=task.task_id,
                           error=f"no child produced a fit result "
                                 f"(failures: {failures})")
        acc: Optional[kernels.StreamingWeightedSum] = None
        base = None     # lazy: only delta-quantized children need it
        node_ids: List[str] = []
        for node, tr in results:       # sorted: the canonical fold order
            res = decode_fit_res(tr.payload)
            if res.partial is not None:
                ps = res.partial       # nested edge: continue its sum
                if acc is None:
                    acc = kernels.StreamingWeightedSum(ps.layout)
                acc.add_partial(ps)
                node_ids.extend(ps.node_ids)
                failures.extend(ps.failures)
                continue
            q = res.quant
            if q is not None and q.is_delta and q.base is None:
                if base is None:
                    # the downlink we forwarded verbatim IS what the
                    # child trained from — same base the root would use
                    base = peek_params(task.payload)
                q.base = base
            sp = res.sparse
            if sp is not None and sp.base is None:
                if base is None:
                    base = peek_params(task.payload)
                # the deferred base lands in raw_sum()/finalize(), so the
                # 0xF4 partial this edge frames stays the true subtree sum
                sp.base = base
            fp = _flat_of(res)
            if acc is None:
                acc = kernels.StreamingWeightedSum(fp.layout)
            acc.add(fp, float(res.num_examples))
            node_ids.append(node)
        if want_partial:
            ps = PartialSum(acc.layout, acc.raw_sum(), acc.total_w,
                            acc.count, tuple(sorted(node_ids)),
                            tuple(failures))
            return TaskRes("fit", task.round, encode_partial_fit_res(ps),
                           task_id=task.task_id)
        # downgrade path: the root doesn't speak 0xF4 — ship the subtree
        # weighted mean with the combined example count instead
        mean = acc.finalize()
        out = FitRes(None, int(round(acc.total_w)), {}, flat=mean)
        return TaskRes("fit", task.round, encode_fit_res(out),
                       task_id=task.task_id)

    def _evaluate(self, task: TaskIns) -> TaskRes:
        results, failures = self._scatter(task)
        if not results:
            return TaskRes("evaluate", task.round, b"",
                           task_id=task.task_id,
                           error=f"no child produced an evaluate result "
                                 f"(failures: {failures})")
        tot_loss, tot_n = 0.0, 0
        sums: Dict[str, float] = {}
        for _node, tr in results:
            ev = decode_evaluate_res(tr.payload)
            tot_loss += float(ev.loss) * ev.num_examples
            tot_n += ev.num_examples
            for k, v in ev.metrics.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    sums[k] = sums.get(k, 0.0) + float(v) * ev.num_examples
        n = max(tot_n, 1)
        out = EvaluateRes(tot_loss / n, tot_n,
                          {k: v / n for k, v in sums.items()})
        return TaskRes("evaluate", task.round, encode_evaluate_res(out),
                       task_id=task.task_id)

    def _get_parameters(self, task: TaskIns) -> TaskRes:
        raw = encode_task_ins(task)
        errors: List[Tuple[str, str]] = []
        for node in sorted(self.driver.node_ids()):
            try:
                out = self.driver.send_and_receive({node: raw},
                                                   self.timeout)
            except TimeoutError:
                errors.append((node, "timeout"))
                continue
            tr = decode_task_res(out[node])
            if tr.error:
                errors.append((node, tr.error))
                continue
            return TaskRes("get_parameters", task.round, tr.payload,
                           task_id=task.task_id)
        return TaskRes("get_parameters", task.round, b"",
                       task_id=task.task_id,
                       error=f"no child returned parameters: {errors}")

    def _get_properties(self, task: TaskIns) -> TaskRes:
        results, failures = self._scatter(task)
        if not results:
            return TaskRes("get_properties", task.round, b"",
                           task_id=task.task_id,
                           error=f"no child responded (failures: "
                                 f"{failures})")
        codecs: Optional[Set[str]] = None
        for _node, tr in results:
            cs = set(decode_properties_res(tr.payload)
                     .get("codecs", ("flat", "legacy")))
            codecs = cs if codecs is None else codecs & cs
        return TaskRes("get_properties", task.round,
                       encode_properties_res({"codecs": sorted(codecs)}),
                       task_id=task.task_id)


def make_edge_tier(link: SuperLink, apps: Dict[str, ClientApp],
                   num_edges: int, timeout: float = 60.0
                   ) -> List[SuperNode]:
    """Partition ``apps`` into ``num_edges`` contiguous (sorted) groups,
    give each group an :class:`InlineFleetDriver` child fleet wrapped in
    an :class:`EdgeAggregatorApp`, and mount the edges as SuperNodes on
    ``link`` (ids ``edge-0 .. edge-{n-1}``).  Returns the started nodes;
    the caller stops them."""
    names = sorted(apps)
    if not 1 <= num_edges <= len(names):
        raise ValueError(f"num_edges must be in [1, {len(names)}], "
                         f"got {num_edges}")
    edges: List[SuperNode] = []
    for e in range(num_edges):
        lo = e * len(names) // num_edges
        hi = (e + 1) * len(names) // num_edges
        child = InlineFleetDriver({n: apps[n] for n in names[lo:hi]})
        app = EdgeAggregatorApp(child, edge_id=f"edge-{e}",
                                timeout=timeout)
        sn = SuperNode(f"edge-{e}", app, NativeConnection(link))
        sn.start()
        edges.append(sn)
    return edges

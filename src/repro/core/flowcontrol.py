"""Credit-based per-peer flow control for the socket transport.

The server grants each peer a byte *window* (the WELCOME frame's
``credits``).  Every frame a peer sends debits the window; the server
returns credits (CREDIT frames) only once it has durably *released* the
bytes — immediately for cheap control/query traffic, but for
``push_task_res`` payloads only when the result permanently leaves the
completion queue (consumed by the driver, dropped as LATE, or discarded
at the round deadline).  A fast client therefore stalls in
:meth:`CreditGate.acquire` once the server holds a full window of its
un-consumed bytes: the *sender* blocks, the server's RSS stays bounded
(the ``backpressure_ok`` benchmark gate), and other peers are unaffected
because the gate lives client-side.

Oversized frames: a single frame larger than the whole window acquires
``min(n, limit)`` and lets the balance go negative — the transfer
overshoots once (the server's :meth:`CreditLedger.debit` tolerates up to
one window of overshoot), then the sender is fully stalled until the
server releases it.  Reconnects re-announce the true remaining window
(:meth:`CreditLedger.snapshot_for_welcome`); resends do not re-acquire,
so client/server drift is bounded by the in-flight frames and self-heals
through the capped :meth:`CreditGate.grant`.
"""
from __future__ import annotations

import threading
import time


class CreditGate:
    """Sender-side window.  Starts closed at 0 credits; the WELCOME after
    (re)connect :meth:`reset`\\ s it to the server-announced balance."""

    def __init__(self):
        self._cv = threading.Condition()
        self._avail = 0              # guarded-by: _cv
        self._limit = 0              # guarded-by: _cv
        self._closed = False         # guarded-by: _cv

    def reset(self, avail: int, limit: int) -> None:
        """Adopt the server-announced balance (connect/reconnect)."""
        with self._cv:
            self._avail = int(avail)
            self._limit = int(limit)
            self._cv.notify_all()

    def grant(self, n: int) -> None:
        """A CREDIT frame arrived.  Capped at the window limit so
        duplicate-release drift after a reconnect can only restore the
        window, never inflate it."""
        with self._cv:
            self._avail = min(self._avail + int(n), self._limit)
            self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def balance(self) -> int:
        with self._cv:
            return self._avail

    def acquire(self, n: int, deadline: float) -> bool:
        """Debit ``n`` bytes, blocking until the window has room or
        ``deadline`` (``time.monotonic()`` timestamp) passes.  Returns
        False on deadline; raises ``ConnectionError`` once closed."""
        with self._cv:
            need = min(int(n), self._limit) if self._limit > 0 else int(n)
            while True:
                if self._closed:
                    raise ConnectionError("credit gate closed")
                if self._avail >= need:
                    self._avail -= int(n)
                    return True
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._cv.wait(remaining)


class CreditLedger:
    """Server-side per-peer accounting, persistent across reconnects.

    :meth:`debit` on frame receipt (reader thread), :meth:`release` when
    the bytes are durably consumed.  Grants are coalesced to at least
    ``limit // 8`` so a storm of small releases does not become a storm
    of CREDIT frames; held-back credits are bounded by that threshold, so
    the peer always retains >= 7/8 of its window and can never deadlock
    on an unflushed grant.
    """

    def __init__(self, limit: int):
        self.limit = int(limit)
        self._lock = threading.Lock()
        self._outstanding = 0        # guarded-by: _lock  received, unreleased
        self._pending_grant = 0      # guarded-by: _lock  released, unsent

    def debit(self, n: int) -> bool:
        """Account a received frame.  False once the peer has overflowed
        the window by more than one full-window overshoot — a protocol
        violation (ignoring flow control), so the caller drops the
        connection instead of buffering unboundedly."""
        with self._lock:
            self._outstanding += int(n)
            return self._outstanding <= 2 * self.limit

    def release(self, n: int) -> int:
        """Return ``n`` bytes to the peer's window; returns the coalesced
        grant to send (0 = held back below the flush threshold)."""
        with self._lock:
            self._outstanding -= int(n)
            self._pending_grant += int(n)
            if self._pending_grant >= max(1, self.limit // 8):
                grant, self._pending_grant = self._pending_grant, 0
                return grant
            return 0

    def snapshot_for_welcome(self) -> int:
        """Balance to announce in WELCOME after (re)connect: the window
        minus bytes still held server-side.  Pending unsent grants fold
        into the announcement (and are zeroed) so they are never counted
        twice."""
        with self._lock:
            self._pending_grant = 0
            return max(0, self.limit - self._outstanding)

    def outstanding(self) -> int:
        with self._lock:
            return self._outstanding

"""Real networked Fleet transport: ``TcpSuperLink`` + ``TcpFleetConnection``.

Everything above the Fleet API seam — ``ServerApp``, ``EdgeAggregatorApp``,
FedBuff async mode, every strategy — runs unmodified: the server side *is*
a :class:`~repro.core.superlink.SuperLink` (subclass) and the client side
is a :class:`~repro.core.superlink.FleetConnection`, so swapping
``NativeConnection`` for a socket is the same move as swapping it for the
FLARE-bridged LGS (paper Fig. 4).  Select it per run with
``ServerConfig(transport="tcp")`` (see :func:`repro.core.interop.run_native`).

Mechanics (see ``repro.core.framing`` for the wire layout and
``docs/INVARIANTS.md`` for the protocol contract):

- **Multiplexing** — one socket per peer carries many logical
  TaskIns/TaskRes exchanges: every REQ has a stable ``msg_id`` and the
  server answers out of order (a parked long-poll pull never blocks a
  concurrent result push on the same socket).
- **Zero-copy payloads** — TaskRes bytes ride as the raw tail of a REQ
  frame; the receiver stores the frame buffer's read-only memoryview
  straight into the completion queue, and the 0xF1–0xF4 codec payloads
  inside it later decode via ``np.frombuffer`` off that same buffer.
- **Backpressure** — per-peer credit windows (``repro.core.flowcontrol``):
  ``push_task_res`` bytes are only re-credited once the result permanently
  leaves the completion queue (the :meth:`SuperLink._result_released`
  hook), so a fast client blocks client-side instead of ballooning the
  server's RSS.
- **Liveness** — monotonic-clock heartbeats: clients PING, the server
  expires peers silent for ``heartbeat_timeout`` and drops them from the
  roster; their in-flight tasks miss the round deadline and surface as
  the established ``(node, "timeout")`` failure records.
- **Reconnect-with-resume** — a reconnecting client re-HELLOs and resends
  its in-flight REQs with the same ``msg_id``; the server's per-peer
  :class:`~repro.runtime.reliable.ResultCache` (the ReliableMessage dedup
  role) executes each at most once and replays cached responses, so a
  dropped RES never loses a pulled task or double-applies a push.
- **TLS hook** — pass an ``ssl.SSLContext`` to either end; CI runs
  plaintext but the seam is exercised by a loopback-cert test.

Set ``REPRO_TCP_LOG=<path>`` to append server-side transport events
(connects, expiries, credit stalls) to a file — the CI ``tcp-mp`` lane
uploads it on failure.
"""
from __future__ import annotations

import itertools
import logging
import os
import socket
import threading
import time
from typing import Dict, List, Optional, Tuple

import msgpack

from repro.core.flowcontrol import CreditGate, CreditLedger
from repro.core.framing import (DEFAULT_MAX_FRAME, FT_BYE, FT_CREDIT,
                                FT_HELLO, FT_PING, FT_PONG, FT_REQ, FT_RES,
                                FT_WELCOME, PROTO_VERSION, FrameError,
                                FrameReader, control_frame, data_frame_parts,
                                frame_nbytes, parse_control, send_parts,
                                split_data)
from repro.core.superlink import FleetConnection, SuperLink, SuperNode
from repro.runtime.reliable import RequestTimeout, ResultCache

log = logging.getLogger("repro.transport")

# length prefix + frame type byte: the fixed per-frame wire overhead the
# credit accounting adds on top of the payload
_FRAME_OVERHEAD = 5


def _maybe_attach_file_log() -> None:
    """Honor REPRO_TCP_LOG: append transport events to the named file (the
    CI tcp-mp lane uploads it as an artifact when the job fails)."""
    path = os.environ.get("REPRO_TCP_LOG")
    if not path:
        return
    path = os.path.abspath(path)
    for h in log.handlers:
        if isinstance(h, logging.FileHandler) and h.baseFilename == path:
            return
    handler = logging.FileHandler(path)
    handler.setFormatter(logging.Formatter(
        "%(asctime)s %(levelname)s %(threadName)s %(message)s"))
    log.addHandler(handler)
    log.setLevel(logging.INFO)


class _Conn:
    """One accepted/connected socket.  Frame sends are serialized by an
    internal lock (interleaved writers would desync the length prefix);
    :meth:`close` shuts the socket down un-locked so it also unblocks a
    writer stuck against a full send buffer."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._send_lock = threading.Lock()
        self._alive = True               # guarded-by: _send_lock

    def send_frame(self, *parts) -> bool:
        with self._send_lock:
            if not self._alive:
                return False
            try:
                send_parts(self.sock, *parts)
                return True
            except OSError:
                self._alive = False
                return False

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


class _PeerState:
    """Server-side per-node state, persistent across reconnects: the
    credit ledger keeps accounting for bytes still buffered from a dead
    connection, and the dedup cache is what makes reconnect-resume safe."""

    def __init__(self, node_id: str, credit_limit: int, result_ttl: float):
        self.node_id = node_id
        self.ledger = CreditLedger(credit_limit)
        self.cache = ResultCache(result_ttl)
        self._lock = threading.Lock()
        self._conn: Optional[_Conn] = None     # guarded-by: _lock
        self._last_seen = time.monotonic()     # guarded-by: _lock

    def attach(self, conn: _Conn) -> Optional[_Conn]:
        """Adopt a new connection; returns the stale one (caller closes
        it — at most one live socket per peer)."""
        with self._lock:
            old, self._conn = self._conn, conn
            self._last_seen = time.monotonic()
            return old

    def detach(self, conn: _Conn) -> None:
        with self._lock:
            if self._conn is conn:
                self._conn = None

    def current_conn(self) -> Optional[_Conn]:
        with self._lock:
            return self._conn

    def touch(self) -> None:
        with self._lock:
            self._last_seen = time.monotonic()

    def silent_for(self) -> float:
        with self._lock:
            return time.monotonic() - self._last_seen


class TcpSuperLink(SuperLink):
    """A :class:`SuperLink` whose Fleet API is served over real sockets.

    The Driver side is unchanged — the ServerApp drives this object
    exactly like the in-proc link — while SuperNodes connect through
    :class:`TcpFleetConnection`.  One reader thread per connection, one
    short-lived worker per REQ (a parked long-poll pull must not block
    the next frame), a reaper for heartbeat expiry, and a grant pump that
    sends CREDIT frames outside every link lock.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 ssl_context=None, credits_per_peer: int = 64 << 20,
                 poll_wait: float = 0.5, heartbeat_timeout: float = 10.0,
                 io_timeout: float = 30.0, result_ttl: float = 60.0,
                 max_frame: int = DEFAULT_MAX_FRAME):
        super().__init__()
        _maybe_attach_file_log()
        self._ssl = ssl_context
        self.credits_per_peer = int(credits_per_peer)
        self.poll_wait = poll_wait
        self.heartbeat_timeout = heartbeat_timeout
        self.io_timeout = io_timeout
        self.result_ttl = result_ttl
        self.max_frame = int(max_frame)
        self._peers: Dict[str, _PeerState] = {}         # guarded-by: _tlock
        self._held_credits: Dict[str, Tuple[_PeerState, int]] = {}  # guarded-by: _tlock
        self._tlock = threading.Lock()
        self._grants: Dict[str, int] = {}               # guarded-by: _grant_cv
        self._grant_cv = threading.Condition()
        self._stop = threading.Event()
        self._listener = socket.create_server((host, port), backlog=64)
        self.address: Tuple[str, int] = self._listener.getsockname()[:2]
        self._threads = [
            threading.Thread(target=self._accept_loop, daemon=True,
                             name="tcp-accept"),
            threading.Thread(target=self._reap_loop, daemon=True,
                             name="tcp-reaper"),
            threading.Thread(target=self._grant_loop, daemon=True,
                             name="tcp-grant-pump"),
        ]
        for t in self._threads:
            t.start()
        log.info("TcpSuperLink listening on %s:%d (credits/peer=%d)",
                 self.address[0], self.address[1], self.credits_per_peer)

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "TcpSuperLink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        with self._grant_cv:
            self._grant_cv.notify_all()
        self._listener.close()
        with self._tlock:
            peers = list(self._peers.values())
        for peer in peers:
            conn = peer.current_conn()
            if conn is not None:
                conn.send_frame(control_frame(FT_BYE, {"reason": "shutdown"}))
                conn.close()
        for t in self._threads:
            t.join(timeout=2.0)
        log.info("TcpSuperLink closed")

    # ------------------------------------------------------------- plumbing
    def _get_peer(self, node_id: str) -> _PeerState:
        with self._tlock:
            peer = self._peers.get(node_id)
            if peer is None:
                peer = self._peers[node_id] = _PeerState(
                    node_id, self.credits_per_peer, self.result_ttl)
            return peer

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, addr = self._listener.accept()
            except OSError:
                break                        # listener closed
            threading.Thread(target=self._conn_loop, args=(sock, addr),
                             daemon=True,
                             name=f"tcp-conn-{addr[0]}:{addr[1]}").start()

    def _conn_loop(self, sock: socket.socket, addr) -> None:
        peer: Optional[_PeerState] = None
        conn: Optional[_Conn] = None
        try:
            sock.settimeout(self.io_timeout)
            if self._ssl is not None:
                sock = self._ssl.wrap_socket(sock, server_side=True)
            conn = _Conn(sock)
            reader = FrameReader(self.max_frame)
            # handshake: the first frame must be HELLO
            pending: List[Tuple[int, memoryview]] = []
            while not pending:
                got = reader.read_from(sock)
                if got is None:
                    return                   # probe connection, no HELLO
                pending = got
            ftype, payload = pending.pop(0)
            if ftype != FT_HELLO:
                raise FrameError(f"expected HELLO, got frame type {ftype}")
            fields = parse_control(payload)
            node = str(fields["node"])
            peer = self._get_peer(node)
            self.fleet_unary("register", node.encode())
            stale = peer.attach(conn)
            if stale is not None:
                stale.close()                # at most one live socket/peer
            conn.send_frame(control_frame(FT_WELCOME, {
                "credits": peer.ledger.snapshot_for_welcome(),
                "limit": peer.ledger.limit,
                "max_frame": self.max_frame,
                "hb": self.heartbeat_timeout,
            }))
            log.info("peer %s connected from %s:%d%s", node, addr[0],
                     addr[1], " (resume)" if stale is not None else "")
            # frames pipelined behind the HELLO, then the steady loop
            for frame in pending:
                if not self._on_frame(peer, conn, frame):
                    return
            while not self._stop.is_set():
                try:
                    frames = reader.read_from(sock)
                except socket.timeout:
                    continue                 # liveness is the reaper's job
                if frames is None:
                    return                   # clean EOF
                for frame in frames:
                    if not self._on_frame(peer, conn, frame):
                        return
        except (OSError, FrameError, KeyError, ValueError) as e:
            who = peer.node_id if peer is not None else f"{addr[0]}:{addr[1]}"
            log.warning("connection %s dropped: %r", who, e)
        finally:
            if conn is not None:
                conn.close()
            if peer is not None:
                peer.detach(conn)

    def _on_frame(self, peer: _PeerState, conn: _Conn,
                  frame: Tuple[int, memoryview]) -> bool:
        """Dispatch one frame from ``peer``; False ends the connection."""
        ftype, payload = frame
        peer.touch()
        if ftype == FT_REQ:
            nbytes = payload.nbytes + _FRAME_OVERHEAD
            if not peer.ledger.debit(nbytes):
                log.warning("peer %s overran its credit window; dropping",
                            peer.node_id)
                raise FrameError("credit window overrun")
            header, body = split_data(payload)
            threading.Thread(target=self._serve_req,
                             args=(peer, nbytes, header, body),
                             daemon=True,
                             name=f"tcp-req-{peer.node_id}").start()
            return True
        if ftype == FT_PING:
            conn.send_frame(control_frame(FT_PONG, parse_control(payload)))
            return True
        if ftype == FT_BYE:
            log.info("peer %s said BYE", peer.node_id)
            self.mark_node_dead(peer.node_id)
            return False
        raise FrameError(f"unexpected frame type {ftype} from peer")

    # ------------------------------------------------------------- requests
    def _serve_req(self, peer: _PeerState, nbytes: int,
                   header: Dict[str, object], body: memoryview) -> None:
        msg_id = str(header.get("i", ""))
        state, cached = peer.cache.begin(msg_id)
        if state != "new":
            # duplicate (reconnect-resend or retry): the bytes were never
            # buffered a second time — push_task_result dedups by msg, so
            # return the dup frame's credits immediately
            self._release_credits(peer, nbytes)
            if state == "done":
                self._send_res(peer, msg_id, cached)
            # "executing": the original execution replies to the peer's
            # then-current connection when it finishes; "seen": payload
            # already reaped — never re-execute, the client re-times-out
            return
        method = str(header.get("m", ""))
        held = False
        try:
            if method == "register":
                self.fleet_unary("register", peer.node_id.encode())
                resp: Tuple[Dict[str, object], bytes] = ({}, b"")
            elif method == "pull_task_ins":
                tid, task = self.pull_task_wait(peer.node_id, self.poll_wait)
                resp = ({"id": tid}, task)
            elif method == "push_task_res":
                tid = str(header["id"])
                with self._tlock:
                    # record BEFORE the push: if the task is already
                    # tombstoned the _result_released hook fires inside
                    # push_task_result and returns these credits
                    self._held_credits[tid] = (peer, nbytes)
                held = True
                ok = self.push_task_result(tid, body)
                resp = ({"s": "OK" if ok else "LATE"}, b"")
            else:
                resp = ({"e": f"unknown fleet method {method!r}",
                         "k": "error"}, b"")
        except Exception as e:  # noqa: BLE001 — a broken request must
            # surface to its sender, not kill the server worker silently
            log.warning("request %s from %s failed: %r", method,
                        peer.node_id, e)
            resp = ({"e": repr(e), "k": "error"}, b"")
        if not held:
            # non-push traffic is cheap: credits return on dispatch
            self._release_credits(peer, nbytes)
        peer.cache.finish(msg_id, resp)
        self._send_res(peer, msg_id, resp)

    def _send_res(self, peer: _PeerState, msg_id: str,
                  resp: Tuple[Dict[str, object], bytes]) -> None:
        """Reply on the peer's *current* connection: if the REQ's socket
        died, the reconnected socket carries the response — and if none is
        live, the cached copy serves the client's resend."""
        extra, body = resp
        header = {"i": msg_id}
        header.update(extra)
        conn = peer.current_conn()
        if conn is not None:
            conn.send_frame(*data_frame_parts(FT_RES, header, body))

    # -------------------------------------------------------------- credits
    def _release_credits(self, peer: _PeerState, nbytes: int) -> None:
        grant = peer.ledger.release(nbytes)
        if grant:
            with self._grant_cv:
                self._grants[peer.node_id] = \
                    self._grants.get(peer.node_id, 0) + grant
                self._grant_cv.notify_all()

    def _result_released(self, task_id: str) -> None:
        # SuperLink hook: the TaskRes bytes left the completion queue
        # (consumed / LATE / discarded) — only now does the pushing peer
        # get its window back.  Runs without link locks held.
        with self._tlock:
            entry = self._held_credits.pop(task_id, None)
        if entry is None:
            return                      # not a TCP-pushed result
        peer, nbytes = entry
        self._release_credits(peer, nbytes)

    def _grant_loop(self) -> None:
        """Send CREDIT frames from a dedicated thread: the releasing
        thread is often the driver inside ``waiter_next``, which must not
        block on a peer's send buffer."""
        while True:
            with self._grant_cv:
                while not self._grants and not self._stop.is_set():
                    self._grant_cv.wait(1.0)
                if self._stop.is_set():
                    return
                batch, self._grants = dict(self._grants), {}
            for node_id, grant in batch.items():
                with self._tlock:
                    peer = self._peers.get(node_id)
                conn = peer.current_conn() if peer is not None else None
                if conn is None or not conn.send_frame(
                        control_frame(FT_CREDIT, {"n": grant})):
                    # no live socket: the reconnect WELCOME re-announces
                    # the true window, so the grant is simply dropped
                    log.info("dropped %d-byte grant for offline peer %s",
                             grant, node_id)

    # ------------------------------------------------------------- liveness
    def _reap_loop(self) -> None:
        interval = max(0.05, min(1.0, self.heartbeat_timeout / 4))
        while not self._stop.wait(interval):
            with self._tlock:
                peers = list(self._peers.values())
            for peer in peers:
                if peer.silent_for() > self.heartbeat_timeout:
                    # expire by silence whether or not the socket is still
                    # attached: a kill -9'd peer delivers EOF (the conn is
                    # long gone) but must still leave the roster
                    conn = peer.current_conn()
                    if conn is not None:
                        conn.close()
                        peer.detach(conn)
                    if self.mark_node_dead(peer.node_id):
                        log.warning("peer %s heartbeat expired (%.1fs "
                                    "silent); dropped from roster",
                                    peer.node_id, peer.silent_for())
                peer.cache.reap()


class _Call:
    """One in-flight REQ on the client: the prebuilt frame parts stay
    around so a reconnect can resend them under the same msg_id."""

    __slots__ = ("seq", "parts", "nbytes", "event", "resp_header",
                 "resp_body", "failed")

    def __init__(self, seq: int, parts, nbytes: int):
        self.seq = seq
        self.parts = parts
        self.nbytes = nbytes
        self.event = threading.Event()
        self.resp_header: Optional[Dict[str, object]] = None
        self.resp_body: Optional[memoryview] = None
        self.failed = False


class TcpFleetConnection(FleetConnection):
    """Client side of the socket transport: connects, speaks
    HELLO/WELCOME, multiplexes typed fleet calls as REQ/RES exchanges,
    PINGs for liveness, blocks sends on the credit gate, and reconnects
    with resume (in-flight REQs are resent under their original msg_ids —
    the server's dedup cache makes that exactly-once)."""

    def __init__(self, host: str, port: int, node_id: str, *,
                 ssl_context=None, server_hostname: Optional[str] = None,
                 request_timeout: float = 30.0, connect_timeout: float = 5.0,
                 heartbeat_interval: float = 1.0,
                 heartbeat_timeout: float = 10.0,
                 reconnect_backoff: float = 0.05,
                 max_disconnected: Optional[float] = None,
                 max_frame: int = DEFAULT_MAX_FRAME):
        self.host, self.port = host, int(port)
        self.node_id = node_id
        self._ssl = ssl_context
        self._server_hostname = server_hostname or host
        self.request_timeout = request_timeout
        self.connect_timeout = connect_timeout
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        self.reconnect_backoff = reconnect_backoff
        # give-up horizon: continuously disconnected for this long -> the
        # connection closes itself, so an orphaned SuperNode process whose
        # server is gone exits instead of reconnect-looping forever
        self.max_disconnected = max_disconnected
        self.max_frame = int(max_frame)
        self._gate = CreditGate()
        self._lock = threading.Lock()
        self._pending: Dict[str, _Call] = {}    # guarded-by: _lock
        self._sock: Optional[socket.socket] = None  # guarded-by: _lock
        self._send_lock = threading.Lock()
        # run-thread-only connection state (re-created per connect)
        self._reader = FrameReader(self.max_frame)
        self._hb = heartbeat_interval
        self._seq = itertools.count()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"tcp-client-{node_id}")
        self._thread.start()

    # --------------------------------------------------------------- public
    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def register(self, node_id: str) -> None:
        self._call("register", {}, b"")

    def pull_task(self, node_id: str) -> Tuple[str, bytes]:
        header, body = self._call("pull_task_ins", {}, b"")
        return str(header.get("id", "")), body

    def push_result(self, task_id: str, res: bytes) -> None:
        # a "LATE" status is fine: the round gave up, the server dropped it
        self._call("push_task_res", {"id": task_id}, res)

    def unary(self, method: str, request: bytes) -> bytes:
        """Compatibility shim for byte-level callers; the typed wrappers
        above are the zero-copy fast path the SuperNode loop uses."""
        if method == "register":
            self.register(request.decode())
            return b"OK"
        if method == "pull_task_ins":
            tid, task = self.pull_task(request.decode())
            return msgpack.packb({"id": tid, "task": bytes(task)},
                                 use_bin_type=True)
        if method == "push_task_res":
            d = msgpack.unpackb(request, raw=False)
            self.push_result(d["id"], d["res"])
            return b"OK"
        raise ValueError(f"unknown fleet method {method!r}")

    def close(self) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        self._gate.close()
        with self._lock:
            sock = self._sock
        if sock is not None:
            with self._send_lock:
                try:
                    send_parts(sock, control_frame(FT_BYE,
                                                   {"reason": "stop"}))
                except OSError:
                    pass
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        self._fail_pending()
        self._thread.join(timeout=2.0)

    # ----------------------------------------------------------------- call
    def _call(self, method: str, extra: Dict[str, object], body,
              timeout: Optional[float] = None
              ) -> Tuple[Dict[str, object], memoryview]:
        deadline = time.monotonic() + (timeout or self.request_timeout)
        seq = next(self._seq)
        msg_id = f"{self.node_id}:{seq}"
        header: Dict[str, object] = {"i": msg_id, "m": method}
        header.update(extra)
        parts = data_frame_parts(FT_REQ, header, body)
        call = _Call(seq, parts, frame_nbytes(parts))
        with self._lock:
            if self._stop.is_set():
                raise ConnectionError("connection closed")
            self._pending[msg_id] = call
        try:
            # backpressure: blocks HERE, in the sender, while the server
            # still holds a window's worth of our un-consumed bytes
            if not self._gate.acquire(call.nbytes, deadline):
                raise RequestTimeout(
                    f"{self.node_id} [{method}] blocked on flow-control "
                    f"credits", target="server", topic=method,
                    timeout=timeout or self.request_timeout)
            self._send_call(call)     # best effort; reconnect resends
            if not call.event.wait(deadline - time.monotonic()):
                raise RequestTimeout(
                    f"{self.node_id} [{method}] timed out",
                    target="server", topic=method,
                    timeout=timeout or self.request_timeout)
            if call.failed or call.resp_header is None:
                raise ConnectionError("connection closed")
            err = call.resp_header.get("e")
            if err:
                if call.resp_header.get("k") == "timeout":
                    raise RequestTimeout(str(err), target="server",
                                         topic=method)
                raise RuntimeError(f"server error: {err}")
            return call.resp_header, call.resp_body
        finally:
            with self._lock:
                self._pending.pop(msg_id, None)

    def _send_call(self, call: _Call) -> None:
        with self._lock:
            sock = self._sock
        if sock is None:
            return               # reconnect pass will send it
        with self._send_lock:
            try:
                send_parts(sock, *call.parts)
            except OSError:
                pass             # the run loop notices and reconnects

    def _fail_pending(self) -> None:
        with self._lock:
            calls = list(self._pending.values())
        for call in calls:
            call.failed = True
            call.event.set()

    # ------------------------------------------------------------ run loop
    def _run(self) -> None:
        backoff = self.reconnect_backoff
        last_connected = time.monotonic()
        while not self._stop.is_set():
            try:
                sock = self._connect()
            except (OSError, FrameError) as e:
                if self.max_disconnected is not None and \
                        time.monotonic() - last_connected > \
                        self.max_disconnected:
                    log.warning("%s: disconnected > %.1fs (%r); giving up",
                                self.node_id, self.max_disconnected, e)
                    break
                self._stop.wait(backoff)
                backoff = min(backoff * 2, 2.0)
                continue
            backoff = self.reconnect_backoff
            try:
                self._serve(sock)
            except (OSError, FrameError) as e:
                if not self._stop.is_set():
                    log.info("%s: connection lost (%r); reconnecting",
                             self.node_id, e)
            finally:
                last_connected = time.monotonic()
                with self._lock:
                    self._sock = None
                try:
                    sock.close()
                except OSError:
                    pass
        self._stop.set()
        self._gate.close()
        self._fail_pending()

    def _connect(self) -> socket.socket:
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.connect_timeout)
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            if self._ssl is not None:
                sock = self._ssl.wrap_socket(
                    sock, server_hostname=self._server_hostname)
            send_parts(sock, control_frame(FT_HELLO, {
                "node": self.node_id, "proto": PROTO_VERSION}))
            reader = FrameReader(self.max_frame)
            frames: List[Tuple[int, memoryview]] = []
            while not frames:
                got = reader.read_from(sock)
                if got is None:
                    raise ConnectionError("EOF before WELCOME")
                frames = got
            ftype, payload = frames[0]
            if ftype != FT_WELCOME:
                raise FrameError(f"expected WELCOME, got type {ftype}")
            fields = parse_control(payload)
            self._gate.reset(int(fields["credits"]), int(fields["limit"]))
            self._reader = reader
            self._hb = min(self.heartbeat_interval,
                           float(fields.get("hb", self.heartbeat_timeout))
                           / 3)
        except BaseException:
            sock.close()
            raise
        return sock

    def _serve(self, sock: socket.socket) -> None:
        sock.settimeout(max(0.05, self._hb / 2))
        with self._lock:
            self._sock = sock
            resend = sorted(self._pending.values(), key=lambda c: c.seq)
        # resume: in-flight REQs go out again under their original msg_ids
        # — the server's dedup cache executes once and replays responses.
        # Resends do NOT re-acquire credits: the WELCOME balance already
        # reflects what the server still holds from us.
        for call in resend:
            with self._send_lock:
                send_parts(sock, *call.parts)
        if resend:
            log.info("%s: resent %d in-flight request(s) after reconnect",
                     self.node_id, len(resend))
        last_rx = time.monotonic()
        last_ping = 0.0
        while not self._stop.is_set():
            now = time.monotonic()
            if now - last_rx > self.heartbeat_timeout:
                raise ConnectionError(
                    f"server silent for {now - last_rx:.1f}s")
            if now - last_ping >= self._hb:
                last_ping = now
                with self._send_lock:
                    send_parts(sock, control_frame(FT_PING, {"t": now}))
            try:
                frames = self._reader.read_from(sock)
            except socket.timeout:
                continue
            if frames is None:
                raise ConnectionError("server closed the connection")
            last_rx = time.monotonic()
            for ftype, payload in frames:
                self._on_frame(sock, ftype, payload)

    def _on_frame(self, sock: socket.socket, ftype: int,
                  payload: memoryview) -> None:
        if ftype == FT_RES:
            header, body = split_data(payload)
            msg_id = str(header.get("i", ""))
            with self._lock:
                call = self._pending.get(msg_id)
            if call is not None:
                call.resp_header = header
                call.resp_body = body
                call.event.set()
            return
        if ftype == FT_CREDIT:
            self._gate.grant(int(parse_control(payload)["n"]))
            return
        if ftype == FT_PONG:
            return                       # any frame already refreshed rx
        if ftype == FT_PING:             # symmetric, though servers don't
            with self._send_lock:
                send_parts(sock, control_frame(FT_PONG,
                                               parse_control(payload)))
            return
        if ftype == FT_BYE:
            raise ConnectionError("server said BYE")
        raise FrameError(f"unexpected frame type {ftype} from server")


def run_supernode(host: str, port: int, node_id: str, client_app_factory,
                  *, run_seconds: float = 120.0,
                  heartbeat_interval: float = 0.5,
                  max_disconnected: float = 15.0,
                  ssl_context=None) -> None:
    """Blocking SuperNode-over-TCP entry point for a child *process* (the
    multi-process CI lane spawns 16 of these).  ``client_app_factory`` is
    a picklable callable ``node_id -> ClientApp``.  Exits when the server
    goes away for ``max_disconnected`` seconds or after ``run_seconds`` —
    a crashed parent can therefore never strand the child forever."""
    conn = TcpFleetConnection(host, port, node_id,
                              heartbeat_interval=heartbeat_interval,
                              max_disconnected=max_disconnected,
                              ssl_context=ssl_context)
    node = SuperNode(node_id, client_app_factory(node_id), conn)
    node.start()
    deadline = time.monotonic() + run_seconds
    try:
        while time.monotonic() < deadline and not conn.closed:
            time.sleep(0.1)
    finally:
        node.stop()

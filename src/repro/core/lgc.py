"""Local gRPC Client analogue (paper Fig. 4, server side).

The LGC lives in the FLARE server job process and completes relayed Flower
calls against the actual SuperLink (hop 4), sending results back down the
reliable path (hops 5–6).
"""
from __future__ import annotations

import msgpack

from repro.core.framing import unpack_unary
from repro.core.superlink import SuperLink
from repro.runtime.ccp import JobContext
from repro.runtime.reliable import RequestTimeout
from repro.runtime.transport import Message


class LGC:
    def __init__(self, ctx: JobContext, superlink: SuperLink):
        self.link = superlink
        ctx.register_handler("flower/unary", self._on_unary)

    def _on_unary(self, msg: Message) -> bytes:
        method, request = unpack_unary(msg.payload)
        try:
            resp = self.link.fleet_unary(method, request)
            return msgpack.packb({"r": resp, "e": ""}, use_bin_type=True)
        except Exception as e:  # noqa: BLE001
            # tag the error kind so the LGS can demote timeouts to a
            # retryable RequestTimeout instead of a fatal RuntimeError
            kind = ("timeout" if isinstance(e, (TimeoutError, RequestTimeout))
                    else "error")
            return msgpack.packb({"r": b"", "e": repr(e), "k": kind},
                                 use_bin_type=True)

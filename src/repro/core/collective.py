"""Tight-mode integration: the FL round as a JAX collective program.

The paper routes Flower's aggregation traffic through FLARE's reliable
messaging; its §6 roadmap is "very large messages, up to hundreds of
gigabytes" for foundation models.  On a TPU fleet the natural realization
is to map federated *sites* onto the ``"pod"`` mesh axis and lower the
aggregation itself to an ICI collective:

  * within a pod: ordinary (data, model)-parallel local training (GSPMD);
  * across pods: the state is pod-stacked (leading num_pods dim sharded
    over "pod") and the K local steps are a vmap over it, so no gradient
    sync crosses pods; FedAvg is then a mean over the pod-sharded dim —
    one all-reduce of the parameter pytree per round, byte-identical in
    meaning to the loose-mode ReliableMessage exchange.

``make_fl_round_step`` is what the multi-pod dry-run lowers: its HLO
contains the cross-pod all-reduce whose bytes are the paper's "hundreds of
GB" message, scheduled by XLA instead of gRPC.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import TrainConfig
from repro.models.api import Model
from repro.train.steps import TrainState, make_train_step


def tight_fedavg(stacked_params, mesh: Mesh, axis: str = "pod"):
    """FedAvg a pod-stacked param pytree: every leaf has a leading
    num_pods dim sharded over `axis`; the mean over it lowers to one
    cross-pod all-reduce and the broadcast back keeps the result
    pod-sharded (= FedAvg result distributed to every site)."""

    def avg(x):
        m = jnp.mean(x, axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape)

    in_sh = jax.tree.map(
        lambda x: jax.sharding.NamedSharding(
            mesh, P(axis, *([None] * (x.ndim - 1)))), stacked_params)
    fn = jax.jit(lambda p: jax.tree.map(avg, p), in_shardings=(in_sh,),
                 out_shardings=in_sh)
    with mesh:
        return fn(stacked_params)


def make_fl_round_step(model: Model, train_cfg: TrainConfig, mesh: Mesh,
                       local_steps: int = 1, impl: str = "xla",
                       aggregate_dtype=None, aggregate_opt_state: bool = True):
    """One synchronized FL round: K per-pod local steps + cross-pod FedAvg.

    Pure-pjit formulation (a partially-manual shard_map over "pod" with the
    full rematted trunk inside crashes XLA's SPMD partitioner): the state is
    *pod-stacked* — every param leaf gains a leading num_pods dim sharded
    over "pod" — and local training is a ``vmap`` over that dim, so no
    gradient sync crosses pods during the K local steps.  FedAvg is then a
    ``mean`` over the pod-sharded dim, which XLA lowers to exactly one
    all-reduce of the parameter pytree across pods — the paper's aggregation
    round as an ICI collective.

    Options (used by the §Perf hillclimb):
      aggregate_dtype     cast params to this dtype for the cross-pod
                          all-reduce (e.g. jnp.bfloat16 halves the bytes —
                          the tight-mode analogue of Flower's compression
                          mods); None = native dtype.
      aggregate_opt_state False = FedAvg only the params; Adam moments stay
                          local per pod (pure FedAvg semantics, 1/3 bytes).
    """
    train_step = make_train_step(model, train_cfg, impl=impl)

    def round_fn(state: TrainState, batches) -> tuple:
        def per_pod(st, bat):
            def one(s, b):
                s2, m = train_step(s, b)
                return s2, m["loss"]

            return jax.lax.scan(one, st, bat)

        state, losses = jax.vmap(per_pod)(state, batches)

        def fedavg(x):
            if not jnp.issubdtype(x.dtype, jnp.floating):
                return x
            xa = x.astype(aggregate_dtype) if aggregate_dtype else x
            avg = jnp.mean(xa, axis=0, keepdims=True)    # all-reduce over pod
            return jnp.broadcast_to(avg, x.shape).astype(x.dtype)

        params = jax.tree.map(fedavg, state.params)
        opt_state = (jax.tree.map(fedavg, state.opt_state)
                     if aggregate_opt_state else state.opt_state)
        return (TrainState(params, opt_state, state.step),
                {"round_losses": losses})

    return round_fn


def pod_stacked_state(state: TrainState, num_pods: int) -> TrainState:
    """Tile a TrainState with a leading pod dim (abstract or concrete)."""
    def tile(x):
        if hasattr(x, "dtype") and not hasattr(x, "addressable_shards") \
                and isinstance(x, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct((num_pods,) + x.shape, x.dtype)
        return jnp.broadcast_to(x[None], (num_pods,) + x.shape)

    return jax.tree.map(tile, state)

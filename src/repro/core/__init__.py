"""The paper's contribution: running unmodified Flower apps inside the
FLARE runtime by routing the Flower transport through FLARE (Fig. 4)."""
from repro.core.superlink import (  # noqa: F401
    SuperLink, SuperLinkDriver, SuperNode, NativeConnection,
    TaskStream, EdgeAggregatorApp, InlineFleetDriver, make_edge_tier,
)
from repro.core.lgs import LGSConnection  # noqa: F401
from repro.core.lgc import LGC  # noqa: F401
from repro.core.interop import (  # noqa: F401
    run_native, run_in_flare, run_hierarchical,
)
from repro.core.collective import tight_fedavg, make_fl_round_step  # noqa: F401

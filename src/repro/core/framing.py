"""Length-prefixed binary framing for the socket transport.

Wire layout (little-endian)::

    [u32 length][u8 ftype][payload ...]        # length = 1 + len(payload)

Control frames (payload is one msgpack map):

    ========  ====  ==============  =========================================
    HELLO     0x01  client->server  {"node": str, "proto": int}
    WELCOME   0x02  server->client  {"credits": int, "max_frame": int,
                                     "hb": float}
    CREDIT    0x03  server->client  {"n": int}
    PING      0x04  either          {"t": float}  (opaque echo token)
    PONG      0x05  either          {"t": float}
    BYE       0x06  either          {"reason": str}
    ========  ====  ==============  =========================================

Data frames (payload = ``[u32 hlen][msgpack header][raw body]``):

    ========  ====  ==============  =========================================
    REQ       0x10  client->server  header {"i": msg_id, "m": method, ...}
    RES       0x11  server->client  header {"i": msg_id, "e": err, "k": kind}
    ========  ====  ==============  =========================================

The raw body rides *after* the msgpack header so model-size TaskIns/TaskRes
bytes are never re-serialized through msgpack: the receiver fills one
exact-size buffer per frame and hands the body up as a **read-only
memoryview** — the 0xF1–0xF4 codec frames inside it decode zero-copy via
``np.frombuffer`` straight off that buffer (views frozen per the aliasing
invariant, docs/INVARIANTS.md).  Frame-type bytes stay below ``0xF0`` on
purpose: the codec-byte registry in ``repro.fl.flat`` owns 0xF0–0xFF.
"""
from __future__ import annotations

import struct
from typing import Dict, List, Optional, Tuple

import msgpack

# frame types (codec registry owns 0xF0-0xFF; these must stay below it)
FT_HELLO = 0x01
FT_WELCOME = 0x02
FT_CREDIT = 0x03
FT_PING = 0x04
FT_PONG = 0x05
FT_BYE = 0x06
FT_REQ = 0x10
FT_RES = 0x11

PROTO_VERSION = 1
DEFAULT_MAX_FRAME = 256 << 20            # one corrupt length prefix must
#                                          not allocate unbounded memory

_LEN = struct.Struct("<I")               # frame length prefix
_HLEN = struct.Struct("<I")              # data-frame header length


class FrameError(ValueError):
    """Malformed or protocol-violating frame; the connection is torn down
    (never silently resynchronized — a desynced length prefix would turn
    payload bytes into frame headers)."""


class FrameReader:
    """Incremental frame decoder: survives arbitrary chunking (partial
    reads) because each ``feed``/``read_from`` step just fills the current
    target buffer — the 4-byte length prefix, then one exact-size frame
    buffer.  Every frame gets its *own* buffer, so the emitted read-only
    payload views never alias a later frame or any shared stream buffer,
    and a zero-copy ``np.frombuffer`` decode can outlive the reader.

    Not thread-safe: one reader per connection, fed by that connection's
    single reader thread.
    """

    def __init__(self, max_frame: int = DEFAULT_MAX_FRAME):
        self.max_frame = int(max_frame)
        self._buf = bytearray(_LEN.size)     # current target: prefix|frame
        self._is_prefix = True
        self._got = 0

    def _advance(self, n: int,
                 out: List[Tuple[int, memoryview]]) -> None:
        self._got += n
        if self._got < len(self._buf):
            return                           # target still partial
        if self._is_prefix:
            need = _LEN.unpack(self._buf)[0]
            if not 1 <= need <= self.max_frame:
                raise FrameError(f"frame length {need} outside "
                                 f"[1, {self.max_frame}]")
            self._buf = bytearray(need)
            self._is_prefix = False
        else:
            frame = self._buf
            self._buf = bytearray(_LEN.size)
            self._is_prefix = True
            out.append((frame[0], memoryview(frame)[1:].toreadonly()))
        self._got = 0

    def feed(self, chunk: bytes) -> List[Tuple[int, memoryview]]:
        """Consume one received chunk; return the ``(ftype, payload)``
        frames it completed (possibly none, possibly several)."""
        out: List[Tuple[int, memoryview]] = []
        mv = memoryview(chunk)
        while mv.nbytes:
            take = min(len(self._buf) - self._got, mv.nbytes)
            self._buf[self._got:self._got + take] = mv[:take]
            mv = mv[take:]
            self._advance(take, out)
        return out

    def read_from(self, sock) -> Optional[List[Tuple[int, memoryview]]]:
        """One ``recv_into`` step straight into the current frame buffer
        (no intermediate chunk copy).  Returns completed frames (possibly
        an empty list), or ``None`` on clean EOF at a frame boundary.
        Raises ``ConnectionError`` if the peer closed mid-frame, and lets
        ``socket.timeout`` propagate with the partial state intact — the
        caller's heartbeat tick resumes the same frame on the next call.
        """
        n = sock.recv_into(memoryview(self._buf)[self._got:])
        if n == 0:
            if self._is_prefix and self._got == 0:
                return None
            raise ConnectionError("peer closed mid-frame")
        out: List[Tuple[int, memoryview]] = []
        self._advance(n, out)
        return out


# --------------------------------------------------------------------- write
def control_frame(ftype: int, fields: Dict[str, object]) -> bytes:
    """One control frame (msgpack-map payload), ready to send."""
    payload = msgpack.packb(fields, use_bin_type=True)
    return _LEN.pack(1 + len(payload)) + bytes((ftype,)) + payload


def data_frame_parts(ftype: int, header: Dict[str, object],
                     body) -> Tuple[bytes, ...]:
    """A REQ/RES frame as ``(prefix, body)`` buffer parts: the raw body is
    referenced, never copied into the frame — callers hand both parts to
    :func:`send_parts`."""
    h = msgpack.packb(header, use_bin_type=True)
    nbody = len(body) if isinstance(body, (bytes, bytearray)) else \
        memoryview(body).nbytes
    prefix = (_LEN.pack(1 + _HLEN.size + len(h) + nbody)
              + bytes((ftype,)) + _HLEN.pack(len(h)) + h)
    return (prefix, body) if nbody else (prefix,)


def frame_nbytes(parts: Tuple[bytes, ...]) -> int:
    """Total on-the-wire size of a frame built by
    :func:`data_frame_parts` — the unit the credit window counts."""
    return sum(len(p) if isinstance(p, (bytes, bytearray))
               else memoryview(p).nbytes for p in parts)


def send_parts(sock, *parts) -> None:
    """sendall with an explicit short-write loop (``sock.send``), so a
    tiny ``SO_SNDBUF`` exercises partial writes deterministically in
    tests.  The caller serializes concurrent senders (per-connection send
    lock) — interleaved frames would desync the length prefix."""
    for p in parts:
        mv = memoryview(p)
        while mv.nbytes:
            mv = mv[sock.send(mv):]


# ---------------------------------------------------------------------- read
def parse_control(payload) -> Dict[str, object]:
    return msgpack.unpackb(payload, raw=False)


def split_data(payload: memoryview) -> Tuple[Dict[str, object], memoryview]:
    """Split a REQ/RES payload into ``(header, body_view)``; the body view
    aliases the frame buffer (read-only, zero-copy)."""
    if payload.nbytes < _HLEN.size:
        raise FrameError("data frame shorter than its header-length field")
    hlen = _HLEN.unpack_from(payload, 0)[0]
    end = _HLEN.size + hlen
    if end > payload.nbytes:
        raise FrameError(f"data-frame header length {hlen} overruns the "
                         f"{payload.nbytes}-byte payload")
    header = msgpack.unpackb(payload[_HLEN.size:end], raw=False)
    return header, payload[end:]


# ------------------------------------------------------------ unary envelope
def pack_unary(method: str, request: bytes) -> bytes:
    """Canonical unary-call envelope (``{"m": method, "q": request}``) the
    FLARE-bridged LGS/LGC pair relays; the TCP transport carries the same
    call as a typed REQ header + raw body instead, so model-size payloads
    skip this msgpack copy."""
    return msgpack.packb({"m": method, "q": request}, use_bin_type=True)


def unpack_unary(b) -> Tuple[str, bytes]:
    d = msgpack.unpackb(b, raw=False)
    return d["m"], d["q"]

"""Interop entry points — the paper's §5.1 experiment surface.

``run_native(server_app, client_app_fn, sites)``
    Flower running "alone": SuperLink + SuperNodes with direct in-process
    connections.

``run_in_flare(runtime, server_app, client_app_fn, sites)``
    The SAME app objects deployed as a FLARE job: the server job process
    hosts SuperLink + LGC + the ServerApp; each site's CCP spawns a client
    job process hosting SuperNode + ClientApp behind an LGS.  No app code
    changes — only the connection object differs (paper §2's goal).

Both return the ServerApp :class:`~repro.fl.server.History`, so the Fig. 5
reproducibility claim is checked by comparing the two histories bit-for-bit.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from repro.core.lgc import LGC
from repro.core.lgs import LGSConnection
from repro.core.superlink import (NativeConnection, SuperLink,
                                  SuperLinkDriver, SuperNode,
                                  make_edge_tier)
from repro.fl.client import ClientApp
from repro.fl.server import History, ServerApp
from repro.runtime.ccp import JobContext
from repro.runtime.jobs import JobSpec
from repro.runtime.scp import FlareRuntime


# ---------------------------------------------------------------------------
# native (Flower alone)
# ---------------------------------------------------------------------------
def run_native(server_app: ServerApp,
               client_app_fn: Callable[[str], ClientApp],
               sites: Sequence[str]) -> History:
    if getattr(server_app.config, "transport", "inproc") == "tcp":
        return run_tcp(server_app, client_app_fn, sites)
    link = SuperLink()
    nodes = [SuperNode(s, client_app_fn(s), NativeConnection(link))
             for s in sites]
    for n in nodes:
        n.start()
    try:
        driver = SuperLinkDriver(link, expected_nodes=len(sites))
        return server_app.run(driver)
    finally:
        for n in nodes:
            n.stop()


def run_tcp(server_app: ServerApp,
            client_app_fn: Callable[[str], ClientApp],
            sites: Sequence[str], *,
            server_ssl=None,
            client_ssl_fn: Optional[Callable[[str], object]] = None
            ) -> History:
    """Native topology over real sockets: a
    :class:`~repro.core.transport.TcpSuperLink` bound to
    ``config.bind_host:config.bind_port`` with one TCP-connected
    SuperNode per site — same apps, same Driver, different wire (the
    Fig. 5 claim extended from the FLARE bridge to a real network).  The
    TLS hook point: pass an ``ssl.SSLContext`` for the listener and a
    per-site context factory for the clients (CI runs plaintext)."""
    from repro.core.transport import TcpFleetConnection, TcpSuperLink
    cfg = server_app.config
    with TcpSuperLink(cfg.bind_host, cfg.bind_port,
                      ssl_context=server_ssl) as link:
        host, port = link.address
        nodes = [SuperNode(s, client_app_fn(s), TcpFleetConnection(
                     host, port, s,
                     ssl_context=client_ssl_fn(s) if client_ssl_fn
                     else None))
                 for s in sites]
        for n in nodes:
            n.start()
        try:
            driver = SuperLinkDriver(link, expected_nodes=len(sites))
            return server_app.run(driver)
        finally:
            for n in nodes:
                n.stop()


def run_hierarchical(server_app: ServerApp,
                     client_app_fn: Callable[[str], ClientApp],
                     sites: Sequence[str], num_edges: int,
                     edge_timeout: float = 60.0) -> History:
    """Two-tier native run: ``sites`` clients partitioned across
    ``num_edges`` edge aggregators (inline child fleets, no per-client
    threads), so the root server folds **O(num_edges)** payloads per
    round instead of O(len(sites)).  With a weighted-sum strategy the
    sync result continues the flat fold's arithmetic exactly — see
    :class:`~repro.core.superlink.EdgeAggregatorApp`."""
    link = SuperLink()
    apps = {s: client_app_fn(s) for s in sites}
    edges = make_edge_tier(link, apps, num_edges, timeout=edge_timeout)
    try:
        driver = SuperLinkDriver(link, expected_nodes=num_edges)
        return server_app.run(driver)
    finally:
        for n in edges:
            n.stop()


# ---------------------------------------------------------------------------
# inside FLARE (the paper's integration)
# ---------------------------------------------------------------------------
class _FlowerServerJob:
    """FLARE server job process: SuperLink + LGC + ServerApp."""

    def __init__(self, server_app: ServerApp, num_sites: int):
        self.server_app = server_app
        self.num_sites = num_sites

    def run(self, ctx: JobContext) -> History:
        link = SuperLink()
        LGC(ctx, link)                       # relayed fleet calls now land here
        driver = SuperLinkDriver(link, expected_nodes=self.num_sites)
        return self.server_app.run(driver)


class _FlowerClientJob:
    """FLARE client job process: SuperNode pointed at the LGS."""

    def __init__(self, site: str, client_app):
        self.site = site
        self.client_app = client_app
        self._node: Optional[SuperNode] = None

    def run(self, ctx: JobContext) -> None:
        app = self.client_app
        if not isinstance(app, ClientApp) and callable(app):
            # hybrid integration (paper §5.2): the factory may consume the
            # FLARE JobContext, e.g. to build a SummaryWriter for metric
            # streaming inside otherwise-unmodified Flower client code
            app = app(ctx)
        conn = LGSConnection(ctx)            # <- the ONLY difference vs native
        self._node = SuperNode(self.site, app, conn)
        self._node.start()
        # serve until the CCP stops the job process
        ctx.stop_event.wait()
        self._node.stop()


def run_in_flare(runtime: FlareRuntime, server_app: ServerApp,
                 client_app_fn: Callable[[str], ClientApp],
                 sites: Optional[Sequence[str]] = None,
                 job_name: str = "flower-app",
                 timeout: float = 300.0) -> History:
    """Submit the Flower app as a FLARE job and wait for its History."""
    sites = list(sites or runtime.sites())
    admin = runtime.provisioner.issue("admin", "admin")
    spec = JobSpec(
        name=job_name,
        server_app_fn=lambda: _FlowerServerJob(server_app, len(sites)),
        client_app_fn=lambda site: _FlowerClientJob(site, client_app_fn(site)),
        min_sites=len(sites),
    )
    job_id = runtime.submit_job(spec, admin)
    rec = runtime.wait(job_id, timeout=timeout)
    if not rec.done.is_set():
        raise TimeoutError(f"job {job_id} did not finish in {timeout}s")
    if rec.error:
        raise RuntimeError(f"job {job_id} failed:\n{rec.error}")
    return rec.result

"""Population registry: who is in the fleet, and who actually shows up.

At fleet scale a round samples ``k`` of ``N`` nodes instead of fanning
out to everyone (Flower's scalability recipe; the FLARE runtime's tiered
deployments assume the same).  :class:`PopulationRegistry` keeps a tiny
per-node success/failure history — fed by the per-node failure records
the ServerApp's ``_exchange`` already produces — and draws each round's
participants with probability proportional to a Laplace-smoothed
availability estimate, so flaky nodes are demoted (but never starved:
``min_weight`` keeps every node eligible).

Determinism: sampling must be reproducible across runs and independent
of dict/arrival order, so draws use ``np.random.default_rng`` seeded
from ``(seed, round)`` via ``SeedSequence`` over the *sorted* node list
— same seed, same history, same round => same sample (the det-entropy
rule in :mod:`repro.analysis` bans ambient entropy here).  No clocks:
history is event-counting only, so replaying a run replays its samples.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np


class PopulationRegistry:
    """Availability-tracked population with seed-deterministic sampling.

    ``observe(successes, failures)`` feeds one round's outcome;
    ``sample(nodes, k, rnd)`` draws ``k`` distinct nodes weighted by
    ``availability(node)`` — the Laplace estimate ``(s+1)/(s+f+2)``,
    floored at ``min_weight`` so a node with a bad streak keeps a
    nonzero chance to rejoin (its estimate recovers as it succeeds).
    """

    def __init__(self, seed: int = 0, min_weight: float = 0.05):
        if not 0.0 < min_weight <= 1.0:
            raise ValueError(f"min_weight must be in (0, 1], got {min_weight}")
        self.seed = int(seed)
        self.min_weight = float(min_weight)
        self._success: Dict[str, int] = {}
        self._failure: Dict[str, int] = {}
        self._last_error: Dict[str, str] = {}

    # ------------------------------------------------------------- history
    def observe(self, successes: Iterable[str] = (),
                failures: Iterable[Tuple[str, str]] = ()) -> None:
        """Record one round's outcome: node ids that responded, and the
        ServerApp's per-node ``(node, reason)`` failure records."""
        for n in successes:
            self._success[n] = self._success.get(n, 0) + 1
        for n, reason in failures:
            self._failure[n] = self._failure.get(n, 0) + 1
            self._last_error[n] = str(reason)

    def availability(self, node: str) -> float:
        """Laplace-smoothed success rate in [0, 1]; 0.5 for unseen nodes."""
        s = self._success.get(node, 0)
        f = self._failure.get(node, 0)
        return (s + 1.0) / (s + f + 2.0)

    def weight(self, node: str) -> float:
        return max(self.availability(node), self.min_weight)

    def snapshot(self, nodes: Sequence[str]) -> Dict[str, Dict[str, object]]:
        """Per-node history view (successes, failures, availability,
        last error) for logging/metrics."""
        out: Dict[str, Dict[str, object]] = {}
        for n in sorted(nodes):
            out[n] = {"successes": self._success.get(n, 0),
                      "failures": self._failure.get(n, 0),
                      "availability": self.availability(n),
                      "last_error": self._last_error.get(n, "")}
        return out

    # ------------------------------------------------------------ sampling
    def sample(self, nodes: Sequence[str], k: int, rnd: int) -> List[str]:
        """Draw ``min(k, len(nodes))`` distinct nodes, availability-
        weighted, deterministic in ``(seed, rnd, sorted(nodes),
        history)``.  Returned sorted (the ServerApp's canonical order)."""
        pool = sorted(nodes)
        if k >= len(pool):
            return pool
        if k <= 0:
            raise ValueError(f"sample_k must be >= 1, got {k}")
        w = np.array([self.weight(n) for n in pool], np.float64)
        p = w / w.sum()
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, int(rnd))))
        idx = rng.choice(len(pool), size=k, replace=False, p=p)
        return sorted(pool[i] for i in idx)

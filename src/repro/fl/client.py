"""Client-side app layer (Flower analogue, paper Listing 2).

    class MyClient(NumPyClient):
        def fit(self, parameters, config): ...
        def evaluate(self, parameters, config): ...

    def client_fn(cid): return MyClient(cid).to_client()
    app = ClientApp(client_fn=client_fn, mods=[DPMod(...)])

``ClientApp.handle(bytes) -> bytes`` is the entire transport contract —
which is what lets the SAME app object run natively or inside the FLARE
runtime with no code changes (the paper's core claim).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fl.messages import (EvaluateIns, EvaluateRes, FitIns, FitRes,
                               TaskIns, TaskRes, decode_evaluate_ins,
                               decode_fit_ins, decode_task_ins,
                               encode_evaluate_res, encode_fit_res,
                               encode_task_ins, encode_task_res,
                               arrays_to_bytes)

NDArrays = List[np.ndarray]


class NumPyClient:
    """Subclass and override fit / evaluate / get_parameters."""

    context: Dict[str, Any] = {}

    def get_parameters(self, config: Dict[str, Any]) -> NDArrays:
        raise NotImplementedError

    def fit(self, parameters: NDArrays, config: Dict[str, Any]
            ) -> Tuple[NDArrays, int, Dict[str, Any]]:
        raise NotImplementedError

    def evaluate(self, parameters: NDArrays, config: Dict[str, Any]
                 ) -> Tuple[float, int, Dict[str, Any]]:
        raise NotImplementedError

    def to_client(self) -> "Client":
        return Client(self)


class Client:
    """Byte-level client wrapper."""

    def __init__(self, numpy_client: NumPyClient):
        self.np_client = numpy_client

    def handle_fit(self, ins: FitIns) -> FitRes:
        params, n, metrics = self.np_client.fit(ins.parameters, ins.config)
        return FitRes(params, n, metrics)

    def handle_evaluate(self, ins: EvaluateIns) -> EvaluateRes:
        loss, n, metrics = self.np_client.evaluate(ins.parameters, ins.config)
        return EvaluateRes(loss, n, metrics)


# Mod signature: (task_ins, call_next) -> task_res  — Flower "mods" chain.
ModFn = Callable[[TaskIns, Callable[[TaskIns], TaskRes]], TaskRes]


class ClientApp:
    """Owns client_fn + the mod chain; transport-agnostic."""

    def __init__(self, client_fn: Callable[[str], Client],
                 mods: Optional[Sequence[ModFn]] = None):
        self.client_fn = client_fn
        self.mods = list(mods or [])
        self._clients: Dict[str, Client] = {}

    def _client(self, cid: str) -> Client:
        if cid not in self._clients:
            self._clients[cid] = self.client_fn(cid)
        return self._clients[cid]

    # -------------------------------------------------------------- handle
    def handle(self, task_ins_bytes: bytes, cid: str = "0") -> bytes:
        task = decode_task_ins(task_ins_bytes)

        def call(t: TaskIns) -> TaskRes:
            client = self._client(cid)
            try:
                if t.task_type == "fit":
                    res = client.handle_fit(decode_fit_ins(t.payload))
                    return TaskRes("fit", t.round, encode_fit_res(res),
                                   task_id=t.task_id)
                if t.task_type == "evaluate":
                    res = client.handle_evaluate(decode_evaluate_ins(t.payload))
                    return TaskRes("evaluate", t.round,
                                   encode_evaluate_res(res), task_id=t.task_id)
                if t.task_type == "get_parameters":
                    arrays = client.np_client.get_parameters({})
                    return TaskRes("get_parameters", t.round,
                                   arrays_to_bytes(arrays), task_id=t.task_id)
                return TaskRes(t.task_type, t.round, b"",
                               task_id=t.task_id, error="unknown task type")
            except Exception as e:  # noqa: BLE001
                return TaskRes(t.task_type, t.round, b"", task_id=t.task_id,
                               error=repr(e))

        chain = call
        for mod in reversed(self.mods):
            chain = _bind_mod(mod, chain)
        return encode_task_res(chain(task))


def _bind_mod(mod: ModFn, nxt: Callable[[TaskIns], TaskRes]):
    def bound(task: TaskIns) -> TaskRes:
        return mod(task, nxt)
    return bound

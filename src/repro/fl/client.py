"""Client-side app layer (Flower analogue, paper Listing 2).

    class MyClient(NumPyClient):
        def fit(self, parameters, config): ...
        def evaluate(self, parameters, config): ...

    def client_fn(cid): return MyClient(cid).to_client()
    app = ClientApp(client_fn=client_fn, mods=[DPMod(...)])

``ClientApp.handle(bytes) -> bytes`` is the entire transport contract —
which is what lets the SAME app object run natively or inside the FLARE
runtime with no code changes (the paper's core claim).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fl.flat import FlatParams, QuantParams, quantizable
from repro.fl.messages import (BF16_MAGIC, FLAT_MAGIC, Q8_MAGIC, QUANT_CODECS,
                               WIRE_CODECS, EvaluateIns, EvaluateRes, FitIns,
                               FitRes, TaskIns, TaskRes, decode_evaluate_ins,
                               decode_fit_ins, decode_fit_res,
                               decode_task_ins, encode_evaluate_res,
                               encode_fit_res, encode_properties_res,
                               encode_task_res, arrays_to_bytes, peek_config,
                               peek_params)

NDArrays = List[np.ndarray]


class NumPyClient:
    """Subclass and override fit / evaluate / get_parameters."""

    context: Dict[str, Any] = {}

    def get_parameters(self, config: Dict[str, Any]) -> NDArrays:
        raise NotImplementedError

    def get_properties(self, config: Dict[str, Any]) -> Dict[str, Any]:
        """Site capabilities/metadata; the ClientApp merges in the wire
        codecs this build speaks (``{"codecs": [...]}``) so the server can
        negotiate a compressed payload encoding."""
        return {}

    def fit(self, parameters: NDArrays, config: Dict[str, Any]
            ) -> Tuple[NDArrays, int, Dict[str, Any]]:
        raise NotImplementedError

    def trainable_ranges(self) -> Optional[Sequence[Tuple[int, int]]]:
        """Adapter/LoRA mode for the negotiated ``sparse`` codec: sorted,
        non-overlapping ``[start, stop)`` element ranges into the flat
        fp32 math vector that this client actually trains.  When set,
        a sparse fit result ships ONLY those coordinate ranges (0xF5
        ranges mode) instead of the TopK of the delta.  ``None`` (the
        default) means every coordinate is trainable — TopK mode."""
        return None

    def evaluate(self, parameters: NDArrays, config: Dict[str, Any]
                 ) -> Tuple[float, int, Dict[str, Any]]:
        raise NotImplementedError

    def to_client(self) -> "Client":
        return Client(self)


class Client:
    """Byte-level client wrapper."""

    def __init__(self, numpy_client: NumPyClient):
        self.np_client = numpy_client

    def handle_fit(self, ins: FitIns) -> FitRes:
        params, n, metrics = self.np_client.fit(ins.parameters, ins.config)
        return FitRes(params, n, metrics)

    def handle_evaluate(self, ins: EvaluateIns) -> EvaluateRes:
        loss, n, metrics = self.np_client.evaluate(ins.parameters, ins.config)
        return EvaluateRes(loss, n, metrics)


# Mod signature: (task_ins, call_next) -> task_res  — Flower "mods" chain.
ModFn = Callable[[TaskIns, Callable[[TaskIns], TaskRes]], TaskRes]


class ClientApp:
    """Owns client_fn + the mod chain; transport-agnostic."""

    def __init__(self, client_fn: Callable[[str], Client],
                 mods: Optional[Sequence[ModFn]] = None):
        self.client_fn = client_fn
        self.mods = list(mods or [])
        self._clients: Dict[str, Client] = {}

    def _client(self, cid: str) -> Client:
        if cid not in self._clients:
            self._clients[cid] = self.client_fn(cid)
        return self._clients[cid]

    # -------------------------------------------------------------- handle
    def handle(self, task_ins_bytes: bytes, cid: str = "0") -> bytes:
        task = decode_task_ins(task_ins_bytes)
        # round-start params stashed by the innermost fit decode, so a
        # quantized downlink is dequantized once (+ one memcpy), not once
        # for training and again for the delta base in _maybe_compress
        stash: Dict[str, Any] = {}

        def call(t: TaskIns) -> TaskRes:
            client = self._client(cid)
            try:
                if t.task_type == "fit":
                    ins = decode_fit_ins(t.payload)
                    codec = ins.config.get("codec")
                    lossy = codec in QUANT_CODECS or codec == "sparse"
                    if lossy and ins.flat is not None \
                            and len(t.payload) \
                            and t.payload[0] in (BF16_MAGIC, Q8_MAGIC):
                        # copy BEFORE fit() may mutate the views in place
                        stash["base"] = FlatParams(ins.flat.buf.copy(),
                                                   ins.flat.layout)
                        stash["base_payload"] = t.payload
                    if codec == "sparse":
                        # adapter/LoRA mask, read once per handle so the
                        # mod-chain re-encode sees the same subset
                        stash["ranges"] = client.np_client \
                            .trainable_ranges()
                        stash["frac"] = ins.config.get("sparse_frac",
                                                       0.01)
                    res = client.handle_fit(ins)
                    enc_codec = enc_base = None
                    if not self.mods and lossy:
                        # no mod chain to feed: skip the intermediate
                        # lossless frame and encode compressed directly
                        # (the encoder still falls back to 0xF1 when the
                        # result is not uniform fp32)
                        base = stash.get("base")
                        if base is None:            # raw 0xF1 downlink
                            base = peek_params(t.payload)
                            if isinstance(base, QuantParams):
                                base = base.to_flat()
                        if base is not None:        # delta-encodable only
                            enc_codec, enc_base = codec, base
                    return TaskRes("fit", t.round,
                                   encode_fit_res(
                                       res, codec=enc_codec, base=enc_base,
                                       sparse_frac=stash.get("frac", 0.01),
                                       sparse_ranges=_valid_ranges(
                                           stash.get("ranges"), enc_base)),
                                   task_id=t.task_id)
                if t.task_type == "evaluate":
                    res = client.handle_evaluate(decode_evaluate_ins(t.payload))
                    return TaskRes("evaluate", t.round,
                                   encode_evaluate_res(res), task_id=t.task_id)
                if t.task_type == "get_parameters":
                    arrays = client.np_client.get_parameters({})
                    return TaskRes("get_parameters", t.round,
                                   arrays_to_bytes(arrays), task_id=t.task_id)
                if t.task_type == "get_properties":
                    props = dict(client.np_client.get_properties({}) or {})
                    props.setdefault("codecs", list(WIRE_CODECS))
                    return TaskRes("get_properties", t.round,
                                   encode_properties_res(props),
                                   task_id=t.task_id)
                return TaskRes(t.task_type, t.round, b"",
                               task_id=t.task_id, error="unknown task type")
            except Exception as e:  # noqa: BLE001
                return TaskRes(t.task_type, t.round, b"", task_id=t.task_id,
                               error=repr(e))

        chain = call
        for mod in reversed(self.mods):
            chain = _bind_mod(mod, chain)
        return encode_task_res(self._maybe_compress(task, chain(task),
                                                    stash))

    def _maybe_compress(self, task: TaskIns, res: TaskRes,
                        stash: Optional[Dict[str, Any]] = None) -> TaskRes:
        """Re-encode the final (post-mod-chain) fit result with the
        negotiated lossy codec, as a **delta** against the round-start
        parameters peeked from the pristine task payload (immune to
        in-place mutation by ``fit``).

        Running OUTSIDE the mod chain means DP/TopK/SecAgg compose
        naturally: mods see exact fp32 buffers, and only the final wire
        hop is quantized.  Results a mod already re-encoded to something
        not uniform fp32 (e.g. SecAgg's uint64 masked shares, whose
        pairwise masks must keep cancelling exactly in the server's
        integer-domain sum) skip compression via the encoder's lossless
        0xF1 fallback — which the header pre-check below shortcuts."""
        codec = None
        cfg: Dict[str, Any] = {}
        if task.task_type == "fit" and not res.error and res.payload:
            cfg = peek_config(task.payload)
            codec = cfg.get("codec")
        if (codec not in QUANT_CODECS and codec != "sparse") \
                or res.payload[0] != FLAT_MAGIC:
            return res                  # nothing requested, or non-flat out
        fit = decode_fit_res(res.payload)          # zero-copy (0xF1)
        if not quantizable(fit.flat.layout):
            return res                  # lossy encode would fall back anyway
        if stash and stash.get("base_payload") is task.payload:
            base = stash["base"]        # pristine copy from the fit decode
        else:
            base = peek_params(task.payload)
            if isinstance(base, QuantParams):
                base = base.to_flat()   # what *we* trained from this round
        if base is not None and base.layout != fit.flat.layout:
            base = None                 # result re-shaped: no delta possible
        if base is None:
            return res                  # keep lossless rather than quantize
        payload = encode_fit_res(
            fit, codec=codec, base=base,
            sparse_frac=(stash or {}).get("frac",
                                          cfg.get("sparse_frac", 0.01)),
            sparse_ranges=_valid_ranges((stash or {}).get("ranges"), base))
        return TaskRes(res.task_type, res.round, payload,
                       task_id=res.task_id)


def _valid_ranges(ranges, base: Optional[FlatParams]):
    """Sanitize a client's adapter mask: sorted, non-overlapping
    ``[start, stop)`` int64 ranges inside the base layout, or ``None``
    (falls back to TopK mode) when the mask is absent or malformed —
    better a denser-than-asked update than a byzantine rejection."""
    if ranges is None or base is None:
        return None
    try:
        r = np.asarray(ranges, np.int64).reshape(-1, 2)
    except (TypeError, ValueError):
        return None
    if r.size == 0:
        return None
    if bool((r[:, 0] >= r[:, 1]).any()) or int(r[0, 0]) < 0 \
            or int(r[-1, 1]) > base.layout.total_size \
            or bool((r[1:, 0] < r[:-1, 1]).any()):
        return None
    return r


def _bind_mod(mod: ModFn, nxt: Callable[[TaskIns], TaskRes]):
    def bound(task: TaskIns) -> TaskRes:
        return mod(task, nxt)
    return bound

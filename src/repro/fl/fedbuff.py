"""FedBuff-style bounded-staleness buffered asynchronous aggregation.

Synchronous rounds pay the straggler tax: every version advance waits
for the slowest sampled node.  FedBuff (Nguyen et al., *Federated
Learning with Buffered Asynchronous Aggregation*, AISTATS 2022) instead
folds updates **as they arrive**: the server keeps one streaming
accumulator, folds each update with a staleness-discounted weight, and
advances the global version every ``buffer_k`` folds — continuously,
never in lockstep.

Semantics implemented here:

- **staleness** of an arriving update is ``server_version -
  trained_version`` (the version the client started from);
- updates staler than ``max_staleness`` are **dropped** (recorded, never
  folded) — the hard bound the property tests pin;
- folded updates are weighted ``num_examples * (1 + s) ** -exponent``
  (the polynomial discount from the paper, exponent 0.5 by default), so
  a stale update still contributes but cannot drag the average back;
- the fold itself reuses :class:`~repro.fl.agg_kernels
  .StreamingWeightedSum` — including fused quantized reads and
  edge-tier partial sums (discount applied as the partial's scale) —
  and each advance runs the strategy's ``_server_opt`` hook, so FedAvgM
  momentum / FedAdam moments work unchanged in async mode.

Async aggregation is lossy **by design**: the result depends on arrival
order, unlike the sync path's canonicalized fold.  What stays invariant
(and tested): the staleness bound, the discount arithmetic, and the
per-window weighted mean given a fixed arrival sequence.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.fl import agg_kernels as kernels
from repro.fl.messages import FitRes
from repro.fl.strategy import _check_shapes, _flat_of

NDArrays = List  # List[np.ndarray]


class FedBuffBuffer:
    """Bounded-staleness buffered fold; one instance per async run.

    ``offer`` returns ``"folded"`` or ``"stale"``; ``ready()`` says a
    window is full; ``advance(current)`` finalizes the window through
    the strategy's server optimizer and bumps :attr:`version`.
    """

    def __init__(self, strategy, *, buffer_k: int = 2,
                 max_staleness: int = 4,
                 staleness_exponent: float = 0.5):
        if not getattr(strategy, "supports_partial", lambda: False)():
            raise ValueError(
                "async FedBuff folding needs a weighted-sum strategy "
                "(FedAvg family); robust/SecAgg strategies require full "
                "per-client rounds")
        if buffer_k < 1:
            raise ValueError(f"buffer_k must be >= 1, got {buffer_k}")
        if max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {max_staleness}")
        self.strategy = strategy
        self.buffer_k = int(buffer_k)
        self.max_staleness = int(max_staleness)
        self.staleness_exponent = float(staleness_exponent)
        self.version = 0
        self.folded = 0             # lifetime folds
        self.dropped = 0            # lifetime stale drops
        self.folded_staleness: List[int] = []   # staleness of every fold
        self._acc: Optional[kernels.StreamingWeightedSum] = None
        self._window = 0            # folds in the current window

    # ------------------------------------------------------------- folding
    def discount(self, staleness: int) -> float:
        """Polynomial staleness discount ``(1 + s) ** -exponent``."""
        return float((1.0 + float(staleness)) ** -self.staleness_exponent)

    def offer(self, node: str, res: FitRes, trained_version: int,
              current: Optional[NDArrays] = None) -> str:
        """Fold one arriving update, or drop it as too stale.

        ``trained_version`` is the server version whose parameters the
        node trained from.  Returns ``"folded"`` or ``"stale"``."""
        s = self.version - int(trained_version)
        if s < 0:
            raise ValueError(
                f"node {node}: trained_version {trained_version} is ahead "
                f"of server version {self.version}")
        if s > self.max_staleness:
            self.dropped += 1
            return "stale"
        disc = self.discount(s)
        if res.partial is not None:
            ps = res.partial
            if current is not None:
                _check_shapes(ps, current, node)
            if self._acc is None:
                self._acc = self._make_acc(ps.layout)
            self._acc.add_partial(ps, scale=disc)
        else:
            fp = _flat_of(res)
            if current is not None:
                _check_shapes(fp, current, node)
            if self._acc is None:
                self._acc = self._make_acc(fp.layout)
            self._acc.add(fp, float(res.num_examples) * disc)
        self.folded += 1
        self._window += 1
        self.folded_staleness.append(s)
        return "folded"

    def _make_acc(self, layout) -> kernels.StreamingWeightedSum:
        st = self.strategy
        return kernels.StreamingWeightedSum(
            layout, backend=st.backend, shards=st.shards,
            mesh=st.shard_mesh, overlap=st.overlap_decode)

    def ready(self) -> bool:
        return self._window >= self.buffer_k

    # ------------------------------------------------------------- advance
    def advance(self, current: NDArrays
                ) -> Tuple[NDArrays, Dict[str, Any]]:
        """Finalize the buffered window into the next global model via
        the strategy's server optimizer; bumps :attr:`version` and opens
        a fresh window."""
        if self._window == 0 or self._acc is None:
            raise RuntimeError("advance() on an empty FedBuff window")
        target = self._acc.finalize()
        new = self.strategy._server_opt(self.version, target, current)
        self.version += 1
        window = self._window
        self._acc = None
        self._window = 0
        metrics = {
            "server_version": self.version,
            "window_folds": window,
            "async_folded": self.folded,
            "async_dropped_stale": self.dropped,
            "max_folded_staleness": max(self.folded_staleness, default=0),
        }
        return new, metrics

"""Wire format for the Flower-analogue app layer.

Everything that crosses a process/transport boundary is **bytes**.  Two
codecs coexist behind a leading version byte:

- **flat** (default, magic ``0xF1``): one msgpack header (layout
  signature + config/metrics) followed by a single 64-byte-aligned
  contiguous binary payload holding every leaf back to back.  Decoding is
  **zero-copy** — leaves are ``np.frombuffer`` views into the received
  bytes, and the whole-model :class:`~repro.fl.flat.FlatParams` rides on
  the decoded message (``.flat``) so the aggregation kernels never touch
  per-layer Python loops.
- **legacy** (any other first byte — legacy messages start with a msgpack
  fixmap/fixarray marker): per-array ``(dtype, shape, raw-buffer)``
  msgpack triples, exactly the seed format, kept for on-the-wire
  compatibility with older peers.

Both encodings carry raw little-endian buffers, so either way the
encoding is exact (bitwise) — a prerequisite for the paper's Fig. 5
reproducibility claim (native vs. in-FLARE must match exactly).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import msgpack
import numpy as np

import jax

from repro.fl.flat import FlatParams, Layout, layout_for, layout_of, np_dtype

NDArrays = List[np.ndarray]

FLAT_MAGIC = 0xF1
_HEADER_ALIGN = 64       # payload starts 64-byte aligned for fast views

_DEFAULT_CODEC = "flat"


def set_default_codec(name: str) -> str:
    """Switch the process-wide encode codec ("flat" | "legacy").

    Decoding always auto-detects, so mixed fleets interoperate; this only
    controls what *we* put on the wire. Returns the previous codec.
    """
    global _DEFAULT_CODEC
    if name not in ("flat", "legacy"):
        raise ValueError(f"unknown codec {name!r}")
    prev, _DEFAULT_CODEC = _DEFAULT_CODEC, name
    return prev


# ---------------------------------------------------------------------------
# legacy per-array codec
# ---------------------------------------------------------------------------
def _np_dtype(name: str) -> np.dtype:
    return np_dtype(name)


def _pack_array(a: np.ndarray) -> Dict[str, Any]:
    a = np.ascontiguousarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: Dict[str, Any]) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=_np_dtype(d["dtype"])) \
        .reshape(d["shape"]).copy()


# ---------------------------------------------------------------------------
# flat codec framing
# ---------------------------------------------------------------------------
def _flat_frame(head: Dict[str, Any], fp: FlatParams) -> bytes:
    """[0xF1][u32 header_len][msgpack header][pad to 64][payload]"""
    h = msgpack.packb(head, use_bin_type=True)
    data_off = _aligned(5 + len(h))
    prefix = bytes([FLAT_MAGIC]) + struct.pack("<I", len(h)) + h \
        + b"\x00" * (data_off - 5 - len(h))
    # single copy of the model payload into the message
    return b"".join((prefix, memoryview(fp.buf)))


def _aligned(n: int) -> int:
    return -(-n // _HEADER_ALIGN) * _HEADER_ALIGN


def _is_flat(b: bytes) -> bool:
    return len(b) >= 5 and b[0] == FLAT_MAGIC


def _flat_unframe(b: bytes, writable: bool = False
                  ) -> Tuple[Dict[str, Any], Optional[FlatParams]]:
    """``writable=False`` wraps the message bytes zero-copy (read-only
    views — the server aggregation hot path only reads).  ``writable=True``
    copies the payload once into a fresh buffer: client-facing decodes use
    it so ``fit(parameters, ...)`` may mutate in place, like the legacy
    per-array codec allowed."""
    (hlen,) = struct.unpack_from("<I", b, 1)
    head = msgpack.unpackb(memoryview(b)[5:5 + hlen], raw=False)
    fp = None
    if "l" in head:
        layout = layout_for([(d, tuple(s)) for d, s in head["l"]])
        fp = FlatParams.from_buffer(b, layout, offset=_aligned(5 + hlen))
        if writable:
            fp = FlatParams(fp.buf.copy(), layout)
    return head, fp


def _leaf_sig(fp: FlatParams) -> List[List[Any]]:
    return [[l.dtype, list(l.shape)] for l in fp.layout.leaves]


def _as_flat(parameters: NDArrays, flat: Optional[FlatParams]) -> FlatParams:
    return flat if flat is not None else FlatParams.from_arrays(parameters)


# ---------------------------------------------------------------------------
# NDArrays <-> bytes (get_parameters / initial parameters path)
# ---------------------------------------------------------------------------
def arrays_to_bytes(arrays: NDArrays, codec: Optional[str] = None) -> bytes:
    if (codec or _DEFAULT_CODEC) == "flat":
        fp = FlatParams.from_arrays(arrays)
        return _flat_frame({"l": _leaf_sig(fp)}, fp)
    return msgpack.packb([_pack_array(a) for a in arrays], use_bin_type=True)


def bytes_to_arrays(b: bytes) -> NDArrays:
    if _is_flat(b):
        _, fp = _flat_unframe(b, writable=True)   # one-shot path, not hot
        return fp.to_arrays()
    return [_unpack_array(d) for d in msgpack.unpackb(b, raw=False)]


# pytree <-> flat NDArrays (clients keep the treedef; the wire sees arrays)
def params_to_arrays(params) -> NDArrays:
    return [np.asarray(x) for x in jax.tree.leaves(params)]


def arrays_to_params(arrays: NDArrays, like):
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(arrays), (len(leaves), len(arrays))
    import jax.numpy as jnp

    return jax.tree.unflatten(
        treedef, [jnp.asarray(a, dtype=l.dtype) for a, l in zip(arrays, leaves)])


# ---------------------------------------------------------------------------
# task messages
# ---------------------------------------------------------------------------
@dataclass
class FitIns:
    parameters: NDArrays
    config: Dict[str, Any] = field(default_factory=dict)
    flat: Optional[FlatParams] = field(default=None, repr=False, compare=False)


@dataclass
class FitRes:
    parameters: NDArrays
    num_examples: int
    metrics: Dict[str, Any] = field(default_factory=dict)
    flat: Optional[FlatParams] = field(default=None, repr=False, compare=False)

    def set_parameters(self, arrays: NDArrays,
                       flat: Optional[FlatParams] = None) -> None:
        """Replace parameters, keeping the cached flat view coherent."""
        self.parameters = arrays
        self.flat = flat


@dataclass
class EvaluateIns:
    parameters: NDArrays
    config: Dict[str, Any] = field(default_factory=dict)
    flat: Optional[FlatParams] = field(default=None, repr=False, compare=False)


@dataclass
class EvaluateRes:
    loss: float
    num_examples: int
    metrics: Dict[str, Any] = field(default_factory=dict)


@dataclass
class TaskIns:
    task_type: str              # "fit" | "evaluate" | "get_parameters"
    round: int
    payload: bytes              # encoded FitIns / EvaluateIns
    task_id: str = ""
    group_id: str = ""


@dataclass
class TaskRes:
    task_type: str
    round: int
    payload: bytes              # encoded FitRes / EvaluateRes
    task_id: str = ""
    error: str = ""


def _enc_config(cfg: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in cfg.items():
        if isinstance(v, (int, float, str, bool, bytes)):
            out[k] = v
        elif isinstance(v, (np.floating, np.integer)):
            out[k] = v.item()
        else:
            raise TypeError(f"config value {k}={type(v)} not wire-safe")
    return out


def encode_fit_ins(x: FitIns, codec: Optional[str] = None) -> bytes:
    if (codec or _DEFAULT_CODEC) == "flat":
        fp = _as_flat(x.parameters, x.flat)
        return _flat_frame({"l": _leaf_sig(fp), "c": _enc_config(x.config)}, fp)
    return msgpack.packb({"p": [_pack_array(a) for a in x.parameters],
                          "c": _enc_config(x.config)}, use_bin_type=True)


def decode_fit_ins(b: bytes) -> FitIns:
    if _is_flat(b):
        head, fp = _flat_unframe(b, writable=True)
        return FitIns(fp.to_arrays(), head.get("c", {}), flat=fp)
    d = msgpack.unpackb(b, raw=False)
    return FitIns([_unpack_array(a) for a in d["p"]], d["c"])


def encode_fit_res(x: FitRes, codec: Optional[str] = None) -> bytes:
    if (codec or _DEFAULT_CODEC) == "flat":
        fp = _as_flat(x.parameters, x.flat)
        return _flat_frame({"l": _leaf_sig(fp), "n": x.num_examples,
                            "m": _enc_config(x.metrics)}, fp)
    return msgpack.packb({"p": [_pack_array(a) for a in x.parameters],
                          "n": x.num_examples, "m": _enc_config(x.metrics)},
                         use_bin_type=True)


def decode_fit_res(b: bytes) -> FitRes:
    if _is_flat(b):
        head, fp = _flat_unframe(b)
        return FitRes(fp.to_arrays(), head["n"], head.get("m", {}), flat=fp)
    d = msgpack.unpackb(b, raw=False)
    return FitRes([_unpack_array(a) for a in d["p"]], d["n"], d["m"])


def encode_evaluate_ins(x: EvaluateIns, codec: Optional[str] = None) -> bytes:
    if (codec or _DEFAULT_CODEC) == "flat":
        fp = _as_flat(x.parameters, x.flat)
        return _flat_frame({"l": _leaf_sig(fp), "c": _enc_config(x.config)}, fp)
    return msgpack.packb({"p": [_pack_array(a) for a in x.parameters],
                          "c": _enc_config(x.config)}, use_bin_type=True)


def decode_evaluate_ins(b: bytes) -> EvaluateIns:
    if _is_flat(b):
        head, fp = _flat_unframe(b, writable=True)
        return EvaluateIns(fp.to_arrays(), head.get("c", {}), flat=fp)
    d = msgpack.unpackb(b, raw=False)
    return EvaluateIns([_unpack_array(a) for a in d["p"]], d["c"])


def encode_evaluate_res(x: EvaluateRes) -> bytes:
    return msgpack.packb({"l": float(x.loss), "n": x.num_examples,
                          "m": _enc_config(x.metrics)}, use_bin_type=True)


def decode_evaluate_res(b: bytes) -> EvaluateRes:
    d = msgpack.unpackb(b, raw=False)
    return EvaluateRes(d["l"], d["n"], d["m"])


def encode_task_ins(t: TaskIns) -> bytes:
    return msgpack.packb({"t": t.task_type, "r": t.round, "p": t.payload,
                          "id": t.task_id, "g": t.group_id}, use_bin_type=True)


def decode_task_ins(b: bytes) -> TaskIns:
    d = msgpack.unpackb(b, raw=False)
    return TaskIns(d["t"], d["r"], d["p"], d["id"], d["g"])


def encode_task_res(t: TaskRes) -> bytes:
    return msgpack.packb({"t": t.task_type, "r": t.round, "p": t.payload,
                          "id": t.task_id, "e": t.error}, use_bin_type=True)


def decode_task_res(b: bytes) -> TaskRes:
    d = msgpack.unpackb(b, raw=False)
    return TaskRes(d["t"], d["r"], d["p"], d["id"], d["e"])

"""Wire format for the Flower-analogue app layer.

Everything that crosses a process/transport boundary is **bytes**.  Four
codecs coexist behind a leading version byte:

- **flat** (default, magic ``0xF1``): one msgpack header (layout
  signature + config/metrics) followed by a single 64-byte-aligned
  contiguous binary payload holding every leaf back to back.  Decoding is
  **zero-copy** — leaves are ``np.frombuffer`` views into the received
  bytes, and the whole-model :class:`~repro.fl.flat.FlatParams` rides on
  the decoded message (``.flat``) so the aggregation kernels never touch
  per-layer Python loops.
- **bf16** (magic ``0xF2``): the same frame with the fp32 payload stored
  as bfloat16 — 2 bytes/param, exact exponent range, ~3 decimal digits.
- **q8** (magic ``0xF3``): symmetric int8 quantization with one fp32
  scale per :data:`~repro.fl.flat.QCHUNK`-element window — ~1 byte/param
  (4x vs fp32) with per-coordinate error bounded by ``scale/2``.  Fit
  results are encoded as **deltas** against the round-start parameters
  (header flag ``d``), which keeps the quantization bound proportional to
  the *update* magnitude, not the weights.  Both lossy frames decode
  zero-copy into :class:`~repro.fl.flat.QuantParams`, which the
  aggregation kernels stream through fused dequantize+accumulate reads.
- **partial** (magic ``0xF4``): an edge aggregator's pre-reduced subtree
  sum — one raw fp64 ``Σw·x`` vector plus total weight / contributing
  node ids in the header (:class:`~repro.fl.flat.PartialSum`).  Lossless
  by construction; only the root server's fit accumulator consumes it —
  parameter-decoding paths raise :class:`UnsupportedCodec` instead of
  misreading a sum as a model (the downgrade path for peers that don't
  speak the edge tier).
- **sparse** (magic ``0xF5``): a structured-sparse **delta** vs the
  round-start parameters — separate index and value streams
  (:class:`~repro.fl.flat.SparseDelta`).  Index modes: sorted-unique COO
  coordinates (TopK of the update magnitude) or sorted ``[start, stop)``
  ranges (the adapter/LoRA-mask mode where only the trainable subset
  travels).  Value modes: int8 + one fp32 scale per
  :data:`~repro.fl.flat.QCHUNK` window of the *packed* stream (composes
  with the q8 delta machinery) or raw fp32.  Untraveled coordinates mean
  "delta == 0", so a 32B-param model federates at <<1% of the full-weight
  ``0xF1`` bytes; the fold consumes it via fused
  scatter-dequantize-accumulate with no model-size densify.  Like
  ``0xF4``, parameter-decoding paths raise :class:`UnsupportedCodec` —
  only the server-side fit fold (with the round base re-attached) can
  reconstruct.
- **legacy** (any other first byte — legacy messages start with a msgpack
  fixmap/fixarray marker): per-array ``(dtype, shape, raw-buffer)``
  msgpack triples, exactly the seed format, kept for on-the-wire
  compatibility with older peers.

``0xF1`` and legacy carry raw little-endian buffers, so both are exact
(bitwise) — the prerequisite for the paper's Fig. 5 reproducibility claim
(native vs. in-FLARE must match exactly).  A reserved-range version byte
(``0xF0``–``0xFF``) this build does not know raises
:class:`UnsupportedCodec` instead of being misparsed as msgpack.

Codec negotiation
-----------------
Lossy codecs are **opt-in and negotiated**, never assumed:

1. Clients advertise the codecs they speak in their ``get_properties``
   response (``{"codecs": [...]}`` — :class:`~repro.fl.client.ClientApp`
   fills this in automatically; see :data:`WIRE_CODECS`).
2. The ServerApp (``ServerConfig.codec="q8" | "bf16"``) intersects the
   fleet's advertisements and picks a codec per round; any node that
   fails to respond (e.g. an older peer that errors on the unknown task
   type) demotes the round to the lossless ``flat`` codec.
3. The negotiated codec rides in the fit config (``config["codec"]``);
   the client's ClientApp re-encodes the final (post-mod-chain) FitRes
   with it, as a delta against the round-start parameters it received.
4. Decoding always auto-detects from the version byte, so a client that
   ignores the request (or a mod whose output is not uniform fp32 — e.g.
   SecAgg's uint64 masked shares) simply falls back to ``0xF1`` and
   interoperates losslessly: negotiation is advisory, the frame is
   authoritative.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

# Decoders accept any byte-addressable buffer, not just ``bytes``: the
# socket transport (repro.core.transport) hands frames over as read-only
# memoryviews into its receive buffer, and every zero-copy path below
# (msgpack.unpackb, np.frombuffer, FlatParams.from_buffer) consumes them
# directly without an intermediate copy.
Buffer = Union[bytes, bytearray, memoryview]

import msgpack
import numpy as np

import jax

from repro.fl.flat import (FlatParams, Layout, PartialSum, QCHUNK,
                           QuantParams, SparseDelta, WIRE_MAGIC_LO,
                           WIRE_MAGICS, layout_for, np_dtype, quantizable,
                           quantize_int8, topk_indices)

NDArrays = List[np.ndarray]

# wire version bytes: fl/flat.py's WIRE_MAGICS is the single registry
FLAT_MAGIC = WIRE_MAGICS["flat"]
BF16_MAGIC = WIRE_MAGICS["bf16"]
Q8_MAGIC = WIRE_MAGICS["q8"]
PARTIAL_MAGIC = WIRE_MAGICS["partial"]
SPARSE_MAGIC = WIRE_MAGICS["sparse"]
_HEADER_ALIGN = 64       # payload starts 64-byte aligned for fast views

#: every codec this build can encode AND decode (advertised by clients in
#: their get_properties response and intersected by the ServerApp)
WIRE_CODECS = ("flat", "bf16", "q8", "sparse", "legacy")
#: the lossy subset, only used after successful negotiation
QUANT_CODECS = ("bf16", "q8")

_MAGIC_BY_CODEC = {"flat": FLAT_MAGIC, "bf16": BF16_MAGIC, "q8": Q8_MAGIC}
_QUANT_MODE_BY_MAGIC = {BF16_MAGIC: "bf16", Q8_MAGIC: "q8"}

_DEFAULT_CODEC = "flat"


class UnsupportedCodec(ValueError):
    """The frame's version byte is in the flat-family reserved range
    (0xF0-0xFF) but this build has no decoder for it — e.g. a newer peer
    skipped negotiation, or the snapshot is from a future version."""


def set_default_codec(name: str) -> str:
    """Switch the process-wide encode codec ("flat" | "legacy").

    The lossy codecs ("bf16" / "q8") are deliberately NOT accepted here:
    they are negotiated per round (see module docstring), never a silent
    process-wide default.  Decoding always auto-detects, so mixed fleets
    interoperate; this only controls what *we* put on the wire.  Returns
    the previous codec.
    """
    global _DEFAULT_CODEC
    if name not in ("flat", "legacy"):
        raise ValueError(f"unknown codec {name!r}")
    prev, _DEFAULT_CODEC = _DEFAULT_CODEC, name
    return prev


# ---------------------------------------------------------------------------
# legacy per-array codec
# ---------------------------------------------------------------------------
def _np_dtype(name: str) -> np.dtype:
    return np_dtype(name)


def _pack_array(a: np.ndarray) -> Dict[str, Any]:
    a = np.ascontiguousarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: Dict[str, Any]) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=_np_dtype(d["dtype"])) \
        .reshape(d["shape"]).copy()


# ---------------------------------------------------------------------------
# flat-family codec framing (0xF1 raw fp / 0xF2 bf16 / 0xF3 int8+scales)
# ---------------------------------------------------------------------------
def _frame(magic: int, head: Dict[str, Any], *payload) -> bytes:
    """[magic][u32 header_len][msgpack header][pad to 64][payload...]"""
    h = msgpack.packb(head, use_bin_type=True)
    data_off = _aligned(5 + len(h))
    prefix = bytes([magic]) + struct.pack("<I", len(h)) + h \
        + b"\x00" * (data_off - 5 - len(h))
    # single copy of the model payload into the message
    return b"".join((prefix, *map(memoryview, payload)))


def _flat_frame(head: Dict[str, Any], fp: FlatParams) -> bytes:
    return _frame(FLAT_MAGIC, head, fp.buf)


def _aligned(n: int) -> int:
    return -(-n // _HEADER_ALIGN) * _HEADER_ALIGN


def _is_framed(b: Buffer) -> bool:
    """Flat-family frame?  Legacy msgpack messages always start with a
    container marker (fixmap/fixarray/map16/array16...), never 0xF0-0xFF,
    so the reserved range is unambiguous."""
    return len(b) >= 5 and b[0] >= WIRE_MAGIC_LO


def _head_of(b: Buffer) -> Tuple[Dict[str, Any], int]:
    if b[0] not in (FLAT_MAGIC, BF16_MAGIC, Q8_MAGIC, PARTIAL_MAGIC,
                    SPARSE_MAGIC):
        raise UnsupportedCodec(
            f"unknown wire codec version byte 0x{b[0]:02X}; this build "
            f"decodes 0xF1 (flat) / 0xF2 (bf16) / 0xF3 (q8) / 0xF4 "
            f"(partial) / 0xF5 (sparse) and legacy msgpack frames")
    (hlen,) = struct.unpack_from("<I", b, 1)
    return msgpack.unpackb(memoryview(b)[5:5 + hlen], raw=False), hlen


def _unframe(b: Buffer, writable: bool = False
             ) -> Tuple[Dict[str, Any], Optional[object]]:
    """Decode any flat-family frame -> (header, FlatParams | QuantParams).

    ``writable=False`` wraps the message bytes zero-copy (read-only
    views — the server aggregation hot path only reads).  ``writable=True``
    copies a 0xF1 payload once into a fresh buffer: client-facing decodes
    use it so ``fit(parameters, ...)`` may mutate in place, like the legacy
    per-array codec allowed.  (Quantized frames ignore it — materializing
    them allocates fresh writable arrays anyway.)
    """
    head, hlen = _head_of(b)
    if "l" not in head:
        return head, None
    layout = layout_for([(d, tuple(s)) for d, s in head["l"]])
    off = _aligned(5 + hlen)
    if b[0] == FLAT_MAGIC:
        fp = FlatParams.from_buffer(b, layout, offset=off)
        if writable:
            fp = FlatParams(fp.buf.copy(), layout)
        return head, fp
    n = layout.total_size
    is_delta = bool(head.get("d", 0))
    if b[0] == BF16_MAGIC:
        data = np.frombuffer(b, np_dtype("bfloat16"), count=n, offset=off)
        data.flags.writeable = False     # borrows the transport buffer
        return head, QuantParams(layout, "bf16", data, is_delta=is_delta)
    if b[0] == Q8_MAGIC:
        qchunk = int(head.get("qc", QCHUNK))
        nchunks = -(-n // qchunk)
        scales = np.frombuffer(b, np.float32, count=nchunks, offset=off)
        data = np.frombuffer(b, np.int8, count=n,
                             offset=off + 4 * nchunks)
        scales.flags.writeable = False   # borrows the transport buffer
        data.flags.writeable = False
        return head, QuantParams(layout, "q8", data, scales, qchunk,
                                 is_delta=is_delta)
    if b[0] == PARTIAL_MAGIC:
        # edge-tier partial aggregate: one fp64 Σw·x vector, zero-copy
        return head, PartialSum.from_buffer(
            b, layout, head.get("w", 0.0), head.get("n", 0),
            tuple(head.get("ids", [])),
            tuple((n, r) for n, r in head.get("f", [])), offset=off)
    if b[0] == SPARSE_MAGIC:
        # structured-sparse delta: [indices int64][scales fp32?][values],
        # every stream a frozen zero-copy view into the transport buffer
        imode = "coo" if head.get("im", "c") == "c" else "ranges"
        vmode = head.get("vm", "q8")
        nz = int(head["nz"])
        nidx = 2 * int(head.get("nr", 0)) if imode == "ranges" else nz
        idx = np.frombuffer(b, np.int64, count=nidx, offset=off)
        idx.flags.writeable = False      # borrows the transport buffer
        if imode == "ranges":
            idx = idx.reshape(-1, 2)     # reshaped view stays read-only
        voff = off + 8 * nidx
        qchunk = int(head.get("qc", QCHUNK))
        scales = None
        if vmode == "q8":
            nchunks = -(-nz // qchunk)
            scales = np.frombuffer(b, np.float32, count=nchunks,
                                   offset=voff)
            scales.flags.writeable = False
            values = np.frombuffer(b, np.int8, count=nz,
                                   offset=voff + 4 * nchunks)
        else:
            values = np.frombuffer(b, np.float32, count=nz, offset=voff)
        values.flags.writeable = False
        return head, SparseDelta(layout, imode, idx, values, scales, qchunk)
    # _head_of above already rejects unknown bytes; keep the dispatch
    # locally exhaustive so a new registry entry cannot fall through to
    # a wrong decoder (codec-dispatch invariant, docs/INVARIANTS.md)
    raise UnsupportedCodec(
        f"no decoder branch for version byte 0x{b[0]:02X}")


def _quant_frame(head: Dict[str, Any], fp: FlatParams, codec: str,
                 base: Optional[FlatParams]) -> bytes:
    """Encode ``fp`` (uniform fp32) as a bf16/q8 frame, as a delta against
    ``base`` (the round-start parameters) when one is supplied."""
    x = fp.math_view()
    if base is not None:
        x = x - base.math_view()             # fp32 delta, bounds the error
        head["d"] = 1
    if codec == "bf16":
        return _frame(BF16_MAGIC, head,
                      x.astype(np_dtype("bfloat16")).view(np.uint8))
    q, scales = quantize_int8(x)
    head["qc"] = QCHUNK
    return _frame(Q8_MAGIC, head, scales.view(np.uint8), q.view(np.uint8))


def _pick_wire(codec: Optional[str], fp_layout: Layout,
               base: Optional[FlatParams]) -> str:
    """Resolve the effective codec: a lossy request silently demotes to
    the lossless flat frame when the payload is not uniform fp32, or when
    the delta base does not match the result layout."""
    codec = codec or _DEFAULT_CODEC
    if codec in QUANT_CODECS:
        if not quantizable(fp_layout):
            return "flat"
        if base is not None and base.layout is not fp_layout \
                and base.layout != fp_layout:
            return "flat"
    if codec == "sparse":
        # sparse frames are deltas by construction: no round base (e.g. a
        # FitIns/get_parameters downlink) or a non-fp32 / layout-mismatched
        # payload falls back to the lossless flat frame
        if base is None or not quantizable(fp_layout):
            return "flat"
        if base.layout is not fp_layout and base.layout != fp_layout:
            return "flat"
    return codec


def _sparse_frame(head: Dict[str, Any], fp: FlatParams, base: FlatParams,
                  frac: float, ranges, vmode: str = "q8") -> bytes:
    """Encode ``fp`` as a structured-sparse 0xF5 delta vs ``base``.

    ``ranges`` (adapter/LoRA mode) is an ``(R, 2)`` array of sorted
    non-overlapping ``[start, stop)`` element ranges into the flat math
    vector — only those coordinates travel.  Without ranges, the TopK
    mode keeps ``max(1, ceil(frac * size))`` coordinates of largest
    |delta| with deterministic tie-breaking (:func:`~repro.fl.flat
    .topk_indices`).  Values pack int8 + per-qchunk fp32 scales of the
    *packed* stream (``vmode="q8"``) or raw fp32 (``"f32"``).
    """
    x = fp.math_view() - base.math_view()     # fp32 delta
    head["d"] = 1
    if ranges is not None:
        r = np.ascontiguousarray(np.asarray(ranges, np.int64).reshape(-1, 2))
        packed = np.concatenate(
            [x[int(a):int(b)] for a, b in r]) if len(r) \
            else np.empty(0, np.float32)
        head["im"], head["nr"] = "r", int(len(r))
        idx = r
    else:
        k = max(1, int(np.ceil(float(frac) * x.size)))
        idx = topk_indices(np.abs(x), k)
        packed = x[idx]
        head["im"] = "c"
    packed = np.ascontiguousarray(packed, np.float32)
    head["nz"] = int(packed.size)
    if vmode == "q8":
        q, scales = quantize_int8(packed)
        head["vm"], head["qc"] = "q8", QCHUNK
        return _frame(SPARSE_MAGIC, head,
                      np.ascontiguousarray(idx).view(np.uint8),
                      scales.view(np.uint8), q.view(np.uint8))
    head["vm"] = "f32"
    return _frame(SPARSE_MAGIC, head,
                  np.ascontiguousarray(idx).view(np.uint8),
                  packed.view(np.uint8))


def _leaf_sig(fp: FlatParams) -> List[List[Any]]:
    return [[l.dtype, list(l.shape)] for l in fp.layout.leaves]


def _as_flat(parameters: NDArrays, flat: Optional[FlatParams]) -> FlatParams:
    return flat if flat is not None else FlatParams.from_arrays(parameters)


def _framed_encode(parameters: NDArrays, flat: Optional[FlatParams],
                   head_extra: Dict[str, Any], codec: Optional[str],
                   base: Optional[FlatParams] = None,
                   sparse_frac: float = 0.01,
                   sparse_ranges=None) -> bytes:
    """Shared flat-family encode dispatch: flatten, resolve the effective
    codec (lossy requests demote per :func:`_pick_wire`), frame.  Callers
    handle the "legacy" codec themselves — it has no flat layout and each
    message shapes its msgpack map differently."""
    fp = _as_flat(parameters, flat)
    codec = _pick_wire(codec, fp.layout, base)
    head = {"l": _leaf_sig(fp), **head_extra}
    if codec in QUANT_CODECS:
        return _quant_frame(head, fp, codec, base)
    if codec == "sparse":
        return _sparse_frame(head, fp, base, sparse_frac, sparse_ranges)
    return _flat_frame(head, fp)


# ---------------------------------------------------------------------------
# header-only peeks (cheap reads the negotiation/delta paths rely on)
# ---------------------------------------------------------------------------
def peek_config(b: bytes) -> Dict[str, Any]:
    """The config dict of a framed FitIns/EvaluateIns, header-only (the
    payload is not touched).  Legacy frames return {} — negotiated codecs
    never ride legacy messages."""
    if not _is_framed(b):
        return {}
    return _head_of(b)[0].get("c", {})


def peek_params(b: bytes):
    """Zero-copy read-only view of a framed message's parameters
    (FlatParams or QuantParams), or None for legacy/param-less frames.

    This is how both ends recover the *round-start* parameters bitwise:
    the client peeks the pristine task payload (immune to in-place
    mutation by ``fit``), the server peeks its own downlink bytes — so
    delta encode and delta reconstruction agree exactly."""
    if not _is_framed(b):
        return None
    return _unframe(b, writable=False)[1]


# ---------------------------------------------------------------------------
# NDArrays <-> bytes (get_parameters / initial parameters path)
# ---------------------------------------------------------------------------
def arrays_to_bytes(arrays: NDArrays, codec: Optional[str] = None) -> bytes:
    if (codec or _DEFAULT_CODEC) == "legacy":     # skip the flatten copy
        return msgpack.packb([_pack_array(a) for a in arrays],
                             use_bin_type=True)
    return _framed_encode(arrays, None, {}, codec)


def bytes_to_arrays(b: bytes) -> NDArrays:
    if _is_framed(b):
        _, p = _unframe(b, writable=True)         # one-shot path, not hot
        return _materialized(p).to_arrays()
    return [_unpack_array(d) for d in msgpack.unpackb(b, raw=False)]


# pytree <-> flat NDArrays (clients keep the treedef; the wire sees arrays)
def params_to_arrays(params) -> NDArrays:
    return [np.asarray(x) for x in jax.tree.leaves(params)]


def arrays_to_params(arrays: NDArrays, like):
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(arrays), (len(leaves), len(arrays))
    import jax.numpy as jnp

    return jax.tree.unflatten(
        treedef, [jnp.asarray(a, dtype=l.dtype) for a, l in zip(arrays, leaves)])


# ---------------------------------------------------------------------------
# task messages
# ---------------------------------------------------------------------------
@dataclass
class FitIns:
    parameters: NDArrays
    config: Dict[str, Any] = field(default_factory=dict)
    flat: Optional[FlatParams] = field(default=None, repr=False, compare=False)


@dataclass
class FitRes:
    # None when the result arrived quantized (``quant`` set) — the server
    # hot path streams the compressed buffer through the kernels instead
    # of materializing per-leaf arrays; call materialize() if needed.
    parameters: Optional[NDArrays]
    num_examples: int
    metrics: Dict[str, Any] = field(default_factory=dict)
    flat: Optional[FlatParams] = field(default=None, repr=False, compare=False)
    quant: Optional[QuantParams] = field(default=None, repr=False,
                                         compare=False)
    # set when the result is an edge-aggregator partial sum (0xF4): a
    # pre-reduced Σw·x over the sender's subtree, consumed only by
    # weighted-sum fit accumulators (strategy.supports_partial())
    partial: Optional[PartialSum] = field(default=None, repr=False,
                                          compare=False)
    # set when the result is a structured-sparse delta (0xF5): only the
    # traveled coordinates changed; the server attaches the round base
    # and the fit fold scatters it without a model-size densify
    sparse: Optional[SparseDelta] = field(default=None, repr=False,
                                          compare=False)

    def set_parameters(self, arrays: NDArrays,
                       flat: Optional[FlatParams] = None) -> None:
        """Replace parameters, keeping the cached views coherent."""
        self.parameters = arrays
        self.flat = flat
        self.quant = None
        self.partial = None
        self.sparse = None

    def materialize(self) -> NDArrays:
        """Per-leaf fp32 arrays, dequantizing if the result is compressed
        (a delta-encoded result needs its ``quant.base`` attached)."""
        if self.parameters is None:
            if self.partial is not None:
                raise UnsupportedCodec(
                    "partial-aggregate results are pre-reduced sums, not "
                    "parameters; only weighted-sum fit accumulators "
                    "(FedAvg family) can fold them")
            if self.sparse is not None:
                raise UnsupportedCodec(
                    "sparse-delta results (0xF5) carry a TopK/adapter "
                    "delta vs a round base held by the server; only "
                    "weighted-sum fit accumulators can fold them")
            self.parameters = self.quant.to_arrays()
        return self.parameters


@dataclass
class EvaluateIns:
    parameters: NDArrays
    config: Dict[str, Any] = field(default_factory=dict)
    flat: Optional[FlatParams] = field(default=None, repr=False, compare=False)


@dataclass
class EvaluateRes:
    loss: float
    num_examples: int
    metrics: Dict[str, Any] = field(default_factory=dict)


@dataclass
class TaskIns:
    task_type: str              # "fit" | "evaluate" | "get_parameters"
    round: int
    payload: bytes              # encoded FitIns / EvaluateIns
    task_id: str = ""
    group_id: str = ""


@dataclass
class TaskRes:
    task_type: str
    round: int
    payload: bytes              # encoded FitRes / EvaluateRes
    task_id: str = ""
    error: str = ""


def _enc_config(cfg: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in cfg.items():
        if isinstance(v, (int, float, str, bool, bytes)):
            out[k] = v
        elif isinstance(v, (np.floating, np.integer)):
            out[k] = v.item()
        else:
            raise TypeError(f"config value {k}={type(v)} not wire-safe")
    return out


def _materialized(p) -> FlatParams:
    """FlatParams for a client-facing decode: 0xF1 payloads arrive here
    already copied into a writable buffer (``_unframe(writable=True)``);
    quantized payloads materialize fresh (writable) fp32 arrays."""
    if isinstance(p, PartialSum):
        # the downgrade path for peers that don't speak the edge tier: a
        # partial-aggregate frame is a pre-reduced SUM, not parameters —
        # only the root's fit accumulator may consume it
        raise UnsupportedCodec(
            "partial-aggregate frame (0xF4) carries a pre-reduced subtree "
            "sum, not model parameters; it cannot be materialized — only "
            "the root server's fit accumulator consumes it")
    if isinstance(p, SparseDelta):
        raise UnsupportedCodec(
            "sparse-delta frame (0xF5) carries a TopK/adapter delta vs a "
            "round base held by the server; it cannot be decoded as "
            "standalone parameters — only the server's fit fold (base "
            "re-attached) can reconstruct")
    if isinstance(p, QuantParams):
        if p.is_delta:
            raise ValueError(
                "delta-encoded parameters cannot be decoded client-side "
                "(no round base); only fit results travel as deltas")
        return p.to_flat()
    return p


def encode_fit_ins(x: FitIns, codec: Optional[str] = None) -> bytes:
    if (codec or _DEFAULT_CODEC) == "legacy":     # skip the flatten copy
        return msgpack.packb({"p": [_pack_array(a) for a in x.parameters],
                              "c": _enc_config(x.config)}, use_bin_type=True)
    return _framed_encode(x.parameters, x.flat,
                          {"c": _enc_config(x.config)}, codec)


def decode_fit_ins(b: bytes) -> FitIns:
    if _is_framed(b):
        head, p = _unframe(b, writable=True)
        fp = _materialized(p)
        return FitIns(fp.to_arrays(), head.get("c", {}), flat=fp)
    d = msgpack.unpackb(b, raw=False)
    return FitIns([_unpack_array(a) for a in d["p"]], d["c"])


def encode_fit_res(x: FitRes, codec: Optional[str] = None,
                   base: Optional[FlatParams] = None,
                   sparse_frac: float = 0.01,
                   sparse_ranges=None) -> bytes:
    """``base`` (the round-start parameters) turns a lossy encode into a
    delta encode: the int8/bf16 payload is (result - base), whose smaller
    dynamic range keeps the quantization error bounded by the update
    magnitude.  The decoder reconstructs after the server re-attaches the
    base (see :func:`peek_params`).  ``codec="sparse"`` additionally
    drops coordinates: ``sparse_ranges`` keeps only those ``[start,
    stop)`` element ranges (adapter/LoRA mode), otherwise the top
    ``sparse_frac`` of |delta| coordinates travel (0xF5)."""
    if (codec or _DEFAULT_CODEC) == "legacy":     # skip the flatten copy
        return msgpack.packb({"p": [_pack_array(a) for a in x.parameters],
                              "n": x.num_examples,
                              "m": _enc_config(x.metrics)},
                             use_bin_type=True)
    return _framed_encode(x.parameters, x.flat,
                          {"n": x.num_examples, "m": _enc_config(x.metrics)},
                          codec, base, sparse_frac, sparse_ranges)


def decode_fit_res(b: bytes) -> FitRes:
    if _is_framed(b):
        head, p = _unframe(b)
        if isinstance(p, PartialSum):
            # edge tier: num_examples reports the contributing-client
            # count; the fold weight is p.total_w, read by the accumulator
            return FitRes(None, p.count, head.get("m", {}), partial=p)
        if isinstance(p, SparseDelta):
            # stays sparse: the fold scatters the traveled coordinates
            # once the server re-attaches the round base
            return FitRes(None, head["n"], head.get("m", {}), sparse=p)
        if isinstance(p, QuantParams):
            # hot path stays compressed: kernels stream it via f64_chunk
            return FitRes(None, head["n"], head.get("m", {}), quant=p)
        return FitRes(p.to_arrays(), head["n"], head.get("m", {}), flat=p)
    d = msgpack.unpackb(b, raw=False)
    return FitRes([_unpack_array(a) for a in d["p"]], d["n"], d["m"])


def encode_partial_fit_res(ps: PartialSum,
                           metrics: Optional[Dict[str, Any]] = None
                           ) -> bytes:
    """Frame an edge aggregator's pre-reduced subtree sum (codec 0xF4).

    The payload is the raw little-endian fp64 ``Σw·x`` vector — lossless,
    so the root's fold continues the edge's accumulation bitwise.  The
    header carries the subtree total weight (``w``), contributing client
    count (``n``), sorted contributing node ids (``ids``) and absorbed
    per-node failures (``f``)."""
    head = {"l": [[l.dtype, list(l.shape)] for l in ps.layout.leaves],
            "w": float(ps.total_w), "n": int(ps.count),
            "ids": list(ps.node_ids),
            "f": [[n, r] for n, r in ps.failures],
            "m": _enc_config(metrics or {})}
    return _frame(PARTIAL_MAGIC, head,
                  np.ascontiguousarray(ps.data).view(np.uint8))


def encode_evaluate_ins(x: EvaluateIns, codec: Optional[str] = None) -> bytes:
    if (codec or _DEFAULT_CODEC) == "legacy":     # skip the flatten copy
        return msgpack.packb({"p": [_pack_array(a) for a in x.parameters],
                              "c": _enc_config(x.config)}, use_bin_type=True)
    return _framed_encode(x.parameters, x.flat,
                          {"c": _enc_config(x.config)}, codec)


def decode_evaluate_ins(b: bytes) -> EvaluateIns:
    if _is_framed(b):
        head, p = _unframe(b, writable=True)
        fp = _materialized(p)
        return EvaluateIns(fp.to_arrays(), head.get("c", {}), flat=fp)
    d = msgpack.unpackb(b, raw=False)
    return EvaluateIns([_unpack_array(a) for a in d["p"]], d["c"])


def encode_evaluate_res(x: EvaluateRes) -> bytes:
    return msgpack.packb({"l": float(x.loss), "n": x.num_examples,
                          "m": _enc_config(x.metrics)}, use_bin_type=True)


def decode_evaluate_res(b: bytes) -> EvaluateRes:
    d = msgpack.unpackb(b, raw=False)
    return EvaluateRes(d["l"], d["n"], d["m"])


def encode_properties_res(props: Dict[str, Any]) -> bytes:
    """get_properties response — plain msgpack (codec lists and friends;
    no tensor payload, so no framing needed)."""
    return msgpack.packb(props, use_bin_type=True)


def decode_properties_res(b: bytes) -> Dict[str, Any]:
    return msgpack.unpackb(b, raw=False)


def encode_task_ins(t: TaskIns) -> bytes:
    return msgpack.packb({"t": t.task_type, "r": t.round, "p": t.payload,
                          "id": t.task_id, "g": t.group_id}, use_bin_type=True)


def decode_task_ins(b: Buffer) -> TaskIns:
    """Accepts any buffer (the TCP SuperNode pull path hands a read-only
    memoryview of the received RES frame straight in — msgpack copies the
    small envelope, the tensor payload stays a bin that downstream
    zero-copy decoders wrap without another copy)."""
    d = msgpack.unpackb(b, raw=False)
    return TaskIns(d["t"], d["r"], d["p"], d["id"], d["g"])


def encode_task_res(t: TaskRes) -> bytes:
    return msgpack.packb({"t": t.task_type, "r": t.round, "p": t.payload,
                          "id": t.task_id, "e": t.error}, use_bin_type=True)


def decode_task_res(b: Buffer) -> TaskRes:
    d = msgpack.unpackb(b, raw=False)
    return TaskRes(d["t"], d["r"], d["p"], d["id"], d["e"])

"""Wire format for the Flower-analogue app layer.

Everything that crosses a process/transport boundary is **bytes** encoded
with msgpack: numpy arrays travel as (dtype, shape, raw-buffer) triples, so
the encoding is exact (bitwise) — a prerequisite for the paper's Fig. 5
reproducibility claim (native vs. in-FLARE must match exactly).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import msgpack
import numpy as np

import jax

NDArrays = List[np.ndarray]


# ---------------------------------------------------------------------------
# array codec
# ---------------------------------------------------------------------------
def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # bf16/fp8 extension dtypes (jax dependency)

        return np.dtype(getattr(ml_dtypes, name))


def _pack_array(a: np.ndarray) -> Dict[str, Any]:
    a = np.ascontiguousarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: Dict[str, Any]) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=_np_dtype(d["dtype"])) \
        .reshape(d["shape"]).copy()


def arrays_to_bytes(arrays: NDArrays) -> bytes:
    return msgpack.packb([_pack_array(a) for a in arrays], use_bin_type=True)


def bytes_to_arrays(b: bytes) -> NDArrays:
    return [_unpack_array(d) for d in msgpack.unpackb(b, raw=False)]


# pytree <-> flat NDArrays (clients keep the treedef; the wire sees arrays)
def params_to_arrays(params) -> NDArrays:
    return [np.asarray(x) for x in jax.tree.leaves(params)]


def arrays_to_params(arrays: NDArrays, like):
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(arrays), (len(leaves), len(arrays))
    import jax.numpy as jnp

    return jax.tree.unflatten(
        treedef, [jnp.asarray(a, dtype=l.dtype) for a, l in zip(arrays, leaves)])


# ---------------------------------------------------------------------------
# task messages
# ---------------------------------------------------------------------------
@dataclass
class FitIns:
    parameters: NDArrays
    config: Dict[str, Any] = field(default_factory=dict)


@dataclass
class FitRes:
    parameters: NDArrays
    num_examples: int
    metrics: Dict[str, Any] = field(default_factory=dict)


@dataclass
class EvaluateIns:
    parameters: NDArrays
    config: Dict[str, Any] = field(default_factory=dict)


@dataclass
class EvaluateRes:
    loss: float
    num_examples: int
    metrics: Dict[str, Any] = field(default_factory=dict)


@dataclass
class TaskIns:
    task_type: str              # "fit" | "evaluate" | "get_parameters"
    round: int
    payload: bytes              # encoded FitIns / EvaluateIns
    task_id: str = ""
    group_id: str = ""


@dataclass
class TaskRes:
    task_type: str
    round: int
    payload: bytes              # encoded FitRes / EvaluateRes
    task_id: str = ""
    error: str = ""


def _enc_config(cfg: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in cfg.items():
        if isinstance(v, (int, float, str, bool, bytes)):
            out[k] = v
        else:
            raise TypeError(f"config value {k}={type(v)} not wire-safe")
    return out


def encode_fit_ins(x: FitIns) -> bytes:
    return msgpack.packb({"p": [_pack_array(a) for a in x.parameters],
                          "c": _enc_config(x.config)}, use_bin_type=True)


def decode_fit_ins(b: bytes) -> FitIns:
    d = msgpack.unpackb(b, raw=False)
    return FitIns([_unpack_array(a) for a in d["p"]], d["c"])


def encode_fit_res(x: FitRes) -> bytes:
    return msgpack.packb({"p": [_pack_array(a) for a in x.parameters],
                          "n": x.num_examples, "m": _enc_config(x.metrics)},
                         use_bin_type=True)


def decode_fit_res(b: bytes) -> FitRes:
    d = msgpack.unpackb(b, raw=False)
    return FitRes([_unpack_array(a) for a in d["p"]], d["n"], d["m"])


def encode_evaluate_ins(x: EvaluateIns) -> bytes:
    return msgpack.packb({"p": [_pack_array(a) for a in x.parameters],
                          "c": _enc_config(x.config)}, use_bin_type=True)


def decode_evaluate_ins(b: bytes) -> EvaluateIns:
    d = msgpack.unpackb(b, raw=False)
    return EvaluateIns([_unpack_array(a) for a in d["p"]], d["c"])


def encode_evaluate_res(x: EvaluateRes) -> bytes:
    return msgpack.packb({"l": float(x.loss), "n": x.num_examples,
                          "m": _enc_config(x.metrics)}, use_bin_type=True)


def decode_evaluate_res(b: bytes) -> EvaluateRes:
    d = msgpack.unpackb(b, raw=False)
    return EvaluateRes(d["l"], d["n"], d["m"])


def encode_task_ins(t: TaskIns) -> bytes:
    return msgpack.packb({"t": t.task_type, "r": t.round, "p": t.payload,
                          "id": t.task_id, "g": t.group_id}, use_bin_type=True)


def decode_task_ins(b: bytes) -> TaskIns:
    d = msgpack.unpackb(b, raw=False)
    return TaskIns(d["t"], d["r"], d["p"], d["id"], d["g"])


def encode_task_res(t: TaskRes) -> bytes:
    return msgpack.packb({"t": t.task_type, "r": t.round, "p": t.payload,
                          "id": t.task_id, "e": t.error}, use_bin_type=True)


def decode_task_res(b: bytes) -> TaskRes:
    d = msgpack.unpackb(b, raw=False)
    return TaskRes(d["t"], d["r"], d["p"], d["id"], d["e"])

"""Server-side app layer (Flower analogue, paper Listing 1).

    strategy = FedAdam(...)
    app = ServerApp(config=ServerConfig(num_rounds=3), strategy=strategy)

``ServerApp.run(driver)`` drives FL rounds against an abstract
:class:`Driver` (Flower Next's Driver API): the native simulation and the
FLARE-bridged deployment provide different drivers, the app code is
identical — the "no code changes" property under test in benchmarks.
"""
from __future__ import annotations

import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.fl.messages import (WIRE_CODECS, EvaluateRes, TaskIns,
                               decode_evaluate_res, decode_fit_res,
                               decode_properties_res, decode_task_res,
                               encode_evaluate_ins, encode_fit_ins,
                               encode_task_ins, bytes_to_arrays, peek_params)
from repro.fl.fedbuff import FedBuffBuffer
from repro.fl.registry import PopulationRegistry
from repro.fl.strategy import Strategy

NDArrays = List[np.ndarray]


@dataclass(frozen=True)
class ServerConfig:
    num_rounds: int = 3
    round_timeout: float = 120.0
    # requested wire codec for the model payloads ("sparse" structured-
    # sparse TopK/adapter deltas, "q8" int8+per-chunk scales, "bf16", or
    # None/"flat" for the lossless default).  A lossy codec is only used
    # after every node advertises it via get_properties; otherwise the
    # run demotes down the ladder sparse -> q8 -> flat (see
    # repro.fl.messages module docstring, "Codec negotiation").
    codec: Optional[str] = None
    # "sparse" codec knob: fraction of coordinates a TopK client update
    # keeps (clients exposing trainable_ranges() ship their adapter
    # subset instead and ignore this).  Rides in the fit config.
    sparse_frac: float = 0.01
    # aggregation kernel backend for the strategy ("numpy" | "pallas" |
    # None = auto: Pallas on TPU hosts, numpy elsewhere).  Applied to the
    # strategy at app construction so streaming arrival-order
    # accumulation folds through the fused device kernels (see
    # repro.fl.agg_kernels "Backend dispatch").
    agg_backend: Optional[str] = None
    # server-state sharding: split the round's streaming accumulator and
    # any FedOpt moments into this many contiguous qchunk-aligned ranges
    # (per-shard memory ~1/agg_shards of the single-host fp64 footprint,
    # one fused kernel per shard, all-gather at finalize).  None keeps
    # the single-host reference state.  ``shard_mesh`` (a jax Mesh)
    # instead derives the count from its "data" axis and pins each
    # shard's kernel to the matching device — see
    # repro.launch.mesh.make_agg_mesh and StreamingWeightedSum.
    agg_shards: Optional[int] = None
    shard_mesh: Optional[Any] = None
    # fleet sampling: draw sample_k of the connected nodes each round
    # (availability-weighted via repro.fl.registry.PopulationRegistry,
    # seeded by sample_seed so runs replay).  None = everyone, the
    # pre-sampling behavior.
    sample_k: Optional[int] = None
    sample_seed: int = 0
    sample_min_weight: float = 0.05
    # async FedBuff mode (repro.fl.fedbuff): fold updates as they
    # arrive with a staleness-discounted weight; advance the global
    # version every async_buffer_k folds; drop updates staler than
    # async_max_staleness.  num_rounds counts version advances.
    # async_concurrency caps in-flight fit tasks (None = whole pool);
    # evaluate runs every async_eval_every advances (0 = never).
    async_mode: bool = False
    async_buffer_k: int = 2
    async_max_staleness: int = 4
    async_staleness_exponent: float = 0.5
    async_concurrency: Optional[int] = None
    async_eval_every: int = 1
    # fleet transport: "inproc" keeps the in-process SuperLink queues;
    # "tcp" serves the same Fleet API over real sockets
    # (repro.core.transport.TcpSuperLink) with per-peer credit
    # backpressure, heartbeats, and reconnect-resume.  The app layer is
    # identical either way — run_native() reads these to build the link.
    # bind_port=0 picks an ephemeral port (the link exposes .address).
    transport: str = "inproc"
    bind_host: str = "127.0.0.1"
    bind_port: int = 0


class Driver:
    """Transport abstraction the ServerApp runs against."""

    def node_ids(self) -> List[str]:
        raise NotImplementedError

    def send_and_receive(self, tasks: Dict[str, bytes],
                         timeout: float) -> Dict[str, bytes]:
        """node_id -> TaskIns bytes; returns node_id -> TaskRes bytes.

        All-or-nothing batch API: raises ``TimeoutError`` if any task
        misses the (shared) deadline.  Callers that tolerate partial
        participation use :meth:`send_and_receive_iter` instead.
        """
        raise NotImplementedError

    def send_and_receive_iter(self, tasks: Dict[str, bytes], timeout: float):
        """Yield (node_id, TaskRes bytes) pairs as results become
        available, releasing each buffer to the consumer.

        Streaming transports yield in **arrival order** and simply stop
        yielding once the shared deadline passes — a straggler or dead
        node means *fewer* pairs, never an exception.  The caller records
        the missing nodes as per-node failures (the FedAvg-family
        accumulators are order-insensitive up to fp64 rounding).

        The default adapts the blocking API and yields in sorted node
        order, which keeps aggregation deterministic.  The blocking API is
        all-or-nothing, so on timeout the adapter yields nothing and every
        node is recorded as a failure — the contract holds either way.
        """
        try:
            res = self.send_and_receive(tasks, timeout)
        except TimeoutError:
            return
        for node in sorted(res):
            yield node, res.pop(node)


@dataclass
class RoundRecord:
    round: int
    loss: Optional[float] = None
    metrics: Dict[str, Any] = field(default_factory=dict)
    # (node_id, reason) for every node that errored or missed the deadline
    # in this round (fit and evaluate phases combined)
    failures: List[Tuple[str, str]] = field(default_factory=list)


@dataclass
class History:
    rounds: List[RoundRecord] = field(default_factory=list)
    final_parameters: Optional[NDArrays] = None

    def losses(self) -> List[Tuple[int, float]]:
        return [(r.round, r.loss) for r in self.rounds if r.loss is not None]


class ServerApp:
    def __init__(self, config: ServerConfig, strategy: Strategy):
        self.config = config
        self.strategy = strategy
        self.registry = PopulationRegistry(
            seed=config.sample_seed, min_weight=config.sample_min_weight)
        if config.agg_backend is not None and hasattr(strategy, "backend"):
            strategy.backend = config.agg_backend
        if config.agg_shards is not None and hasattr(strategy, "shards"):
            strategy.shards = config.agg_shards
        if config.shard_mesh is not None and hasattr(strategy,
                                                     "shard_mesh"):
            strategy.shard_mesh = config.shard_mesh

    @staticmethod
    def _memo_encode(memo: Dict[Any, bytes], ins, enc_fn,
                     codec: Optional[str]) -> bytes:
        """One (potentially lossy, model-size) encode per distinct
        broadcast (params, config) per round — all nodes usually share
        the same parameters object, so this is one quantization pass per
        round, not one per node.  Shared by the fit and evaluate phases
        so their key scheme can never desynchronize."""
        try:
            key = (id(ins.parameters), id(ins.flat),
                   tuple(sorted(ins.config.items())))
            payload = memo.get(key)
        except TypeError:
            # unhashable/unsortable config value: skip the memo so the
            # encoder's own "not wire-safe" error (the pre-memo
            # behavior) surfaces
            return enc_fn(ins, codec=codec)
        if payload is None:
            payload = memo[key] = enc_fn(ins, codec=codec)
        return payload

    @staticmethod
    def _exchange(driver: Driver, tasks: Dict[str, bytes], timeout: float,
                  on_result) -> List[Tuple[str, str]]:
        """Stream one round-trip: decode each TaskRes as it arrives and
        hand successes to ``on_result(node, task_res)``; return the
        failures — errored responses plus a ``(node, "timeout")`` entry
        for every node that missed the shared deadline."""
        failures: List[Tuple[str, str]] = []
        received = set()
        for node, tr_bytes in driver.send_and_receive_iter(tasks, timeout):
            received.add(node)
            try:
                tr = decode_task_res(tr_bytes)
                if tr.error:
                    failures.append((node, tr.error))
                else:
                    on_result(node, tr)
            except Exception as e:  # noqa: BLE001 — byzantine/buggy payload
                failures.append((node, f"malformed response: {e!r}"))
        for node in sorted(set(tasks) - received):
            failures.append((node, "timeout"))
        return failures

    # -------------------------------------------------------- negotiation
    def _negotiate_codec(self, driver: Driver,
                         nodes: List[str]) -> Tuple[str, str]:
        """Pick the wire codec for this run: the configured lossy codec if
        EVERY node advertises it in get_properties, else lossless "flat".

        A node that errors on the unknown task type (older peer) or
        misses the deadline demotes the whole fleet — a lossy frame it
        cannot decode would cost the round anyway.  Returns ``(codec,
        demotion_note)``; the note names the nodes responsible so a
        demoted run is visible in every RoundRecord, not silent."""
        want = self.config.codec or "flat"
        if want == "flat":
            return "flat", ""
        if want not in WIRE_CODECS:
            raise ValueError(f"unknown codec {want!r}; have {WIRE_CODECS}")
        note = ""
        if want == "sparse" and not self.strategy.supports_partial():
            # the sparse fold scatters into a weighted-sum accumulator;
            # strategies that need dense per-client rows (median/trim/
            # Krum — exactly the ones that refuse 0xF4 partials) get the
            # next rung down instead of a protocol violation per node
            note = ("sparse demoted to q8: strategy needs dense "
                    "per-client updates")
            want = "q8"
        tasks = {node: encode_task_ins(TaskIns(
            "get_properties", 0, b"", task_id=uuid.uuid4().hex))
            for node in nodes}
        supported: Optional[set] = None
        lacking: List[str] = []

        def on_props(node, tr):
            nonlocal supported
            cs = set(decode_properties_res(tr.payload)
                     .get("codecs", ("flat", "legacy")))
            if want not in cs:
                lacking.append(node)
            supported = cs if supported is None else supported & cs

        failures = self._exchange(driver, tasks, self.config.round_timeout,
                                  on_props)
        if failures or supported is None or want not in supported:
            culprits = sorted(set(lacking) | {n for n, _ in failures})
            who = ",".join(culprits) or "empty fleet"
            if want == "sparse" and not failures and supported \
                    and "q8" in supported:
                # a fleet that lacks sparse but all speaks q8 keeps the
                # int8-delta rung instead of falling to raw fp32
                return "q8", f"sparse demoted to q8 by {who}"
            demote = f"{want} demoted to flat by {who}"
            return "flat", f"{note}; {demote}" if note else demote
        return want, note

    # ------------------------------------------------ shared round phases
    def _initial_parameters(self, driver: Driver,
                            nodes: List[str]) -> NDArrays:
        """Round 0: pull initial parameters from the fleet — probed in
        small waves, each under ONE shared deadline and first success
        wins, so dead nodes neither abort the run nor stack up per-node
        timeouts, and a large fleet doesn't upload N models.  (On a
        blocking-only driver each wave is all-or-nothing: a dead node
        costs its whole wave, and the next wave is probed instead.)"""
        parameters = None
        errors: List[Tuple[str, str]] = []
        for lo in range(0, len(nodes), 3):
            wave = nodes[lo:lo + 3]
            tasks = {node: encode_task_ins(TaskIns(
                "get_parameters", 0, b"", task_id=uuid.uuid4().hex))
                for node in wave}
            received = set()
            for node, tr_bytes in driver.send_and_receive_iter(
                    tasks, self.config.round_timeout):
                received.add(node)
                try:
                    tr = decode_task_res(tr_bytes)
                    if tr.error:
                        errors.append((node, tr.error))
                        continue
                    parameters = bytes_to_arrays(tr.payload)
                except Exception as e:  # noqa: BLE001 — bad payload
                    errors.append((node, f"malformed response: {e!r}"))
                    continue
                break                # closing the iter reaps the rest
            if parameters is not None:
                return parameters
            errors.extend((n, "timeout") for n in wave
                          if n not in received)
        raise RuntimeError(
            f"no node returned initial parameters: {errors}")

    def _round_participants(self, nodes: List[str], rnd: int) -> List[str]:
        """The nodes this round talks to: everyone, or ``sample_k`` of
        them drawn availability-weighted from the registry."""
        if self.config.sample_k is None:
            return nodes
        return self.registry.sample(nodes, self.config.sample_k, rnd)

    def _evaluate_phase(self, driver: Driver, rnd: int,
                        parameters: NDArrays, nodes: List[str],
                        enc_codec: Optional[str], record: RoundRecord
                        ) -> None:
        """Configure/dispatch/aggregate one evaluate phase into
        ``record`` (no-op if the strategy declines to evaluate)."""
        ev_cfg = self.strategy.configure_evaluate(rnd, parameters, nodes)
        if not ev_cfg:
            return
        tasks = {}
        ev_memo: Dict[Any, bytes] = {}
        for node, ins in ev_cfg.items():
            payload = self._memo_encode(ev_memo, ins,
                                        encode_evaluate_ins, enc_codec)
            t = TaskIns("evaluate", rnd, payload,
                        task_id=uuid.uuid4().hex)
            tasks[node] = encode_task_ins(t)
        ev_results: List[Tuple[str, EvaluateRes]] = []
        ev_failures = self._exchange(
            driver, tasks, self.config.round_timeout,
            lambda node, tr: ev_results.append(
                (node, decode_evaluate_res(tr.payload))))
        ev_results.sort()              # arrival order -> deterministic
        loss, ev_metrics = self.strategy.aggregate_evaluate(
            rnd, ev_results, ev_failures)
        record.loss = loss
        record.metrics.update(ev_metrics)
        record.failures.extend(ev_failures)

    # ------------------------------------------------------------- rounds
    def run(self, driver: Driver) -> History:
        if self.config.async_mode:
            return self.run_async(driver)
        history = History()
        nodes = sorted(driver.node_ids())
        if not nodes:
            raise RuntimeError("no connected nodes")
        wire_codec, demotion = self._negotiate_codec(driver, nodes)
        # "flat" means: leave the encode to the process default (which may
        # legitimately be "legacy" for mixed-fleet deployments)
        enc_codec = None if wire_codec == "flat" else wire_codec

        parameters = self.strategy.initialize_parameters()
        if parameters is None:
            parameters = self._initial_parameters(driver, nodes)
        partial_ok = self.strategy.supports_partial()

        for rnd in range(1, self.config.num_rounds + 1):
            participants = self._round_participants(nodes, rnd)
            # ---- fit phase ----------------------------------------------
            fit_cfg = self.strategy.configure_fit(rnd, parameters,
                                                  participants)
            tasks = {}
            fit_payloads: Dict[str, bytes] = {}
            enc_memo: Dict[Any, bytes] = {}
            for node, ins in fit_cfg.items():
                if wire_codec != "flat":
                    ins.config.setdefault("codec", wire_codec)
                if wire_codec == "sparse":
                    ins.config.setdefault("sparse_frac",
                                          self.config.sparse_frac)
                if partial_ok:
                    # edge aggregators may pre-reduce their subtree into
                    # one 0xF4 partial-sum frame; leaf clients ignore it
                    ins.config.setdefault("partial", 1)
                payload = self._memo_encode(enc_memo, ins, encode_fit_ins,
                                            enc_codec)
                fit_payloads[node] = payload
                t = TaskIns("fit", rnd, payload, task_id=uuid.uuid4().hex)
                tasks[node] = encode_task_ins(t)
            # delta reconstruction bases: OUR OWN downlink bytes, i.e.
            # exactly what each client decoded and trained from — client
            # and server agree on the round base bitwise even when the
            # downlink itself is quantized
            bases: Dict[int, Any] = {}

            def _base_for(node):
                p = fit_payloads[node]
                bp = bases.get(id(p))
                if bp is None:
                    bp = bases[id(p)] = peek_params(p)
                return bp

            fit_ok: List[str] = []

            def on_fit(node, tr):
                res = decode_fit_res(tr.payload)
                q = res.quant
                if q is not None and q.is_delta and q.base is None:
                    q.base = _base_for(node)
                sp = res.sparse
                if sp is not None and sp.base is None:
                    sp.base = _base_for(node)
                acc.add(node, res)
                fit_ok.append(node)

            # results fold into the strategy's accumulator as they arrive
            # (zero-copy flat views / streaming sums — no per-layer stacking)
            acc = self.strategy.fit_accumulator(rnd, parameters)
            # stragglers / dead nodes: recorded failures, not round-aborting
            failures = self._exchange(
                driver, tasks, self.config.round_timeout, on_fit)
            parameters, agg_metrics = acc.finalize(failures)

            # ---- evaluate phase ------------------------------------------
            record = RoundRecord(rnd, metrics=dict(agg_metrics),
                                 failures=list(failures))
            if self.config.codec and self.config.codec != "flat":
                # a requested lossy codec is ALWAYS reported — seeing
                # wire_codec="flat" (+ the demotion note) tells the
                # operator the fleet fell back to raw fp32
                record.metrics.setdefault("wire_codec", wire_codec)
                if demotion:
                    record.metrics.setdefault("wire_codec_demotion",
                                              demotion)
            self._evaluate_phase(driver, rnd, parameters, participants,
                                 enc_codec, record)
            # availability feedback drives the next round's sampling
            self.registry.observe(fit_ok, record.failures)
            history.rounds.append(record)

        history.final_parameters = parameters
        return history

    # -------------------------------------------------------------- async
    def run_async(self, driver: Driver) -> History:
        """FedBuff-style asynchronous run (see :mod:`repro.fl.fedbuff`).

        Needs a streaming driver exposing ``open_stream()`` (e.g.
        SuperLinkDriver): fit tasks stay in flight continuously, each
        arriving update folds immediately with a staleness-discounted
        weight, and the global version advances every ``async_buffer_k``
        folds — ``num_rounds`` counts advances.  One RoundRecord per
        advance; evaluate runs every ``async_eval_every`` advances.
        """
        open_stream = getattr(driver, "open_stream", None)
        if open_stream is None:
            raise RuntimeError(
                "async_mode needs a streaming driver with open_stream() "
                "(e.g. SuperLinkDriver)")
        cfg = self.config
        history = History()
        nodes = sorted(driver.node_ids())
        if not nodes:
            raise RuntimeError("no connected nodes")
        wire_codec, demotion = self._negotiate_codec(driver, nodes)
        enc_codec = None if wire_codec == "flat" else wire_codec
        parameters = self.strategy.initialize_parameters()
        if parameters is None:
            parameters = self._initial_parameters(driver, nodes)
        partial_ok = self.strategy.supports_partial()
        buf = FedBuffBuffer(
            self.strategy, buffer_k=cfg.async_buffer_k,
            max_staleness=cfg.async_max_staleness,
            staleness_exponent=cfg.async_staleness_exponent)
        pool = self._round_participants(nodes, 0)
        width = min(cfg.async_concurrency or len(pool), len(pool))

        # one encoded downlink per (version, distinct config).  The memos
        # are kept for the whole run: delta bases are keyed by payload
        # identity, so every dispatched payload must stay alive or a
        # recycled id() could alias a stale base.
        enc_memos: Dict[int, Dict[Any, bytes]] = {}
        bases: Dict[int, Any] = {}

        def base_for(payload: bytes):
            bp = bases.get(id(payload))
            if bp is None:
                bp = bases[id(payload)] = peek_params(payload)
            return bp

        # task_id -> (node, trained_version, downlink payload)
        outstanding: Dict[str, Tuple[str, int, bytes]] = {}

        def dispatch(stream, node: str) -> None:
            ver = buf.version
            ins = self.strategy.configure_fit(ver, parameters,
                                              [node])[node]
            if wire_codec != "flat":
                ins.config.setdefault("codec", wire_codec)
            if wire_codec == "sparse":
                ins.config.setdefault("sparse_frac", cfg.sparse_frac)
            if partial_ok:
                ins.config.setdefault("partial", 1)
            memo = enc_memos.setdefault(ver, {})
            payload = self._memo_encode(memo, ins, encode_fit_ins,
                                        enc_codec)
            t = TaskIns("fit", ver, payload, task_id=uuid.uuid4().hex)
            tids = stream.send({node: encode_task_ins(t)})
            outstanding[tids[node]] = (node, ver, payload)

        fit_ok: List[str] = []
        failures: List[Tuple[str, str]] = []
        stream = open_stream()
        try:
            for node in pool[:width]:
                dispatch(stream, node)
            while buf.version < cfg.num_rounds:
                got = stream.recv(cfg.round_timeout)
                if got is None:
                    # nothing arrived within a full round_timeout: the
                    # in-flight fleet is dead/stalled — record and stop
                    for _tid, sent in sorted(outstanding.items()):
                        failures.append((sent[0], "timeout"))
                    break
                _node, tid, tr_bytes = got
                sent = outstanding.pop(tid, None)
                if sent is None:
                    continue         # late duplicate of a reaped task
                node, ver, payload = sent
                try:
                    tr = decode_task_res(tr_bytes)
                    if tr.error:
                        failures.append((node, tr.error))
                    else:
                        res = decode_fit_res(tr.payload)
                        q = res.quant
                        if q is not None and q.is_delta and q.base is None:
                            q.base = base_for(payload)
                        sp = res.sparse
                        if sp is not None and sp.base is None:
                            sp.base = base_for(payload)
                        if buf.offer(node, res, ver,
                                     parameters) == "stale":
                            failures.append(
                                (node, f"stale update dropped (trained "
                                       f"at v{ver}, server at "
                                       f"v{buf.version})"))
                        else:
                            fit_ok.append(node)
                except Exception as e:  # noqa: BLE001 — byzantine payload
                    failures.append((node, f"malformed response: {e!r}"))
                if buf.ready():
                    parameters, adv_metrics = buf.advance(parameters)
                    record = RoundRecord(buf.version,
                                         metrics=dict(adv_metrics),
                                         failures=list(failures))
                    if cfg.codec and cfg.codec != "flat":
                        record.metrics.setdefault("wire_codec",
                                                  wire_codec)
                        if demotion:
                            record.metrics.setdefault(
                                "wire_codec_demotion", demotion)
                    if cfg.async_eval_every and (
                            buf.version % cfg.async_eval_every == 0
                            or buf.version == cfg.num_rounds):
                        self._evaluate_phase(driver, buf.version,
                                             parameters, pool,
                                             enc_codec, record)
                    self.registry.observe(fit_ok, record.failures)
                    fit_ok, failures = [], []
                    history.rounds.append(record)
                if buf.version < cfg.num_rounds:
                    dispatch(stream, node)
        finally:
            stream.close()
        if fit_ok or failures:
            # stragglers that landed after the final advance
            self.registry.observe(fit_ok, failures)
            if history.rounds:
                history.rounds[-1].failures.extend(failures)
        history.final_parameters = parameters
        return history

"""Server-side app layer (Flower analogue, paper Listing 1).

    strategy = FedAdam(...)
    app = ServerApp(config=ServerConfig(num_rounds=3), strategy=strategy)

``ServerApp.run(driver)`` drives FL rounds against an abstract
:class:`Driver` (Flower Next's Driver API): the native simulation and the
FLARE-bridged deployment provide different drivers, the app code is
identical — the "no code changes" property under test in benchmarks.
"""
from __future__ import annotations

import threading
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fl.messages import (EvaluateRes, TaskIns, decode_evaluate_res,
                               decode_fit_res, decode_task_res,
                               encode_evaluate_ins, encode_fit_ins,
                               encode_task_ins, bytes_to_arrays)
from repro.fl.strategy import Strategy

NDArrays = List[np.ndarray]


@dataclass(frozen=True)
class ServerConfig:
    num_rounds: int = 3
    round_timeout: float = 120.0


class Driver:
    """Transport abstraction the ServerApp runs against."""

    def node_ids(self) -> List[str]:
        raise NotImplementedError

    def send_and_receive(self, tasks: Dict[str, bytes],
                         timeout: float) -> Dict[str, bytes]:
        """node_id -> TaskIns bytes; returns node_id -> TaskRes bytes."""
        raise NotImplementedError

    def send_and_receive_iter(self, tasks: Dict[str, bytes], timeout: float):
        """Yield (node_id, TaskRes bytes) pairs as results become
        available, releasing each buffer to the consumer.

        The default adapts the blocking API and yields in sorted node
        order, which keeps aggregation deterministic; streaming transports
        can override to yield in arrival order (the FedAvg-family
        accumulators are order-insensitive up to fp64 rounding).
        """
        res = self.send_and_receive(tasks, timeout)
        for node in sorted(res):
            yield node, res.pop(node)


@dataclass
class RoundRecord:
    round: int
    loss: Optional[float] = None
    metrics: Dict[str, Any] = field(default_factory=dict)


@dataclass
class History:
    rounds: List[RoundRecord] = field(default_factory=list)
    final_parameters: Optional[NDArrays] = None

    def losses(self) -> List[Tuple[int, float]]:
        return [(r.round, r.loss) for r in self.rounds if r.loss is not None]


class ServerApp:
    def __init__(self, config: ServerConfig, strategy: Strategy):
        self.config = config
        self.strategy = strategy

    # ------------------------------------------------------------- rounds
    def run(self, driver: Driver) -> History:
        history = History()
        nodes = sorted(driver.node_ids())
        if not nodes:
            raise RuntimeError("no connected nodes")

        # round 0: pull initial parameters from the first node if the
        # strategy does not provide them
        parameters = self.strategy.initialize_parameters()
        if parameters is None:
            t = TaskIns("get_parameters", 0, b"", task_id=uuid.uuid4().hex)
            res = driver.send_and_receive(
                {nodes[0]: encode_task_ins(t)}, self.config.round_timeout)
            task_res = decode_task_res(res[nodes[0]])
            if task_res.error:
                raise RuntimeError(task_res.error)
            parameters = bytes_to_arrays(task_res.payload)

        for rnd in range(1, self.config.num_rounds + 1):
            # ---- fit phase ----------------------------------------------
            fit_cfg = self.strategy.configure_fit(rnd, parameters, nodes)
            tasks = {}
            for node, ins in fit_cfg.items():
                t = TaskIns("fit", rnd, encode_fit_ins(ins),
                            task_id=uuid.uuid4().hex)
                tasks[node] = encode_task_ins(t)
            # results fold into the strategy's accumulator as they arrive
            # (zero-copy flat views / streaming sums — no per-layer stacking)
            acc = self.strategy.fit_accumulator(rnd, parameters)
            failures: List[Tuple[str, str]] = []
            for node, tr_bytes in driver.send_and_receive_iter(
                    tasks, self.config.round_timeout):
                tr = decode_task_res(tr_bytes)
                if tr.error:
                    failures.append((node, tr.error))
                else:
                    acc.add(node, decode_fit_res(tr.payload))
            parameters, agg_metrics = acc.finalize(failures)

            # ---- evaluate phase ------------------------------------------
            ev_cfg = self.strategy.configure_evaluate(rnd, parameters, nodes)
            record = RoundRecord(rnd, metrics=dict(agg_metrics))
            if ev_cfg:
                tasks = {}
                for node, ins in ev_cfg.items():
                    t = TaskIns("evaluate", rnd, encode_evaluate_ins(ins),
                                task_id=uuid.uuid4().hex)
                    tasks[node] = encode_task_ins(t)
                res = driver.send_and_receive(tasks, self.config.round_timeout)
                ev_results: List[Tuple[str, EvaluateRes]] = []
                for node in sorted(res):
                    tr = decode_task_res(res[node])
                    if not tr.error:
                        ev_results.append((node, decode_evaluate_res(tr.payload)))
                loss, ev_metrics = self.strategy.aggregate_evaluate(
                    rnd, ev_results, [])
                record.loss = loss
                record.metrics.update(ev_metrics)
            history.rounds.append(record)

        history.final_parameters = parameters
        return history

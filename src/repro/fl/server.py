"""Server-side app layer (Flower analogue, paper Listing 1).

    strategy = FedAdam(...)
    app = ServerApp(config=ServerConfig(num_rounds=3), strategy=strategy)

``ServerApp.run(driver)`` drives FL rounds against an abstract
:class:`Driver` (Flower Next's Driver API): the native simulation and the
FLARE-bridged deployment provide different drivers, the app code is
identical — the "no code changes" property under test in benchmarks.
"""
from __future__ import annotations

import threading
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fl.messages import (EvaluateRes, TaskIns, decode_evaluate_res,
                               decode_fit_res, decode_task_res,
                               encode_evaluate_ins, encode_fit_ins,
                               encode_task_ins, bytes_to_arrays)
from repro.fl.strategy import Strategy

NDArrays = List[np.ndarray]


@dataclass(frozen=True)
class ServerConfig:
    num_rounds: int = 3
    round_timeout: float = 120.0


class Driver:
    """Transport abstraction the ServerApp runs against."""

    def node_ids(self) -> List[str]:
        raise NotImplementedError

    def send_and_receive(self, tasks: Dict[str, bytes],
                         timeout: float) -> Dict[str, bytes]:
        """node_id -> TaskIns bytes; returns node_id -> TaskRes bytes.

        All-or-nothing batch API: raises ``TimeoutError`` if any task
        misses the (shared) deadline.  Callers that tolerate partial
        participation use :meth:`send_and_receive_iter` instead.
        """
        raise NotImplementedError

    def send_and_receive_iter(self, tasks: Dict[str, bytes], timeout: float):
        """Yield (node_id, TaskRes bytes) pairs as results become
        available, releasing each buffer to the consumer.

        Streaming transports yield in **arrival order** and simply stop
        yielding once the shared deadline passes — a straggler or dead
        node means *fewer* pairs, never an exception.  The caller records
        the missing nodes as per-node failures (the FedAvg-family
        accumulators are order-insensitive up to fp64 rounding).

        The default adapts the blocking API and yields in sorted node
        order, which keeps aggregation deterministic.  The blocking API is
        all-or-nothing, so on timeout the adapter yields nothing and every
        node is recorded as a failure — the contract holds either way.
        """
        try:
            res = self.send_and_receive(tasks, timeout)
        except TimeoutError:
            return
        for node in sorted(res):
            yield node, res.pop(node)


@dataclass
class RoundRecord:
    round: int
    loss: Optional[float] = None
    metrics: Dict[str, Any] = field(default_factory=dict)
    # (node_id, reason) for every node that errored or missed the deadline
    # in this round (fit and evaluate phases combined)
    failures: List[Tuple[str, str]] = field(default_factory=list)


@dataclass
class History:
    rounds: List[RoundRecord] = field(default_factory=list)
    final_parameters: Optional[NDArrays] = None

    def losses(self) -> List[Tuple[int, float]]:
        return [(r.round, r.loss) for r in self.rounds if r.loss is not None]


class ServerApp:
    def __init__(self, config: ServerConfig, strategy: Strategy):
        self.config = config
        self.strategy = strategy

    @staticmethod
    def _exchange(driver: Driver, tasks: Dict[str, bytes], timeout: float,
                  on_result) -> List[Tuple[str, str]]:
        """Stream one round-trip: decode each TaskRes as it arrives and
        hand successes to ``on_result(node, task_res)``; return the
        failures — errored responses plus a ``(node, "timeout")`` entry
        for every node that missed the shared deadline."""
        failures: List[Tuple[str, str]] = []
        received = set()
        for node, tr_bytes in driver.send_and_receive_iter(tasks, timeout):
            received.add(node)
            try:
                tr = decode_task_res(tr_bytes)
                if tr.error:
                    failures.append((node, tr.error))
                else:
                    on_result(node, tr)
            except Exception as e:  # noqa: BLE001 — byzantine/buggy payload
                failures.append((node, f"malformed response: {e!r}"))
        for node in sorted(set(tasks) - received):
            failures.append((node, "timeout"))
        return failures

    # ------------------------------------------------------------- rounds
    def run(self, driver: Driver) -> History:
        history = History()
        nodes = sorted(driver.node_ids())
        if not nodes:
            raise RuntimeError("no connected nodes")

        # round 0: pull initial parameters if the strategy does not provide
        # them — probed in small waves, each under ONE shared deadline and
        # first success wins, so dead nodes neither abort the run nor stack
        # up per-node timeouts, and a large fleet doesn't upload N models.
        # (On a blocking-only driver each wave is all-or-nothing: a dead
        # node costs its whole wave, and the next wave is probed instead.)
        parameters = self.strategy.initialize_parameters()
        if parameters is None:
            errors: List[Tuple[str, str]] = []
            for lo in range(0, len(nodes), 3):
                wave = nodes[lo:lo + 3]
                tasks = {node: encode_task_ins(TaskIns(
                    "get_parameters", 0, b"", task_id=uuid.uuid4().hex))
                    for node in wave}
                received = set()
                for node, tr_bytes in driver.send_and_receive_iter(
                        tasks, self.config.round_timeout):
                    received.add(node)
                    try:
                        tr = decode_task_res(tr_bytes)
                        if tr.error:
                            errors.append((node, tr.error))
                            continue
                        parameters = bytes_to_arrays(tr.payload)
                    except Exception as e:  # noqa: BLE001 — bad payload
                        errors.append((node, f"malformed response: {e!r}"))
                        continue
                    break                # closing the iter reaps the rest
                if parameters is not None:
                    break
                errors.extend((n, "timeout") for n in wave
                              if n not in received)
            if parameters is None:
                raise RuntimeError(
                    f"no node returned initial parameters: {errors}")

        for rnd in range(1, self.config.num_rounds + 1):
            # ---- fit phase ----------------------------------------------
            fit_cfg = self.strategy.configure_fit(rnd, parameters, nodes)
            tasks = {}
            for node, ins in fit_cfg.items():
                t = TaskIns("fit", rnd, encode_fit_ins(ins),
                            task_id=uuid.uuid4().hex)
                tasks[node] = encode_task_ins(t)
            # results fold into the strategy's accumulator as they arrive
            # (zero-copy flat views / streaming sums — no per-layer stacking)
            acc = self.strategy.fit_accumulator(rnd, parameters)
            # stragglers / dead nodes: recorded failures, not round-aborting
            failures = self._exchange(
                driver, tasks, self.config.round_timeout,
                lambda node, tr: acc.add(node, decode_fit_res(tr.payload)))
            parameters, agg_metrics = acc.finalize(failures)

            # ---- evaluate phase ------------------------------------------
            ev_cfg = self.strategy.configure_evaluate(rnd, parameters, nodes)
            record = RoundRecord(rnd, metrics=dict(agg_metrics),
                                 failures=list(failures))
            if ev_cfg:
                tasks = {}
                for node, ins in ev_cfg.items():
                    t = TaskIns("evaluate", rnd, encode_evaluate_ins(ins),
                                task_id=uuid.uuid4().hex)
                    tasks[node] = encode_task_ins(t)
                ev_results: List[Tuple[str, EvaluateRes]] = []
                ev_failures = self._exchange(
                    driver, tasks, self.config.round_timeout,
                    lambda node, tr: ev_results.append(
                        (node, decode_evaluate_res(tr.payload))))
                ev_results.sort()          # arrival order -> deterministic
                loss, ev_metrics = self.strategy.aggregate_evaluate(
                    rnd, ev_results, ev_failures)
                record.loss = loss
                record.metrics.update(ev_metrics)
                record.failures.extend(ev_failures)
            history.rounds.append(record)

        history.final_parameters = parameters
        return history

"""Vectorized aggregation kernels over flat parameter buffers.

Every strategy's per-layer Python loop reduces to one of four kernels over
the (clients x total_params) logical matrix, all cache-blocked on a
``CHUNK``-element window so the float64 accumulator and scratch stay
resident in L2 while the loop streams each client's fp32 view exactly once:

- :func:`weighted_mean` — FedAvg's sum((w_i/W) * x_i).  The per-client
  weight is folded to ``np.float64(w_i / W)`` up front, which both removes
  the final rescale pass and (because the ops and their order match the
  legacy per-layer loop elementwise) keeps the result **bitwise identical**
  to the legacy implementation.
- :class:`StreamingWeightedSum` — the same reduction, but folding each
  client in as it arrives and releasing the payload; peak memory is one
  float64 accumulator instead of every client's update. sum(w_i x_i)/W
  differs from the fold by <=1 ULP of the fp64 accumulator (invisible
  after the fp32 cast).  With ``shards=N`` (or a mesh) the accumulator
  splits into N qchunk-aligned ranges — per-shard Pallas folds, decode/
  reduce overlap, deferred delta bases; see the class docstring.
- :func:`median` / :func:`trimmed_mean` — coordinate-wise robust
  aggregation on a chunk-stacked (n, CHUNK) float64 tile (peak extra
  memory O(n * CHUNK), not O(n * total)).
- :func:`krum_distances` — all pairwise squared L2 distances via a
  chunk-accumulated Gram matrix: ||a-b||^2 = ||a||^2 + ||b||^2 - 2<a,b>,
  one dgemm per chunk instead of the O(n^2) Python loop over full vectors.

Every kernel reads its inputs through the chunked ``f64_chunk(lo, hi,
out)`` protocol, which both :class:`~repro.fl.flat.FlatParams` (raw
buffers) and :class:`~repro.fl.flat.QuantParams` (int8/bf16 compressed
wire payloads) implement.  For quantized inputs the dequantize + scale
(+ delta-base add) is **fused into the per-chunk read**, so accumulators
consume compressed buffers directly — peak extra memory stays one
CHUNK-sized fp64 scratch, never a model-size fp32 copy of the payload.

NB (numpy>=2 / NEP 50): scalar weights MUST be ``np.float64`` — a bare
python float is "weak" and would demote the multiply to the fp32 loop,
silently breaking the exactness guarantee.

Backend dispatch
----------------
Every public kernel takes ``backend="numpy" | "pallas" | None`` (None /
"auto" resolves to :func:`default_backend`: the Pallas path on TPU hosts,
numpy everywhere else — overridable with ``REPRO_AGG_BACKEND`` or
:func:`set_default_backend`).  The contract:

- the numpy path is the reference and the default off-TPU; its arithmetic
  is frozen (the fig. 5 bitwise-repro claim rides on it);
- the Pallas path (:mod:`repro.kernels.agg_reduce`) must agree with it to
  <=1 ULP of the output leaf dtype for every (kernel, codec) pair — it is
  bitwise in practice, and `tests/test_agg_pallas.py` enforces the bound
  across layouts, dtypes, codecs (0xF1/0xF2/0xF3 incl. int8 deltas) and
  client counts.  Krum's Gram matmul reduction order is hardware-defined,
  so its *distances* carry a tight relative tolerance instead while the
  selection and the aggregate stay exact;
- off-TPU the Pallas kernels run in interpret mode, so CI exercises the
  real kernel bodies on CPU;
- payload stacks the Pallas kernels cannot express fall back to numpy
  silently: non-float domains (SecAgg uint64 shares), clients with
  heterogeneous codecs/dtypes in one round, mismatched int8 scale
  windows, or delta payloads with more than one distinct base.  Fallback
  is per-call, so a single odd client never aborts a round.
"""
from __future__ import annotations

import contextlib
import os
import queue
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fl.flat import FlatParams, Layout, memo_token, np_dtype

# 16K elements: chunk fp64 accumulator + scratch = 256 KiB, L2-resident.
# QCHUNK (int8 scale window) divides CHUNK, so quantized reads stay aligned.
CHUNK = 1 << 14

_FLOATS = {"float16", "float32", "float64"}

# ---------------------------------------------------------------------------
# backend selection
# ---------------------------------------------------------------------------
BACKENDS = ("numpy", "pallas")
_DEFAULT_BACKEND: Optional[str] = None


def default_backend() -> str:
    """Resolved process default: ``REPRO_AGG_BACKEND`` if set, else
    "pallas" when a TPU is attached, else "numpy"."""
    global _DEFAULT_BACKEND
    if _DEFAULT_BACKEND is None:
        env = os.environ.get("REPRO_AGG_BACKEND", "").strip().lower()
        if env:
            if env not in BACKENDS:
                raise ValueError(
                    f"REPRO_AGG_BACKEND={env!r}; expected one of {BACKENDS}")
            _DEFAULT_BACKEND = env
        else:
            _DEFAULT_BACKEND = "pallas" if _on_tpu() else "numpy"
    return _DEFAULT_BACKEND


def set_default_backend(name: Optional[str]) -> None:
    """Override (or with ``None`` re-derive) the process default."""
    global _DEFAULT_BACKEND
    if name is not None and name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; have {BACKENDS}")
    _DEFAULT_BACKEND = name


def _on_tpu() -> bool:
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:  # noqa: BLE001 — no jax, no accelerator
        return False


def resolve_backend(backend: Optional[str]) -> str:
    if backend in (None, "auto"):
        return default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    return backend


def _interpret() -> bool:
    # off-TPU the kernel bodies execute in interpret mode (CPU CI)
    return not _on_tpu()


def _host_cores() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except (AttributeError, OSError):
        return os.cpu_count() or 1


def resolve_shards(shards: Optional[int], mesh=None) -> int:
    """Shard count for the server aggregation state: an explicit count
    wins; otherwise the mesh's "data" axis size (total device count for
    meshes without one).  0 means single-host (legacy) state."""
    if shards:
        if shards < 0:
            raise ValueError(f"shards must be >= 1, got {shards}")
        return int(shards)
    if mesh is None:
        return 0
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("data", mesh.devices.size))


def _tile_stack(flats: Sequence) -> Optional[Dict[str, Any]]:
    """Stack per-client :class:`~repro.fl.flat.TileSource` adapters into
    the (C, N) host arrays the Pallas kernels consume, or ``None`` when
    the round must fall back to numpy (see module docstring)."""
    sources = []
    for fp in flats:
        ts = getattr(fp, "tile_source", None)
        src = ts() if ts is not None else None
        if src is None:
            return None
        sources.append(src)
    first = sources[0]
    if any(s.kind != first.kind for s in sources):
        return None
    bases = {id(s.base): s.base for s in sources}
    if len(bases) > 1:
        return None
    base_obj = next(iter(bases.values()))
    base = base_obj.to_f64() if base_obj is not None else None
    if first.kind == "q8":
        if any(s.qchunk != first.qchunk for s in sources):
            return None
        return {"data": np.stack([s.data for s in sources]),
                "scales": np.stack([s.scales for s in sources]),
                "qchunk": first.qchunk, "base": base}
    if any(s.data.dtype != first.data.dtype for s in sources):
        return None
    return {"data": np.stack([s.data for s in sources]), "scales": None,
            "qchunk": 1, "base": base}


def _scatter_leaves(vec: np.ndarray, layout: Layout,
                    out: FlatParams) -> None:
    """Write a full math vector into ``out`` leaf by leaf, casting to each
    leaf's dtype — the one shared rounding path for every kernel's
    non-uniform (or vector-producing) output."""
    for i, spec in enumerate(layout.leaves):
        out.leaf(i)[...] = vec[spec.eoffset:spec.eoffset + spec.size] \
            .reshape(spec.shape).astype(np_dtype(spec.dtype))


def _vec_to_flat(vec: np.ndarray, layout: Layout) -> FlatParams:
    """fp64 math vector -> FlatParams, with the same per-element rounding
    the numpy kernels apply when writing their output chunks."""
    out = FlatParams.zeros(layout)
    if layout.uniform_dtype in _FLOATS:
        out.math_view()[...] = vec
    else:
        _scatter_leaves(vec, layout, out)
    return out


def weighted_mean(pairs: Sequence[Tuple[FlatParams, float]],
                  layout: Layout, backend: Optional[str] = None,
                  block: Optional[int] = None) -> FlatParams:
    """sum((w_i / W) x_i) over flat buffers -> FlatParams of ``layout``.

    Chunk-outer / client-inner: the fp64 accumulator chunk is reused across
    clients and cast straight into the output buffer, so no total-size fp64
    array is ever materialized.
    """
    total_w = float(sum(w for _, w in pairs))
    scaled = [np.float64(w / total_w) for _, w in pairs]
    out = FlatParams.zeros(layout)
    n = layout.total_size
    if n == 0 or not pairs:
        return out
    if resolve_backend(backend) == "pallas":
        stack = _tile_stack([fp for fp, _ in pairs])
        if stack is not None:
            from repro.kernels import agg_reduce

            vec = agg_reduce.weighted_sum(
                stack["data"], np.array(scaled, np.float64),
                scales=stack["scales"], qchunk=stack["qchunk"],
                base=stack["base"], block=block, interpret=_interpret())
            return _vec_to_flat(vec, layout)
    uniform = layout.uniform_dtype in _FLOATS
    ovec = out.math_view() if uniform else np.empty(n, np.float64)
    acc = np.empty(CHUNK, np.float64)
    scratch = np.empty(CHUNK, np.float64)
    tmp = np.empty(CHUNK, np.float64)
    for lo in range(0, n, CHUNK):
        hi = min(lo + CHUNK, n)
        a = acc[:hi - lo]
        x0 = pairs[0][0].f64_chunk(lo, hi, tmp)
        np.multiply(x0, scaled[0], out=a)
        for (fp, _), sw in zip(pairs[1:], scaled[1:]):
            x = fp.f64_chunk(lo, hi, tmp)
            np.multiply(x, sw, out=scratch[:hi - lo])
            a += scratch[:hi - lo]
        ovec[lo:hi] = a
    if not uniform:
        _scatter_leaves(ovec, layout, out)
    return out


class _DecodePipeline:
    """Decode/reduce overlap for the sharded streaming fold.

    One decoder thread pulls arrivals off a depth-1 job queue, streams
    each shard's range through the payload's ``decode_chunk`` into a
    slot from a small ring of reusable shard-size fp64 buffers, scales
    by the arrival weight, and hands (shard, buffer) to the caller's
    thread, which folds it into the per-shard accumulator — so the codec
    decode of arrival k+1 runs while arrival k is being reduced.  The
    job queue bounds live payload references at two (the one decoding
    plus the one queued: double buffering); the ring bounds decoded-but-
    unfolded data at ``nslots`` shard ranges.

    Ordering: one decoder + FIFO queues keep the (arrival, shard) fold
    order identical to the serial loop, so the result is bitwise equal
    to the non-overlapped fold.  A decoder exception is re-raised on the
    caller's thread at the next submit/drain and kills the pipeline (and
    so the round) — payload validation (shape checks, delta-base attach)
    happens before submit, so this path is reserved for genuinely
    malformed buffers.
    """

    def __init__(self, bounds: Sequence[Tuple[int, int]], nslots: int = 3):
        self._shards = [(si, lo, hi)
                        for si, (lo, hi) in enumerate(bounds) if hi > lo]
        maxm = max((hi - lo for _, lo, hi in self._shards), default=0)
        self._pool: "queue.Queue[np.ndarray]" = queue.Queue()
        for _ in range(nslots):
            self._pool.put(np.empty(maxm, np.float64))
        self._jobs: "queue.Queue" = queue.Queue(maxsize=1)
        self._out: "queue.Queue" = queue.Queue()
        self._failed = False
        self._closed = False
        self._thread = threading.Thread(
            target=self._run, name="agg-decode", daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            job = self._jobs.get()
            if job is None:
                self._out.put(None)
                return
            dec, sw = job
            try:
                for si, lo, hi in self._shards:
                    buf = self._pool.get()
                    for a in range(lo, hi, CHUNK):
                        b = min(a + CHUNK, hi)
                        o = buf[a - lo:b - lo]
                        dec(a, b, o)
                        o *= sw     # rounds like multiply-into-scratch
                    self._out.put((si, buf, hi - lo))
            except BaseException as e:  # noqa: BLE001 — forwarded to caller
                self._out.put(e)
                return

    def submit(self, dec, sw: np.float64, fold) -> None:
        if self._failed or self._closed:
            raise RuntimeError("aggregation decode pipeline is closed")
        while True:
            try:
                self._jobs.put_nowait((dec, sw))
                break
            except queue.Full:
                self._fold_next(fold, block=True)
        while self._fold_next(fold, block=False):
            pass

    def _fold_next(self, fold, block: bool) -> bool:
        try:
            item = self._out.get(block=block)
        except queue.Empty:
            return False
        if item is None:            # close sentinel: keep it for drain()
            self._out.put(None)
            return False
        if isinstance(item, BaseException):
            self._failed = True
            raise item
        si, buf, m = item
        try:
            fold(si, buf, m)
        finally:
            self._pool.put(buf)
        return True

    def drain(self, fold) -> None:
        """Close the job stream and fold everything still in flight."""
        if self._failed:
            raise RuntimeError("aggregation decode pipeline failed")
        if not self._closed:
            self._closed = True
            while True:
                # a plain blocking put could deadlock: the decoder may be
                # waiting on a ring slot only this thread can return
                try:
                    self._jobs.put_nowait(None)
                    break
                except queue.Full:
                    self._fold_next(fold, block=True)
        while True:
            item = self._out.get()
            if item is None:
                break
            if isinstance(item, BaseException):
                self._failed = True
                self._thread.join(timeout=10.0)
                raise item
            si, buf, m = item
            fold(si, buf, m)
            self._pool.put(buf)
        self._thread.join(timeout=10.0)


class StreamingWeightedSum:
    """Incremental sum(w_i x_i); finalize() divides by W and casts.

    Two modes:

    **Single-host (default, ``shards=None``)** — the frozen reference
    semantics: one fp64 accumulator, every arrival folded through
    ``f64_chunk`` (delta payloads reconstructed per arrival).  On the
    Pallas backend each arrival is one fused dequantize+scale+accumulate
    kernel launch; the padded device accumulator is **cached across
    arrivals keyed by the round's codec block geometry** (the common,
    codec-homogeneous case after PR 3 negotiation keeps one padded
    buffer and one async dispatch chain for the whole round), and only a
    mixed arrival with different geometry pays the retire + re-pad.

    **Sharded (``shards=N`` or ``mesh=...``)** — the round's accumulator
    splits into N contiguous qchunk-aligned ranges
    (:func:`repro.sharding.shard_bounds` over the mesh "data" axis), so
    per-shard memory is ~1/N of the single-host fp64 footprint and each
    range folds through its own per-shard Pallas call (pinned to the
    matching mesh device when a mesh is given); the all-gather into the
    output buffer happens once, at :meth:`finalize`.  Delta payloads are
    folded **base-deferred**: sum_k w_k (d_k + b) == sum_k w_k d_k +
    W b, so the fold streams only the compressed delta and the fp64 base
    is read once per round at finalize instead of once per arrival —
    measurably faster single-core and the enabler for both overlap
    modes.  Decode/reduce overlap: on the numpy backend a decoder thread
    (:class:`_DecodePipeline`) decodes arrival k+1 while the caller's
    thread reduces arrival k (auto-enabled on multi-core hosts;
    ``overlap`` forces it); on the Pallas backend the same overlap falls
    out of async dispatch — ``out_padded`` accumulator chaining means
    kernel launches return before the device folds, so the host decodes
    the next arrival while shard kernels run.  On TPU the per-shard
    kernels use fp32 tiles + an fp64 carry (fp64 VPU is emulated); off-
    TPU they stay fp64, the bitwise oracle.

    Numerics: the sharded fold is bitwise-invariant across shard counts
    and overlap on/off (pure elementwise ops in arrival order).  It is
    bitwise-equal to the single-host mode for non-delta payloads, and
    within ~1 ULP of the fp64 accumulator for delta payloads (the
    deferred base changes the summation grouping) — the same order of
    difference the arrival-order fold already carries vs the deferred
    batch kernel, invisible after the fp32 output cast.
    """

    def __init__(self, layout: Layout, backend: Optional[str] = None,
                 block: Optional[int] = None, *,
                 shards: Optional[int] = None, mesh=None,
                 overlap: Optional[bool] = None,
                 tile_dtype: Optional[str] = None):
        self.layout = layout
        self.backend = resolve_backend(backend)
        self._block = block
        # delta-base memo, memo_token(base) -> fp64 materialization.
        # Tokens are process-unique (never recycled, unlike id()), so the
        # memo cannot alias a GC'd base and need not pin the object.
        self._base_memo: Dict[str, np.ndarray] = {}
        self._scratch = np.empty(min(CHUNK, max(layout.total_size, 1)),
                                 np.float64)
        self._tmp = np.empty_like(self._scratch)
        self.total_w = 0.0
        self.count = 0
        self.shards = resolve_shards(shards, mesh)
        self.mesh = mesh
        self._tile_dtype = tile_dtype or (
            "float32" if _on_tpu() else "float64")
        # legacy-mode padded device accumulator (geometry-keyed cache)
        self._acc_padded = None
        self._pad_geom: Optional[Tuple[int, int]] = None
        # deferred delta bases: token -> [base object, summed weight].
        # Sharded dense deltas and sparse deltas (both modes) fold
        # base-deferred: sum_k w_k (d_k + b) == sum_k w_k d_k + W b
        self._deferred: Dict[str, list] = {}
        if self.shards:
            from repro.fl.flat import QCHUNK
            from repro.sharding import shard_bounds

            self._bounds = shard_bounds(layout.total_size, self.shards,
                                        align=QCHUNK)
            self._sacc: List[Optional[np.ndarray]] = [
                np.zeros(hi - lo, np.float64) for lo, hi in self._bounds]
            self._spad: List[Any] = [None] * self.shards
            self._sgeom: List[Optional[Tuple[int, int]]] = \
                [None] * self.shards
            self._devices = (list(mesh.devices.flat)
                             if mesh is not None else None)
            use_pipe = (self.backend == "numpy" and layout.total_size > 0
                        and (overlap if overlap is not None
                             else _host_cores() > 1))
            self._pipe = _DecodePipeline(self._bounds) if use_pipe else None
            self._acc = None
        else:
            self._acc = np.zeros(layout.total_size, np.float64)
            self._pipe = None
        self.overlap = self._pipe is not None

    # ------------------------------------------------------------ shared
    def add(self, fp: FlatParams, w: float) -> None:
        if getattr(fp, "is_sparse", False):
            # 0xF5 structured-sparse delta: O(nnz) scatter fold — routed
            # here so edge pre-reduce and FedBuff call sites fold sparse
            # payloads without knowing about them
            self.add_sparse(fp, w)
            return
        if self.shards:
            self._add_sharded(fp, w)
            self.total_w += float(w)
            self.count += 1
            return
        if self.backend == "pallas" and self.layout.total_size \
                and self._add_pallas(fp, w):
            self.total_w += float(w)
            self.count += 1
            return
        sw = np.float64(w)
        n = self.layout.total_size
        acc = self._acc_vec()
        for lo in range(0, n, CHUNK):
            hi = min(lo + CHUNK, n)
            x = fp.f64_chunk(lo, hi, self._tmp)
            np.multiply(x, sw, out=self._scratch[:hi - lo])
            acc[lo:hi] += self._scratch[:hi - lo]
        self.total_w += float(w)
        self.count += 1

    def add_partial(self, ps, scale: float = 1.0) -> None:
        """Fold a pre-reduced subtree sum (:class:`~repro.fl.flat
        .PartialSum`): ``acc += scale * S_e`` — no per-client weight
        multiply, the edge already applied them.  The edge computed
        ``S_e`` with this class's own chunk arithmetic, so root-folding
        partials continues the flat fold's accumulation exactly (bitwise
        for a single edge on any data; regrouped-sum ULP otherwise).
        ``scale`` (async staleness discount) also multiplies the
        contributed weight: ``total_w += scale * W_e``."""
        sw = np.float64(scale)
        if self.shards:
            if self._pipe is not None:
                # ride the decode pipeline so the (arrival, shard) fold
                # order stays the serial order
                self._pipe.submit(ps.decode_chunk, sw, self._fold_item)
            else:
                for si, (lo, hi) in enumerate(self._bounds):
                    if hi <= lo:
                        continue
                    acc = self._shard_acc(si)
                    for a in range(lo, hi, CHUNK):
                        b = min(a + CHUNK, hi)
                        x = ps.decode_chunk(a, b, self._tmp)
                        np.multiply(x, sw, out=self._scratch[:b - a])
                        acc[a - lo:b - lo] += self._scratch[:b - a]
        else:
            acc = self._acc_vec()
            n = self.layout.total_size
            for lo in range(0, n, CHUNK):
                hi = min(lo + CHUNK, n)
                x = ps.f64_chunk(lo, hi, self._tmp)
                np.multiply(x, sw, out=self._scratch[:hi - lo])
                acc[lo:hi] += self._scratch[:hi - lo]
        self.total_w += float(scale) * float(ps.total_w)
        self.count += int(ps.count)

    def add_sparse(self, sp, w: float) -> None:
        """Fold a structured-sparse delta (0xF5,
        :class:`~repro.fl.flat.SparseDelta`): ``acc[traveled] += w *
        dequant(values)`` — O(nnz) per arrival, never a model-size
        densify.  The round base is **deferred** (recorded at its summed
        weight and applied chunk-streamed at :meth:`finalize` /
        :meth:`raw_sum`), exactly like the sharded dense-delta fold.  On
        the Pallas backend the dequantize+scale chain runs as a jitted
        device graph (``kernels.agg_reduce.scatter_wsum``, bitwise the
        numpy chain); the scatter-add itself stays host-side — unique
        indices, so there is no reduction-order ambiguity."""
        self._record_base(sp, w)
        sw = np.float64(w)
        if self.shards:
            if self._pipe is not None:
                # keep the (arrival, shard) fold order serial: queued
                # dense decodes fold before this sparse arrival
                self._pipe.drain(self._fold_item)
            for si, (lo, hi) in enumerate(self._bounds):
                if hi <= lo:
                    continue
                self._scatter_spans(sp, lo, hi, self._shard_acc(si), sw)
        else:
            self._scatter_spans(sp, 0, self.layout.total_size,
                                self._acc_vec(), sw)
        self.total_w += float(w)
        self.count += 1

    def _scatter_spans(self, sp, lo: int, hi: int, acc: np.ndarray,
                       sw: np.float64) -> None:
        """Scatter ``sp``'s traveled coordinates inside [lo, hi) into
        ``acc`` (indexed relative to ``lo``), sub-chunked to the scratch
        size so a whole-model adapter range never allocates O(range)."""
        use_dev = self.backend == "pallas" and self.layout.total_size
        if use_dev:
            from repro.kernels import agg_reduce
        for p0, p1, dest in sp.iter_spans(lo, hi):
            for q0 in range(p0, p1, CHUNK):
                q1 = min(q0 + CHUNK, p1)
                if isinstance(dest, slice):
                    d = slice(dest.start + (q0 - p0),
                              dest.start + (q1 - p0))
                else:
                    d = dest[q0 - p0:q1 - p0]
                if use_dev:
                    agg_reduce.scatter_wsum(
                        acc, d, sp.values[q0:q1], float(sw),
                        scales=sp.scales, qchunk=sp.qchunk, pos0=q0)
                else:
                    buf = sp.dequant_packed(q0, q1, self._tmp)
                    np.multiply(buf, sw, out=self._scratch[:q1 - q0])
                    acc[d] += self._scratch[:q1 - q0]

    def _apply_deferred(self, acc: np.ndarray, denom: float) -> None:
        """Add every deferred round base at ``summed_weight / denom``,
        chunk-streamed in canonical token order (arrival-order
        invariant; no model-size fp64 base materializes)."""
        if not self._deferred:
            return
        defs = [(self._deferred[tok][0],
                 np.float64(self._deferred[tok][1] / denom))
                for tok in sorted(self._deferred)]
        n = acc.size
        for lo in range(0, n, CHUNK):
            hi = min(lo + CHUNK, n)
            for bobj, bw in defs:
                x = bobj.f64_chunk(lo, hi, self._tmp)
                np.multiply(x, bw, out=self._scratch[:hi - lo])
                acc[lo:hi] += self._scratch[:hi - lo]
        self._deferred.clear()

    def raw_sum(self) -> np.ndarray:
        """The unscaled fp64 accumulator ``sum_i w_i x_i`` — what an edge
        aggregator frames as a 0xF4 partial payload instead of calling
        :meth:`finalize`.  Ends the fold: the returned vector IS the
        accumulator (no copy), so neither :meth:`add` nor
        :meth:`finalize` may be called afterwards.  Single-host mode
        only (edges pre-reduce locally; sharding is root-side state).
        Deferred sparse-delta bases are applied here at their SUMMED
        weight (S_e = sum w·d + W_b·b), so the 0xF4 partial an edge
        frames from sparse arrivals is the true subtree sum."""
        if self.shards:
            raise ValueError(
                "raw_sum() is single-host only: edge pre-reduction keeps "
                "one local accumulator, sharded state is for the root")
        acc = self._acc_vec()
        self._apply_deferred(acc, 1.0)
        return acc

    def finalize(self) -> FlatParams:
        if self.shards:
            return self._finalize_sharded()
        acc = self._acc_vec()
        acc *= np.float64(1.0 / self.total_w)
        self._apply_deferred(acc, self.total_w)
        out = FlatParams.zeros(self.layout)
        _scatter_leaves(acc, self.layout, out)
        return out

    def per_shard_acc_bytes(self) -> int:
        """Largest per-shard fp64 accumulator footprint, in bytes."""
        if not self.shards:
            return self.layout.total_size * 8
        return max((hi - lo for lo, hi in self._bounds), default=0) * 8

    def _geometry(self, src, n: int) -> Tuple[int, int]:
        from repro.kernels import agg_reduce

        qc = src.qchunk if src.kind == "q8" else 1
        blk = self._block or agg_reduce.choose_block(n, qc)
        if src.kind == "q8":
            blk = -(-blk // qc) * qc
        return blk, -(-n // blk) * blk

    # ------------------------------------------------- single-host mode
    def _acc_vec(self) -> np.ndarray:
        """The unpadded single-host accumulator; a live padded device
        accumulator (geometry cache) is materialized and retired first —
        the per-arrival pad+slice fallback for mixed arrivals."""
        if self._acc_padded is not None:
            n = self.layout.total_size
            self._acc = np.array(np.asarray(self._acc_padded)[:n])
            self._acc_padded = None
            self._pad_geom = None
        return self._acc

    def _add_pallas(self, fp, w: float) -> bool:
        ts = getattr(fp, "tile_source", None)
        src = ts() if ts is not None else None
        if src is None:
            return False
        base = None
        if src.base is not None:
            tok = memo_token(src.base)
            base = self._base_memo.get(tok)
            if base is None:
                base = self._base_memo[tok] = src.base.to_f64()
        from repro.kernels import agg_reduce

        geom = self._geometry(src, self.layout.total_size)
        if self._pad_geom is not None and self._pad_geom != geom:
            self._acc_vec()         # mixed arrival: retire, re-pad below
        acc = self._acc_padded if self._pad_geom == geom else self._acc
        out = agg_reduce.weighted_sum(
            src.data[None, :], np.array([w], np.float64),
            scales=None if src.scales is None else src.scales[None, :],
            qchunk=src.qchunk, base=base, acc=acc,
            block=geom[0], interpret=_interpret(), out_padded=True)
        self._acc_padded, self._pad_geom = out, geom
        self._acc = None
        return True

    # ------------------------------------------------------ sharded mode
    @staticmethod
    def _decoder(fp):
        dec = getattr(fp, "decode_chunk", None)
        if dec is None:
            if getattr(fp, "is_delta", False):
                raise TypeError(
                    "sharded fold needs decode_chunk() on delta payloads "
                    f"(got {type(fp).__name__})")
            dec = fp.f64_chunk
        return dec

    def _record_base(self, fp, w: float) -> None:
        if not getattr(fp, "is_delta", False):
            return
        base = getattr(fp, "base", None)
        if base is None:
            raise ValueError(
                "delta-encoded payload needs its round base attached "
                "(QuantParams.base / SparseDelta.base) before it can "
                "be folded")
        tok = memo_token(base)
        ent = self._deferred.get(tok)
        if ent is None:
            self._deferred[tok] = [base, float(w)]
        else:
            ent[1] += float(w)

    def _shard_acc(self, si: int) -> np.ndarray:
        if self._spad[si] is not None:
            lo, hi = self._bounds[si]
            self._sacc[si] = np.array(np.asarray(self._spad[si])[:hi - lo])
            self._spad[si] = None
            self._sgeom[si] = None
        return self._sacc[si]

    def _fold_item(self, si: int, buf: np.ndarray, m: int) -> None:
        self._sacc[si] += buf[:m]

    def _add_sharded(self, fp, w: float) -> None:
        self._record_base(fp, w)
        if self.backend == "pallas" and self.layout.total_size \
                and self._add_sharded_pallas(fp, w):
            return
        dec = self._decoder(fp)
        sw = np.float64(w)
        if self._pipe is not None:
            self._pipe.submit(dec, sw, self._fold_item)
            return
        for si, (lo, hi) in enumerate(self._bounds):
            if hi <= lo:
                continue
            acc = self._shard_acc(si)
            for a in range(lo, hi, CHUNK):
                b = min(a + CHUNK, hi)
                x = dec(a, b, self._tmp)
                np.multiply(x, sw, out=self._scratch[:b - a])
                acc[a - lo:b - lo] += self._scratch[:b - a]

    def _add_sharded_pallas(self, fp, w: float) -> bool:
        ts = getattr(fp, "tile_source", None)
        if ts is None:
            return False
        live = [(si, lo, hi)
                for si, (lo, hi) in enumerate(self._bounds) if hi > lo]
        sources = []
        try:
            for _, lo, hi in live:
                src = ts(lo, hi)
                if src is None:
                    return False
                sources.append(src)
        except TypeError:       # foreign adapter without range support
            return False
        from repro.kernels import agg_reduce

        wts = np.array([w], np.float64)
        for (si, lo, hi), src in zip(live, sources):
            geom = self._geometry(src, hi - lo)
            if self._sgeom[si] is not None and self._sgeom[si] != geom:
                self._shard_acc(si)
            acc = self._spad[si] if self._sgeom[si] == geom \
                else self._sacc[si]
            dev = None
            if self._devices:
                dev = self._devices[si % len(self._devices)]
            if dev is not None:
                import jax

                ctx = jax.default_device(dev)
            else:
                ctx = contextlib.nullcontext()
            with ctx:
                # base deferred to finalize even when attached (base=None)
                out = agg_reduce.weighted_sum(
                    src.data[None, :], wts,
                    scales=None if src.scales is None
                    else src.scales[None, :],
                    qchunk=src.qchunk, base=None, acc=acc,
                    block=geom[0], interpret=_interpret(),
                    out_padded=True, tile_dtype=self._tile_dtype)
            self._spad[si], self._sgeom[si] = out, geom
            self._sacc[si] = None
        return True

    def _finalize_sharded(self) -> FlatParams:
        if self._pipe is not None:
            self._pipe.drain(self._fold_item)
        inv = np.float64(1.0 / self.total_w)
        # canonical token order: the deferred-base add is independent of
        # which client's delta arrived first
        defs = [(self._deferred[tok][0],
                 np.float64(self._deferred[tok][1] / self.total_w))
                for tok in sorted(self._deferred)]
        out = FlatParams.zeros(self.layout)
        n = self.layout.total_size
        uniform = self.layout.uniform_dtype in _FLOATS
        ovec = out.math_view() if uniform else np.empty(n, np.float64)
        # the one all-gather: each shard's acc/W (+ deferred (w_b/W) b,
        # streamed chunk-wise so no model-size fp64 base materializes)
        # lands in the output buffer
        for si, (lo, hi) in enumerate(self._bounds):
            if hi <= lo:
                continue
            a = self._shard_acc(si)
            a *= inv
            for c0 in range(lo, hi, CHUNK):
                c1 = min(c0 + CHUNK, hi)
                seg = a[c0 - lo:c1 - lo]
                for bobj, bw in defs:
                    x = bobj.f64_chunk(c0, c1, self._tmp)
                    np.multiply(x, bw, out=self._scratch[:c1 - c0])
                    seg += self._scratch[:c1 - c0]
                ovec[c0:c1] = seg
        if not uniform:
            _scatter_leaves(ovec, self.layout, out)
        return out


def _rowstack(flats: Sequence[FlatParams], lo: int, hi: int,
              m: np.ndarray) -> np.ndarray:
    tile = m[:len(flats), :hi - lo]
    for i, fp in enumerate(flats):
        fp.f64_chunk(lo, hi, tile[i])
    return tile


def _sorted_reduce_pallas(flats, layout, kind: str, trim_k: int,
                          block: Optional[int]) -> Optional[FlatParams]:
    """Shared Pallas branch of the sort-based reductions; ``None`` means
    "fall back to numpy" (unsupported payload stack)."""
    stack = _tile_stack(flats)
    if stack is None:
        return None
    from repro.kernels import agg_reduce

    vec = agg_reduce.sort_reduce(
        stack["data"], kind=kind, trim_k=trim_k, scales=stack["scales"],
        qchunk=stack["qchunk"], base=stack["base"], block=block,
        interpret=_interpret())
    if kind == "trim_sum":
        # numpy's np.mean = sum of rows, then one true divide — doing the
        # divide host-side keeps the rounding identical
        vec /= len(flats) - 2 * trim_k
    return _vec_to_flat(vec, layout)


def median(flats: Sequence[FlatParams], layout: Layout,
           backend: Optional[str] = None,
           block: Optional[int] = None) -> FlatParams:
    """Coordinate-wise median, chunk-stacked."""
    if layout.total_size and flats \
            and resolve_backend(backend) == "pallas":
        out = _sorted_reduce_pallas(flats, layout, "median", 0, block)
        if out is not None:
            return out
    return _coordinatewise(flats, layout,
                           lambda t: np.median(t, axis=0, overwrite_input=True))


def trimmed_mean(flats: Sequence[FlatParams], layout: Layout,
                 k: int, backend: Optional[str] = None,
                 block: Optional[int] = None) -> FlatParams:
    """Mean after trimming the k smallest/largest values per coordinate."""
    n = len(flats)
    if layout.total_size and flats \
            and resolve_backend(backend) == "pallas":
        k_eff = k if n > 2 * k else 0
        out = _sorted_reduce_pallas(flats, layout, "trim_sum", k_eff, block)
        if out is not None:
            return out

    def reduce(tile: np.ndarray) -> np.ndarray:
        tile.sort(axis=0)
        sl = tile[k:n - k] if n > 2 * k else tile
        return np.mean(sl, axis=0)

    return _coordinatewise(flats, layout, reduce)


def _coordinatewise(flats, layout, reduce_fn) -> FlatParams:
    out = FlatParams.zeros(layout)
    n = layout.total_size
    if n == 0 or not flats:
        return out
    uniform = layout.uniform_dtype in _FLOATS
    ovec = out.math_view() if uniform else np.empty(n, np.float64)
    m = np.empty((len(flats), CHUNK), np.float64)
    for lo in range(0, n, CHUNK):
        hi = min(lo + CHUNK, n)
        ovec[lo:hi] = reduce_fn(_rowstack(flats, lo, hi, m))
    if not uniform:
        _scatter_leaves(ovec, layout, out)
    return out


def krum_distances(flats: Sequence[FlatParams], layout: Layout,
                   backend: Optional[str] = None,
                   block: Optional[int] = None) -> np.ndarray:
    """(n, n) matrix of pairwise squared L2 distances.

    Accumulates the Gram matrix G += X_c X_c^T one (n, CHUNK) fp64 tile at
    a time, then expands ||a-b||^2 = ||a||^2 + ||b||^2 - 2<a,b>.  Each tile
    is centered on its first row before the dgemm — pairwise distances are
    translation-invariant, and removing the large common component (late
    rounds: client updates nearly identical, norms huge) keeps the
    expansion from cancelling catastrophically.  Clamped at zero for the
    residual rounding.
    """
    n_clients = len(flats)
    if layout.total_size and flats \
            and resolve_backend(backend) == "pallas":
        stack = _tile_stack(flats)
        if stack is not None:
            from repro.kernels import agg_reduce

            G = agg_reduce.gram(
                stack["data"], scales=stack["scales"],
                qchunk=stack["qchunk"], base=stack["base"], block=block,
                interpret=_interpret())
            sq = np.diag(G).copy()
            D = sq[:, None] + sq[None, :] - 2.0 * G
            np.maximum(D, 0.0, out=D)
            return D
    G = np.zeros((n_clients, n_clients), np.float64)
    m = np.empty((n_clients, CHUNK), np.float64)
    ref = np.empty(CHUNK, np.float64)
    total = layout.total_size
    for lo in range(0, total, CHUNK):
        hi = min(lo + CHUNK, total)
        tile = _rowstack(flats, lo, hi, m)
        np.copyto(ref[:hi - lo], tile[0])
        tile -= ref[:hi - lo]
        G += tile @ tile.T
    sq = np.diag(G).copy()
    D = sq[:, None] + sq[None, :] - 2.0 * G
    np.maximum(D, 0.0, out=D)
    return D


def krum_scores(D: np.ndarray, num_byzantine: int) -> np.ndarray:
    """Multi-Krum scores: per client, the sum of its n-f-2 smallest
    distances to other clients (Blanchard et al. 2017)."""
    n = D.shape[0]
    f = min(num_byzantine, max(0, (n - 3) // 2))
    D = D.copy()
    np.fill_diagonal(D, np.inf)
    D.sort(axis=1)
    m = max(n - f - 2, 1)
    return D[:, :m].sum(axis=1)


def wrapping_sum_u64(flats: Sequence[FlatParams],
                     layout: Layout) -> np.ndarray:
    """Mod-2^64 sum of uint64 flat buffers (SecAgg mask cancellation)."""
    acc = np.zeros(layout.total_size, np.uint64)
    for fp in flats:
        acc += fp.math_view()
    return acc

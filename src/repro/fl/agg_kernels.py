"""Vectorized aggregation kernels over flat parameter buffers.

Every strategy's per-layer Python loop reduces to one of four kernels over
the (clients x total_params) logical matrix, all cache-blocked on a
``CHUNK``-element window so the float64 accumulator and scratch stay
resident in L2 while the loop streams each client's fp32 view exactly once:

- :func:`weighted_mean` — FedAvg's sum((w_i/W) * x_i).  The per-client
  weight is folded to ``np.float64(w_i / W)`` up front, which both removes
  the final rescale pass and (because the ops and their order match the
  legacy per-layer loop elementwise) keeps the result **bitwise identical**
  to the legacy implementation.
- :class:`StreamingWeightedSum` — the same reduction, but folding each
  client in as it arrives and releasing the payload; peak memory is one
  float64 accumulator instead of every client's update. sum(w_i x_i)/W
  differs from the fold by <=1 ULP of the fp64 accumulator (invisible
  after the fp32 cast).
- :func:`median` / :func:`trimmed_mean` — coordinate-wise robust
  aggregation on a chunk-stacked (n, CHUNK) float64 tile (peak extra
  memory O(n * CHUNK), not O(n * total)).
- :func:`krum_distances` — all pairwise squared L2 distances via a
  chunk-accumulated Gram matrix: ||a-b||^2 = ||a||^2 + ||b||^2 - 2<a,b>,
  one dgemm per chunk instead of the O(n^2) Python loop over full vectors.

Every kernel reads its inputs through the chunked ``f64_chunk(lo, hi,
out)`` protocol, which both :class:`~repro.fl.flat.FlatParams` (raw
buffers) and :class:`~repro.fl.flat.QuantParams` (int8/bf16 compressed
wire payloads) implement.  For quantized inputs the dequantize + scale
(+ delta-base add) is **fused into the per-chunk read**, so accumulators
consume compressed buffers directly — peak extra memory stays one
CHUNK-sized fp64 scratch, never a model-size fp32 copy of the payload.

NB (numpy>=2 / NEP 50): scalar weights MUST be ``np.float64`` — a bare
python float is "weak" and would demote the multiply to the fp32 loop,
silently breaking the exactness guarantee.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.fl.flat import FlatParams, Layout, np_dtype

# 16K elements: chunk fp64 accumulator + scratch = 256 KiB, L2-resident.
# QCHUNK (int8 scale window) divides CHUNK, so quantized reads stay aligned.
CHUNK = 1 << 14

_FLOATS = {"float16", "float32", "float64"}


def weighted_mean(pairs: Sequence[Tuple[FlatParams, float]],
                  layout: Layout) -> FlatParams:
    """sum((w_i / W) x_i) over flat buffers -> FlatParams of ``layout``.

    Chunk-outer / client-inner: the fp64 accumulator chunk is reused across
    clients and cast straight into the output buffer, so no total-size fp64
    array is ever materialized.
    """
    total_w = float(sum(w for _, w in pairs))
    scaled = [np.float64(w / total_w) for _, w in pairs]
    out = FlatParams.zeros(layout)
    n = layout.total_size
    if n == 0 or not pairs:
        return out
    uniform = layout.uniform_dtype in _FLOATS
    ovec = out.math_view() if uniform else np.empty(n, np.float64)
    acc = np.empty(CHUNK, np.float64)
    scratch = np.empty(CHUNK, np.float64)
    tmp = np.empty(CHUNK, np.float64)
    for lo in range(0, n, CHUNK):
        hi = min(lo + CHUNK, n)
        a = acc[:hi - lo]
        x0 = pairs[0][0].f64_chunk(lo, hi, tmp)
        np.multiply(x0, scaled[0], out=a)
        for (fp, _), sw in zip(pairs[1:], scaled[1:]):
            x = fp.f64_chunk(lo, hi, tmp)
            np.multiply(x, sw, out=scratch[:hi - lo])
            a += scratch[:hi - lo]
        ovec[lo:hi] = a
    if not uniform:
        for i, spec in enumerate(layout.leaves):
            out.leaf(i)[...] = ovec[spec.eoffset:spec.eoffset + spec.size] \
                .reshape(spec.shape).astype(np_dtype(spec.dtype))
    return out


class StreamingWeightedSum:
    """Incremental sum(w_i x_i); finalize() divides by W and casts."""

    def __init__(self, layout: Layout):
        self.layout = layout
        self._acc = np.zeros(layout.total_size, np.float64)
        self._scratch = np.empty(min(CHUNK, max(layout.total_size, 1)),
                                 np.float64)
        self._tmp = np.empty_like(self._scratch)
        self.total_w = 0.0
        self.count = 0

    def add(self, fp: FlatParams, w: float) -> None:
        sw = np.float64(w)
        n = self.layout.total_size
        for lo in range(0, n, CHUNK):
            hi = min(lo + CHUNK, n)
            x = fp.f64_chunk(lo, hi, self._tmp)
            np.multiply(x, sw, out=self._scratch[:hi - lo])
            self._acc[lo:hi] += self._scratch[:hi - lo]
        self.total_w += float(w)
        self.count += 1

    def finalize(self) -> FlatParams:
        self._acc *= np.float64(1.0 / self.total_w)
        out = FlatParams.zeros(self.layout)
        for i, spec in enumerate(self.layout.leaves):
            seg = self._acc[spec.eoffset:spec.eoffset + spec.size]
            out.leaf(i)[...] = seg.reshape(spec.shape) \
                .astype(np_dtype(spec.dtype))
        return out


def _rowstack(flats: Sequence[FlatParams], lo: int, hi: int,
              m: np.ndarray) -> np.ndarray:
    tile = m[:len(flats), :hi - lo]
    for i, fp in enumerate(flats):
        fp.f64_chunk(lo, hi, tile[i])
    return tile


def median(flats: Sequence[FlatParams], layout: Layout) -> FlatParams:
    """Coordinate-wise median, chunk-stacked."""
    return _coordinatewise(flats, layout,
                           lambda t: np.median(t, axis=0, overwrite_input=True))


def trimmed_mean(flats: Sequence[FlatParams], layout: Layout,
                 k: int) -> FlatParams:
    """Mean after trimming the k smallest/largest values per coordinate."""
    n = len(flats)

    def reduce(tile: np.ndarray) -> np.ndarray:
        tile.sort(axis=0)
        sl = tile[k:n - k] if n > 2 * k else tile
        return np.mean(sl, axis=0)

    return _coordinatewise(flats, layout, reduce)


def _coordinatewise(flats, layout, reduce_fn) -> FlatParams:
    out = FlatParams.zeros(layout)
    n = layout.total_size
    if n == 0 or not flats:
        return out
    uniform = layout.uniform_dtype in _FLOATS
    ovec = out.math_view() if uniform else np.empty(n, np.float64)
    m = np.empty((len(flats), CHUNK), np.float64)
    for lo in range(0, n, CHUNK):
        hi = min(lo + CHUNK, n)
        ovec[lo:hi] = reduce_fn(_rowstack(flats, lo, hi, m))
    if not uniform:
        for i, spec in enumerate(layout.leaves):
            out.leaf(i)[...] = ovec[spec.eoffset:spec.eoffset + spec.size] \
                .reshape(spec.shape).astype(np_dtype(spec.dtype))
    return out


def krum_distances(flats: Sequence[FlatParams], layout: Layout) -> np.ndarray:
    """(n, n) matrix of pairwise squared L2 distances.

    Accumulates the Gram matrix G += X_c X_c^T one (n, CHUNK) fp64 tile at
    a time, then expands ||a-b||^2 = ||a||^2 + ||b||^2 - 2<a,b>.  Each tile
    is centered on its first row before the dgemm — pairwise distances are
    translation-invariant, and removing the large common component (late
    rounds: client updates nearly identical, norms huge) keeps the
    expansion from cancelling catastrophically.  Clamped at zero for the
    residual rounding.
    """
    n_clients = len(flats)
    G = np.zeros((n_clients, n_clients), np.float64)
    m = np.empty((n_clients, CHUNK), np.float64)
    ref = np.empty(CHUNK, np.float64)
    total = layout.total_size
    for lo in range(0, total, CHUNK):
        hi = min(lo + CHUNK, total)
        tile = _rowstack(flats, lo, hi, m)
        np.copyto(ref[:hi - lo], tile[0])
        tile -= ref[:hi - lo]
        G += tile @ tile.T
    sq = np.diag(G).copy()
    D = sq[:, None] + sq[None, :] - 2.0 * G
    np.maximum(D, 0.0, out=D)
    return D


def krum_scores(D: np.ndarray, num_byzantine: int) -> np.ndarray:
    """Multi-Krum scores: per client, the sum of its n-f-2 smallest
    distances to other clients (Blanchard et al. 2017)."""
    n = D.shape[0]
    f = min(num_byzantine, max(0, (n - 3) // 2))
    D = D.copy()
    np.fill_diagonal(D, np.inf)
    D.sort(axis=1)
    m = max(n - f - 2, 1)
    return D[:, :m].sum(axis=1)


def wrapping_sum_u64(flats: Sequence[FlatParams],
                     layout: Layout) -> np.ndarray:
    """Mod-2^64 sum of uint64 flat buffers (SecAgg mask cancellation)."""
    acc = np.zeros(layout.total_size, np.uint64)
    for fp in flats:
        acc += fp.math_view()
    return acc

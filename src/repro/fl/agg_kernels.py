"""Vectorized aggregation kernels over flat parameter buffers.

Every strategy's per-layer Python loop reduces to one of four kernels over
the (clients x total_params) logical matrix, all cache-blocked on a
``CHUNK``-element window so the float64 accumulator and scratch stay
resident in L2 while the loop streams each client's fp32 view exactly once:

- :func:`weighted_mean` — FedAvg's sum((w_i/W) * x_i).  The per-client
  weight is folded to ``np.float64(w_i / W)`` up front, which both removes
  the final rescale pass and (because the ops and their order match the
  legacy per-layer loop elementwise) keeps the result **bitwise identical**
  to the legacy implementation.
- :class:`StreamingWeightedSum` — the same reduction, but folding each
  client in as it arrives and releasing the payload; peak memory is one
  float64 accumulator instead of every client's update. sum(w_i x_i)/W
  differs from the fold by <=1 ULP of the fp64 accumulator (invisible
  after the fp32 cast).
- :func:`median` / :func:`trimmed_mean` — coordinate-wise robust
  aggregation on a chunk-stacked (n, CHUNK) float64 tile (peak extra
  memory O(n * CHUNK), not O(n * total)).
- :func:`krum_distances` — all pairwise squared L2 distances via a
  chunk-accumulated Gram matrix: ||a-b||^2 = ||a||^2 + ||b||^2 - 2<a,b>,
  one dgemm per chunk instead of the O(n^2) Python loop over full vectors.

Every kernel reads its inputs through the chunked ``f64_chunk(lo, hi,
out)`` protocol, which both :class:`~repro.fl.flat.FlatParams` (raw
buffers) and :class:`~repro.fl.flat.QuantParams` (int8/bf16 compressed
wire payloads) implement.  For quantized inputs the dequantize + scale
(+ delta-base add) is **fused into the per-chunk read**, so accumulators
consume compressed buffers directly — peak extra memory stays one
CHUNK-sized fp64 scratch, never a model-size fp32 copy of the payload.

NB (numpy>=2 / NEP 50): scalar weights MUST be ``np.float64`` — a bare
python float is "weak" and would demote the multiply to the fp32 loop,
silently breaking the exactness guarantee.

Backend dispatch
----------------
Every public kernel takes ``backend="numpy" | "pallas" | None`` (None /
"auto" resolves to :func:`default_backend`: the Pallas path on TPU hosts,
numpy everywhere else — overridable with ``REPRO_AGG_BACKEND`` or
:func:`set_default_backend`).  The contract:

- the numpy path is the reference and the default off-TPU; its arithmetic
  is frozen (the fig. 5 bitwise-repro claim rides on it);
- the Pallas path (:mod:`repro.kernels.agg_reduce`) must agree with it to
  <=1 ULP of the output leaf dtype for every (kernel, codec) pair — it is
  bitwise in practice, and `tests/test_agg_pallas.py` enforces the bound
  across layouts, dtypes, codecs (0xF1/0xF2/0xF3 incl. int8 deltas) and
  client counts.  Krum's Gram matmul reduction order is hardware-defined,
  so its *distances* carry a tight relative tolerance instead while the
  selection and the aggregate stay exact;
- off-TPU the Pallas kernels run in interpret mode, so CI exercises the
  real kernel bodies on CPU;
- payload stacks the Pallas kernels cannot express fall back to numpy
  silently: non-float domains (SecAgg uint64 shares), clients with
  heterogeneous codecs/dtypes in one round, mismatched int8 scale
  windows, or delta payloads with more than one distinct base.  Fallback
  is per-call, so a single odd client never aborts a round.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fl.flat import FlatParams, Layout, np_dtype

# 16K elements: chunk fp64 accumulator + scratch = 256 KiB, L2-resident.
# QCHUNK (int8 scale window) divides CHUNK, so quantized reads stay aligned.
CHUNK = 1 << 14

_FLOATS = {"float16", "float32", "float64"}

# ---------------------------------------------------------------------------
# backend selection
# ---------------------------------------------------------------------------
BACKENDS = ("numpy", "pallas")
_DEFAULT_BACKEND: Optional[str] = None


def default_backend() -> str:
    """Resolved process default: ``REPRO_AGG_BACKEND`` if set, else
    "pallas" when a TPU is attached, else "numpy"."""
    global _DEFAULT_BACKEND
    if _DEFAULT_BACKEND is None:
        env = os.environ.get("REPRO_AGG_BACKEND", "").strip().lower()
        if env:
            if env not in BACKENDS:
                raise ValueError(
                    f"REPRO_AGG_BACKEND={env!r}; expected one of {BACKENDS}")
            _DEFAULT_BACKEND = env
        else:
            _DEFAULT_BACKEND = "pallas" if _on_tpu() else "numpy"
    return _DEFAULT_BACKEND


def set_default_backend(name: Optional[str]) -> None:
    """Override (or with ``None`` re-derive) the process default."""
    global _DEFAULT_BACKEND
    if name is not None and name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; have {BACKENDS}")
    _DEFAULT_BACKEND = name


def _on_tpu() -> bool:
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:  # noqa: BLE001 — no jax, no accelerator
        return False


def resolve_backend(backend: Optional[str]) -> str:
    if backend in (None, "auto"):
        return default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    return backend


def _interpret() -> bool:
    # off-TPU the kernel bodies execute in interpret mode (CPU CI)
    return not _on_tpu()


def _tile_stack(flats: Sequence) -> Optional[Dict[str, Any]]:
    """Stack per-client :class:`~repro.fl.flat.TileSource` adapters into
    the (C, N) host arrays the Pallas kernels consume, or ``None`` when
    the round must fall back to numpy (see module docstring)."""
    sources = []
    for fp in flats:
        ts = getattr(fp, "tile_source", None)
        src = ts() if ts is not None else None
        if src is None:
            return None
        sources.append(src)
    first = sources[0]
    if any(s.kind != first.kind for s in sources):
        return None
    bases = {id(s.base): s.base for s in sources}
    if len(bases) > 1:
        return None
    base_obj = next(iter(bases.values()))
    base = base_obj.to_f64() if base_obj is not None else None
    if first.kind == "q8":
        if any(s.qchunk != first.qchunk for s in sources):
            return None
        return {"data": np.stack([s.data for s in sources]),
                "scales": np.stack([s.scales for s in sources]),
                "qchunk": first.qchunk, "base": base}
    if any(s.data.dtype != first.data.dtype for s in sources):
        return None
    return {"data": np.stack([s.data for s in sources]), "scales": None,
            "qchunk": 1, "base": base}


def _scatter_leaves(vec: np.ndarray, layout: Layout,
                    out: FlatParams) -> None:
    """Write a full math vector into ``out`` leaf by leaf, casting to each
    leaf's dtype — the one shared rounding path for every kernel's
    non-uniform (or vector-producing) output."""
    for i, spec in enumerate(layout.leaves):
        out.leaf(i)[...] = vec[spec.eoffset:spec.eoffset + spec.size] \
            .reshape(spec.shape).astype(np_dtype(spec.dtype))


def _vec_to_flat(vec: np.ndarray, layout: Layout) -> FlatParams:
    """fp64 math vector -> FlatParams, with the same per-element rounding
    the numpy kernels apply when writing their output chunks."""
    out = FlatParams.zeros(layout)
    if layout.uniform_dtype in _FLOATS:
        out.math_view()[...] = vec
    else:
        _scatter_leaves(vec, layout, out)
    return out


def weighted_mean(pairs: Sequence[Tuple[FlatParams, float]],
                  layout: Layout, backend: Optional[str] = None,
                  block: Optional[int] = None) -> FlatParams:
    """sum((w_i / W) x_i) over flat buffers -> FlatParams of ``layout``.

    Chunk-outer / client-inner: the fp64 accumulator chunk is reused across
    clients and cast straight into the output buffer, so no total-size fp64
    array is ever materialized.
    """
    total_w = float(sum(w for _, w in pairs))
    scaled = [np.float64(w / total_w) for _, w in pairs]
    out = FlatParams.zeros(layout)
    n = layout.total_size
    if n == 0 or not pairs:
        return out
    if resolve_backend(backend) == "pallas":
        stack = _tile_stack([fp for fp, _ in pairs])
        if stack is not None:
            from repro.kernels import agg_reduce

            vec = agg_reduce.weighted_sum(
                stack["data"], np.array(scaled, np.float64),
                scales=stack["scales"], qchunk=stack["qchunk"],
                base=stack["base"], block=block, interpret=_interpret())
            return _vec_to_flat(vec, layout)
    uniform = layout.uniform_dtype in _FLOATS
    ovec = out.math_view() if uniform else np.empty(n, np.float64)
    acc = np.empty(CHUNK, np.float64)
    scratch = np.empty(CHUNK, np.float64)
    tmp = np.empty(CHUNK, np.float64)
    for lo in range(0, n, CHUNK):
        hi = min(lo + CHUNK, n)
        a = acc[:hi - lo]
        x0 = pairs[0][0].f64_chunk(lo, hi, tmp)
        np.multiply(x0, scaled[0], out=a)
        for (fp, _), sw in zip(pairs[1:], scaled[1:]):
            x = fp.f64_chunk(lo, hi, tmp)
            np.multiply(x, sw, out=scratch[:hi - lo])
            a += scratch[:hi - lo]
        ovec[lo:hi] = a
    if not uniform:
        _scatter_leaves(ovec, layout, out)
    return out


class StreamingWeightedSum:
    """Incremental sum(w_i x_i); finalize() divides by W and casts.

    On the Pallas backend each arriving payload folds in through one
    fused dequantize+scale+accumulate kernel launch, so device reduction
    overlaps the stragglers' compute (the numpy fold is the bitwise
    reference and the fallback for payloads the kernels cannot express —
    a mixed round may fold through both, which is still exact because the
    per-arrival arithmetic is identical).  The accumulator stays
    *unpadded* between arrivals: block geometry depends on each payload's
    codec (qchunk alignment), so a persistent padded accumulator would
    only be valid for codec-homogeneous rounds — the per-arrival
    pad+slice is the price of accepting mixed arrivals."""

    def __init__(self, layout: Layout, backend: Optional[str] = None,
                 block: Optional[int] = None):
        self.layout = layout
        self.backend = resolve_backend(backend)
        self._block = block
        # id(base) -> (base object, its fp64 materialization)
        self._base_memo: Dict[int, Tuple[Any, np.ndarray]] = {}
        self._acc = np.zeros(layout.total_size, np.float64)
        self._scratch = np.empty(min(CHUNK, max(layout.total_size, 1)),
                                 np.float64)
        self._tmp = np.empty_like(self._scratch)
        self.total_w = 0.0
        self.count = 0

    def add(self, fp: FlatParams, w: float) -> None:
        if self.backend == "pallas" and self.layout.total_size \
                and self._add_pallas(fp, w):
            self.total_w += float(w)
            self.count += 1
            return
        sw = np.float64(w)
        n = self.layout.total_size
        for lo in range(0, n, CHUNK):
            hi = min(lo + CHUNK, n)
            x = fp.f64_chunk(lo, hi, self._tmp)
            np.multiply(x, sw, out=self._scratch[:hi - lo])
            self._acc[lo:hi] += self._scratch[:hi - lo]
        self.total_w += float(w)
        self.count += 1

    def _add_pallas(self, fp, w: float) -> bool:
        ts = getattr(fp, "tile_source", None)
        src = ts() if ts is not None else None
        if src is None:
            return False
        base = None
        if src.base is not None:
            # the memo entry keeps the base OBJECT alive: a bare id() key
            # could be reused by a different base after gc
            hit = self._base_memo.get(id(src.base))
            if hit is not None and hit[0] is src.base:
                base = hit[1]
            else:
                base = src.base.to_f64()
                self._base_memo[id(src.base)] = (src.base, base)
        from repro.kernels import agg_reduce

        self._acc = agg_reduce.weighted_sum(
            src.data[None, :], np.array([w], np.float64),
            scales=None if src.scales is None else src.scales[None, :],
            qchunk=src.qchunk, base=base, acc=self._acc,
            block=self._block, interpret=_interpret())
        return True

    def finalize(self) -> FlatParams:
        self._acc *= np.float64(1.0 / self.total_w)
        out = FlatParams.zeros(self.layout)
        _scatter_leaves(self._acc, self.layout, out)
        return out


def _rowstack(flats: Sequence[FlatParams], lo: int, hi: int,
              m: np.ndarray) -> np.ndarray:
    tile = m[:len(flats), :hi - lo]
    for i, fp in enumerate(flats):
        fp.f64_chunk(lo, hi, tile[i])
    return tile


def _sorted_reduce_pallas(flats, layout, kind: str, trim_k: int,
                          block: Optional[int]) -> Optional[FlatParams]:
    """Shared Pallas branch of the sort-based reductions; ``None`` means
    "fall back to numpy" (unsupported payload stack)."""
    stack = _tile_stack(flats)
    if stack is None:
        return None
    from repro.kernels import agg_reduce

    vec = agg_reduce.sort_reduce(
        stack["data"], kind=kind, trim_k=trim_k, scales=stack["scales"],
        qchunk=stack["qchunk"], base=stack["base"], block=block,
        interpret=_interpret())
    if kind == "trim_sum":
        # numpy's np.mean = sum of rows, then one true divide — doing the
        # divide host-side keeps the rounding identical
        vec /= len(flats) - 2 * trim_k
    return _vec_to_flat(vec, layout)


def median(flats: Sequence[FlatParams], layout: Layout,
           backend: Optional[str] = None,
           block: Optional[int] = None) -> FlatParams:
    """Coordinate-wise median, chunk-stacked."""
    if layout.total_size and flats \
            and resolve_backend(backend) == "pallas":
        out = _sorted_reduce_pallas(flats, layout, "median", 0, block)
        if out is not None:
            return out
    return _coordinatewise(flats, layout,
                           lambda t: np.median(t, axis=0, overwrite_input=True))


def trimmed_mean(flats: Sequence[FlatParams], layout: Layout,
                 k: int, backend: Optional[str] = None,
                 block: Optional[int] = None) -> FlatParams:
    """Mean after trimming the k smallest/largest values per coordinate."""
    n = len(flats)
    if layout.total_size and flats \
            and resolve_backend(backend) == "pallas":
        k_eff = k if n > 2 * k else 0
        out = _sorted_reduce_pallas(flats, layout, "trim_sum", k_eff, block)
        if out is not None:
            return out

    def reduce(tile: np.ndarray) -> np.ndarray:
        tile.sort(axis=0)
        sl = tile[k:n - k] if n > 2 * k else tile
        return np.mean(sl, axis=0)

    return _coordinatewise(flats, layout, reduce)


def _coordinatewise(flats, layout, reduce_fn) -> FlatParams:
    out = FlatParams.zeros(layout)
    n = layout.total_size
    if n == 0 or not flats:
        return out
    uniform = layout.uniform_dtype in _FLOATS
    ovec = out.math_view() if uniform else np.empty(n, np.float64)
    m = np.empty((len(flats), CHUNK), np.float64)
    for lo in range(0, n, CHUNK):
        hi = min(lo + CHUNK, n)
        ovec[lo:hi] = reduce_fn(_rowstack(flats, lo, hi, m))
    if not uniform:
        _scatter_leaves(ovec, layout, out)
    return out


def krum_distances(flats: Sequence[FlatParams], layout: Layout,
                   backend: Optional[str] = None,
                   block: Optional[int] = None) -> np.ndarray:
    """(n, n) matrix of pairwise squared L2 distances.

    Accumulates the Gram matrix G += X_c X_c^T one (n, CHUNK) fp64 tile at
    a time, then expands ||a-b||^2 = ||a||^2 + ||b||^2 - 2<a,b>.  Each tile
    is centered on its first row before the dgemm — pairwise distances are
    translation-invariant, and removing the large common component (late
    rounds: client updates nearly identical, norms huge) keeps the
    expansion from cancelling catastrophically.  Clamped at zero for the
    residual rounding.
    """
    n_clients = len(flats)
    if layout.total_size and flats \
            and resolve_backend(backend) == "pallas":
        stack = _tile_stack(flats)
        if stack is not None:
            from repro.kernels import agg_reduce

            G = agg_reduce.gram(
                stack["data"], scales=stack["scales"],
                qchunk=stack["qchunk"], base=stack["base"], block=block,
                interpret=_interpret())
            sq = np.diag(G).copy()
            D = sq[:, None] + sq[None, :] - 2.0 * G
            np.maximum(D, 0.0, out=D)
            return D
    G = np.zeros((n_clients, n_clients), np.float64)
    m = np.empty((n_clients, CHUNK), np.float64)
    ref = np.empty(CHUNK, np.float64)
    total = layout.total_size
    for lo in range(0, total, CHUNK):
        hi = min(lo + CHUNK, total)
        tile = _rowstack(flats, lo, hi, m)
        np.copyto(ref[:hi - lo], tile[0])
        tile -= ref[:hi - lo]
        G += tile @ tile.T
    sq = np.diag(G).copy()
    D = sq[:, None] + sq[None, :] - 2.0 * G
    np.maximum(D, 0.0, out=D)
    return D


def krum_scores(D: np.ndarray, num_byzantine: int) -> np.ndarray:
    """Multi-Krum scores: per client, the sum of its n-f-2 smallest
    distances to other clients (Blanchard et al. 2017)."""
    n = D.shape[0]
    f = min(num_byzantine, max(0, (n - 3) // 2))
    D = D.copy()
    np.fill_diagonal(D, np.inf)
    D.sort(axis=1)
    m = max(n - f - 2, 1)
    return D[:, :m].sum(axis=1)


def wrapping_sum_u64(flats: Sequence[FlatParams],
                     layout: Layout) -> np.ndarray:
    """Mod-2^64 sum of uint64 flat buffers (SecAgg mask cancellation)."""
    acc = np.zeros(layout.total_size, np.uint64)
    for fp in flats:
        acc += fp.math_view()
    return acc

"""Flat-buffer parameter representation — the aggregation hot path.

A model's parameters cross every FL hop as ``List[np.ndarray]``; treating
them leaf-by-leaf makes each round O(clients x layers) in Python overhead
and copies the payload several times per hop.  :class:`FlatParams` instead
carries **one contiguous byte buffer** plus a :class:`Layout` (dtypes,
shapes, offsets).  Properties:

- pytree/NDArrays <-> flat conversion is a single ``concatenate`` (or free,
  when the arrays already view one buffer, e.g. straight off the wire);
- per-leaf access is a zero-copy ``view``/``reshape`` into the buffer;
- layouts are interned in a cache, so repeated rounds of the same model
  reuse one Layout object and comparisons are pointer comparisons;
- the math view (one fp64/native vector over all leaves) is what the
  vectorized strategy kernels in :mod:`repro.fl.agg_kernels` consume.

The byte buffer preserves leaves bitwise, so the Fig. 5 exactness guarantee
(native vs in-FLARE bit-identical) survives the representation change.

:class:`QuantParams` is the **compressed** sibling (wire codecs ``0xF2``
bf16 / ``0xF3`` int8 + per-chunk fp32 scales, see
:mod:`repro.fl.messages`): a zero-copy view of the quantized payload that
implements the same chunked-read protocol (``layout`` / :meth:`f64_chunk` /
``nbytes``) as FlatParams, so the aggregation kernels consume compressed
buffers directly — dequantize + scale (+ delta-base add) fused into the
per-chunk accumulate, never materializing a model-size fp32 copy.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NDArrays = List[np.ndarray]

# ---------------------------------------------------------------------------
# wire version-byte registry — the single source of truth for 0xF0-0xFF
# ---------------------------------------------------------------------------
# Legacy msgpack frames always start with a container marker, never a byte
# in the reserved range, so one leading byte disambiguates every codec.
# All other modules must import these names; a raw hex literal in the
# range anywhere else is a `codec-literal` finding (repro.analysis) —
# that is how two files would silently claim the same byte.
WIRE_MAGIC_LO = 0xF0
WIRE_MAGIC_HI = 0xFF
WIRE_MAGICS: Dict[str, int] = {
    "flat": 0xF1,          # raw little-endian fp payload (lossless)
    "bf16": 0xF2,          # bfloat16 payload
    "q8": 0xF3,            # int8 + per-chunk fp32 scales
    "partial": 0xF4,       # edge-aggregator partial sum (fp64 Σw·x + W)
    "sparse": 0xF5,        # structured-sparse delta (index + value streams)
    "metric_batch": 0xFB,  # runtime/streaming.py metric event batches
}
#: the subset that frames *model payloads*: a decoder dispatching on
#: these must cover all of them or raise UnsupportedCodec on the rest
PAYLOAD_CODEC_MAGICS = ("flat", "bf16", "q8", "partial", "sparse")

# process-unique memo-token counter (see memo_token)
_MEMO_COUNTER = itertools.count(1)


def memo_token(obj) -> str:
    """Stable identity token for payload memoization (delta-base caches).

    ``id()`` is only unique among *live* objects: a GC'd round base can
    recycle its id mid-round and alias a stale fp64 materialization in a
    long-lived memo.  The token instead combines a process-unique counter
    (assigned lazily, stored on the object) with the layout fingerprint,
    so it is never reused — a memo keyed by it cannot alias and need not
    keep the object alive.  Objects without the ``_memo_token`` slot get
    a fresh token per call (memo never hits: always correct, just
    uncached).
    """
    tok = getattr(obj, "_memo_token", None)
    if tok is None:
        lo = getattr(obj, "layout", None)
        fp = f"{lo.total_bytes}x{lo.total_size}" if lo is not None else "?"
        tok = f"{next(_MEMO_COUNTER)}:{fp}"
        try:
            obj._memo_token = tok
        except AttributeError:
            pass
    return tok


def np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including ml_dtypes extensions (bf16/fp8)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax dependency; provides bfloat16 et al.

        return np.dtype(getattr(ml_dtypes, name))


@dataclass(frozen=True)
class LeafSpec:
    dtype: str                  # dtype name ("float32", "bfloat16", ...)
    shape: Tuple[int, ...]
    offset: int                 # byte offset into the flat buffer
    nbytes: int
    eoffset: int                # element offset into the math vector
    size: int                   # number of elements


@dataclass(frozen=True)
class Layout:
    leaves: Tuple[LeafSpec, ...]
    total_bytes: int
    total_size: int             # total element count
    uniform_dtype: Optional[str]  # set when every leaf shares one dtype

    @property
    def signature(self) -> Tuple[Tuple[str, Tuple[int, ...]], ...]:
        return tuple((l.dtype, l.shape) for l in self.leaves)


_LAYOUT_CACHE: Dict[Tuple[Tuple[str, Tuple[int, ...]], ...], Layout] = {}


def layout_for(signature: Sequence[Tuple[str, Tuple[int, ...]]]) -> Layout:
    """Intern a Layout for a (dtype, shape) signature."""
    key = tuple((str(d), tuple(int(x) for x in s)) for d, s in signature)
    cached = _LAYOUT_CACHE.get(key)
    if cached is not None:
        return cached
    leaves = []
    off = eoff = 0
    for dname, shape in key:
        dt = np_dtype(dname)
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = size * dt.itemsize
        leaves.append(LeafSpec(dname, shape, off, nbytes, eoff, size))
        off += nbytes
        eoff += size
    dtypes = {l.dtype for l in leaves}
    layout = Layout(tuple(leaves), off, eoff,
                    dtypes.pop() if len(dtypes) == 1 else None)
    _LAYOUT_CACHE[key] = layout
    return layout


def layout_of(arrays: NDArrays) -> Layout:
    return layout_for([(a.dtype.name, a.shape) for a in arrays])


class FlatParams:
    """One contiguous uint8 buffer + a Layout describing the leaves."""

    __slots__ = ("buf", "layout", "_memo_token")

    def __init__(self, buf: np.ndarray, layout: Layout):
        assert buf.dtype == np.uint8 and buf.ndim == 1
        assert buf.nbytes == layout.total_bytes, (buf.nbytes, layout)
        self.buf = buf
        self.layout = layout
        self._memo_token: Optional[str] = None

    # ------------------------------------------------------------ builders
    @classmethod
    def from_arrays(cls, arrays: NDArrays,
                    layout: Optional[Layout] = None) -> "FlatParams":
        """Pack leaves into one contiguous buffer (a single copy).

        Messages decoded from the flat wire format never come through here —
        their FlatParams wraps the received payload zero-copy (see
        ``messages.decode_fit_res``); this is the entry point for freshly
        produced client/strategy arrays.
        """
        layout = layout or layout_of(arrays)
        buf = np.empty(layout.total_bytes, np.uint8)
        for spec, a in zip(layout.leaves, arrays):
            seg = buf[spec.offset:spec.offset + spec.nbytes]
            seg.view(np_dtype(spec.dtype))[...] = \
                np.ascontiguousarray(a).reshape(-1)
        return cls(buf, layout)

    @classmethod
    def from_buffer(cls, data, layout: Layout, offset: int = 0
                    ) -> "FlatParams":
        """Zero-copy wrap of ``data`` (bytes/memoryview/ndarray).

        The view is frozen: it borrows the transport buffer, and every
        downstream reader (tile_source tiles, delta-base chunk caches)
        aliases it.  bytes-backed views are born read-only anyway;
        bytearray/memoryview-backed receive buffers are not.
        """
        buf = np.frombuffer(data, np.uint8, count=layout.total_bytes,
                            offset=offset)
        buf.flags.writeable = False
        return cls(buf, layout)

    @classmethod
    def zeros(cls, layout: Layout) -> "FlatParams":
        return cls(np.zeros(layout.total_bytes, np.uint8), layout)

    # ------------------------------------------------------------- views
    def leaf(self, i: int) -> np.ndarray:
        spec = self.layout.leaves[i]
        seg = self.buf[spec.offset:spec.offset + spec.nbytes]
        return seg.view(np_dtype(spec.dtype)).reshape(spec.shape)

    def to_arrays(self) -> NDArrays:
        """Zero-copy per-leaf views (read-only iff the buffer is)."""
        return [self.leaf(i) for i in range(len(self.layout.leaves))]

    def math_view(self) -> np.ndarray:
        """The whole buffer as one 1-D vector of the uniform dtype.

        Zero-copy; only valid for uniform-dtype layouts (the common case —
        fp32 models, or uint64 SecAgg shares).
        """
        u = self.layout.uniform_dtype
        if u is None:
            raise ValueError("math_view() needs a uniform-dtype layout")
        return self.buf.view(np_dtype(u))

    def to_f64(self, out: Optional[np.ndarray] = None) -> np.ndarray:
        """All leaves as one float64 vector (one pass; ``out`` reusable)."""
        lo = self.layout
        if out is None:
            out = np.empty(lo.total_size, np.float64)
        if lo.uniform_dtype is not None:
            np.copyto(out, self.math_view(), casting="unsafe")
        else:
            for i, spec in enumerate(lo.leaves):
                np.copyto(out[spec.eoffset:spec.eoffset + spec.size],
                          self.leaf(i).reshape(-1), casting="unsafe")
        return out

    def f64_chunk(self, lo: int, hi: int, out: np.ndarray) -> np.ndarray:
        """Elements [lo, hi) as float64, written into ``out[:hi-lo]``.

        The chunked-read protocol the aggregation kernels stream through;
        :class:`QuantParams` implements the same method with the dequantize
        fused in, so kernels are agnostic to the wire encoding.
        """
        o = out[:hi - lo]
        layout = self.layout
        if layout.uniform_dtype is not None:
            np.copyto(o, self.math_view()[lo:hi], casting="unsafe")
            return o
        for i, spec in enumerate(layout.leaves):  # mixed dtypes: per-segment
            s, e = spec.eoffset, spec.eoffset + spec.size
            if e <= lo or s >= hi:
                continue
            a, b = max(s, lo), min(e, hi)
            np.copyto(o[a - lo:b - lo], self.leaf(i).reshape(-1)[a - s:b - s],
                      casting="unsafe")
        return o

    # raw buffers carry no delta encoding: the codec decode IS f64_chunk
    # (shared protocol with QuantParams.decode_chunk, which strips the
    # delta-base add — see the sharded deferred-base fold)
    def decode_chunk(self, lo: int, hi: int, out: np.ndarray) -> np.ndarray:
        return self.f64_chunk(lo, hi, out)

    def nbytes(self) -> int:
        return self.layout.total_bytes

    def tile_source(self, lo: int = 0,
                    hi: Optional[int] = None) -> Optional["TileSource"]:
        """Adapter for the Pallas aggregation backend; ``None`` when this
        payload must stay on the numpy kernels (integer domains, e.g.
        SecAgg's uint64 shares).

        ``(lo, hi)`` selects an element range — the shard-aware slicing
        the mesh-sharded accumulator uses to hand each shard's column
        range to its own kernel launch (zero-copy for uniform layouts).
        """
        if hi is None:
            hi = self.layout.total_size
        u = self.layout.uniform_dtype
        if u is None:
            # mixed dtypes: one fp64 materialization of the range — the
            # same values f64_chunk streams, so the fused kernels stay
            # bitwise
            if lo == 0 and hi == self.layout.total_size:
                return TileSource("float", self.to_f64())
            return TileSource(
                "float", self.f64_chunk(lo, hi, np.empty(hi - lo)))
        if u in ("float16", "float32", "float64", "bfloat16"):
            return TileSource("float", self.math_view()[lo:hi])
        return None


@dataclass
class TileSource:
    """Chunk -> tile adapter: the raw typed arrays a payload contributes
    to a stacked (clients, N) device tile (see
    :mod:`repro.kernels.agg_reduce`).

    ``kind="float"``: ``data`` is the (N,) fp16/fp32/fp64/bf16 vector
    (zero-copy for uniform layouts; mixed-dtype layouts materialize one
    fp64 vector — exactly the values ``f64_chunk`` would stream).
    ``kind="q8"``: ``data`` is the (N,) int8 payload and ``scales`` the
    per-``qchunk`` fp32 scales.  ``base`` carries the *object* (FlatParams
    or QuantParams) a delta payload reconstructs against; the dispatch
    layer materializes it to fp64 once per distinct base, not per client.
    """

    kind: str                            # "float" | "q8"
    data: np.ndarray
    scales: Optional[np.ndarray] = None
    qchunk: int = 1024
    base: Optional[object] = None


def unflatten_vector(vec: np.ndarray, layout: Layout) -> NDArrays:
    """Split a math vector back into leaves, cast to each leaf's dtype."""
    out = []
    for spec in layout.leaves:
        seg = vec[spec.eoffset:spec.eoffset + spec.size]
        out.append(seg.reshape(spec.shape).astype(np_dtype(spec.dtype)))
    return out


# ---------------------------------------------------------------------------
# quantized payloads (wire codecs 0xF2 bf16 / 0xF3 int8 + per-chunk scales)
# ---------------------------------------------------------------------------
QCHUNK = 1024        # elements per int8 scale chunk (fp32 scale each)
_QBLOCK = 1 << 20    # elements per quantize/dequantize pass (QCHUNK-aligned)


def quantizable(layout: Layout) -> bool:
    """Lossy codecs only apply to uniform-fp32 models; anything else
    (mixed dtypes, SecAgg's uint64 shares, integer leaves) must travel
    losslessly and falls back to the raw 0xF1 flat frame."""
    return layout.uniform_dtype == "float32" and layout.total_size > 0


def quantize_int8(vec: np.ndarray, qchunk: int = QCHUNK
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-chunk int8 quantization of a fp32 vector.

    Each ``qchunk``-element window gets scale ``max|x| / 127`` (1.0 for
    all-zero windows), so dequantization error is bounded per coordinate:
    ``|x - scale * q| <= scale / 2``.  Returns ``(q int8, scales fp32)``.
    """
    n = int(vec.size)
    nchunks = -(-n // qchunk)
    scales = np.empty(nchunks, np.float32)
    q = np.empty(n, np.int8)
    block = max(_QBLOCK // qchunk, 1) * qchunk
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        x = np.asarray(vec[lo:hi], np.float32)
        nfull = (hi - lo) // qchunk * qchunk
        amax = (np.abs(x[:nfull]).reshape(-1, qchunk).max(axis=1)
                if nfull else np.empty(0, np.float32))
        if nfull < hi - lo:                       # ragged tail chunk
            amax = np.append(amax, np.abs(x[nfull:]).max())
        s = (amax / np.float32(127.0)).astype(np.float32)
        s[s == 0] = np.float32(1.0)
        c0 = lo // qchunk
        scales[c0:c0 + s.size] = s
        if nfull:       # broadcast one scale per (nchunks, qchunk) row
            xs = x[:nfull].reshape(-1, qchunk) / s[:nfull // qchunk, None]
            q[lo:lo + nfull] = np.clip(np.rint(xs), -127, 127) \
                .astype(np.int8).reshape(-1)
        if nfull < hi - lo:
            xt = x[nfull:] / s[-1]
            q[lo + nfull:hi] = np.clip(np.rint(xt), -127, 127) \
                .astype(np.int8)
    return q, scales


def _dequant_q8(data: np.ndarray, scales: np.ndarray, qchunk: int,
                lo: int, hi: int, out: np.ndarray) -> np.ndarray:
    """Fused int8 -> f64 dequantize of elements [lo, hi) into ``out``.

    Rounds through fp32 (``int8 * fp32-scale`` is exact in f64, then one
    fp32 rounding) so the server-side reconstruction is **bitwise equal**
    to the fp32 arrays a client materializes from the same bytes.
    """
    o = out[:hi - lo]
    np.copyto(o, data[lo:hi], casting="unsafe")
    if lo % qchunk == 0:
        # aligned fast path (kernel CHUNK is a multiple of QCHUNK):
        # broadcast one scale per row of the (nchunks, qchunk) view
        nfull = (hi - lo) // qchunk * qchunk
        c0 = lo // qchunk
        if nfull:
            o[:nfull].reshape(-1, qchunk)[...] *= \
                scales[c0:c0 + nfull // qchunk].astype(np.float64)[:, None]
        if nfull < hi - lo:                       # ragged tail chunk
            o[nfull:] *= np.float64(scales[c0 + nfull // qchunk])
    else:
        c0, c1 = lo // qchunk, -(-hi // qchunk)
        sv = np.repeat(scales[c0:c1].astype(np.float64), qchunk)
        o *= sv[lo - c0 * qchunk:lo - c0 * qchunk + (hi - lo)]
    o[...] = o.astype(np.float32)
    return o


def dequantize_int8(data: np.ndarray, scales: np.ndarray,
                    qchunk: int = QCHUNK,
                    out: Optional[np.ndarray] = None) -> np.ndarray:
    """int8 + per-chunk scales -> fp64 vector (the `_dequant_q8` chain:
    rounds through fp32 once, bitwise the client-side reconstruction).
    Public entry point for consumers of the PR 3 quant layout outside the
    wire path — e.g. the int8-quantized FedOpt server moments."""
    n = int(data.size)
    if out is None:
        out = np.empty(n, np.float64)
    if n:
        _dequant_q8(data, scales, qchunk, 0, n, out)
    return out[:n]


class QuantParams:
    """Zero-copy view of a quantized wire payload.

    Carries the *logical* fp32 :class:`Layout` plus the compressed data as
    ``np.frombuffer`` views into the received message:

    - ``mode="bf16"``: ``data`` is a bf16 vector (lossless to decode);
    - ``mode="q8"``: ``data`` is int8 and ``scales`` holds one fp32 scale
      per ``qchunk`` elements.

    ``is_delta`` marks a payload encoded as (result - round-start params);
    the server attaches ``base`` (the round's downlink params, FlatParams
    or QuantParams) before handing it to the kernels, which then read
    ``base + dequant(delta)`` through the same fused :meth:`f64_chunk`.
    """

    __slots__ = ("layout", "mode", "data", "scales", "qchunk", "is_delta",
                 "base", "_chunk_cache", "_memo_token")

    def __init__(self, layout: Layout, mode: str, data: np.ndarray,
                 scales: Optional[np.ndarray] = None, qchunk: int = QCHUNK,
                 is_delta: bool = False, base=None):
        assert mode in ("bf16", "q8"), mode
        self.layout = layout
        self.mode = mode
        self.data = data
        self.scales = scales
        self.qchunk = qchunk
        self.is_delta = is_delta
        self.base = base
        # last dequantized chunk, memoized when *this* object serves as a
        # shared delta base.  Helps the deferred kernels (weighted_mean /
        # _rowstack), which stream chunk-outer/client-inner so every
        # client re-reads the same base chunk back to back; the
        # low_memory streaming path folds client-outer and misses — it
        # trades that redundant dequant for O(1)-model-size peak memory.
        self._chunk_cache = None
        self._memo_token: Optional[str] = None

    # ------------------------------------------------------------- protocol
    def decode_chunk(self, lo: int, hi: int, out: np.ndarray) -> np.ndarray:
        """Codec decode of elements [lo, hi) into ``out`` — WITHOUT the
        delta-base add.  The sharded streaming fold reads deltas through
        this and defers the base to finalize (sum_k w_k (d_k + b) ==
        sum_k w_k d_k + W b), so the fp64 base is read once per round,
        not once per arrival."""
        o = out[:hi - lo]
        if self.mode == "bf16":
            np.copyto(o, self.data[lo:hi], casting="unsafe")
        else:
            _dequant_q8(self.data, self.scales, self.qchunk, lo, hi, o)
        return o

    def f64_chunk(self, lo: int, hi: int, out: np.ndarray) -> np.ndarray:
        """Fused dequantize(+base-add) of elements [lo, hi) into ``out``."""
        o = self.decode_chunk(lo, hi, out)
        if self.is_delta:
            base = self.base
            if base is None:
                raise ValueError(
                    "delta-encoded payload needs its round base attached "
                    "(QuantParams.base) before it can be read")
            arr = None
            if isinstance(base, QuantParams):
                c = base._chunk_cache
                if c is not None and c[0] == lo and c[1] == hi:
                    arr = c[2]
            if arr is None:
                arr = base.f64_chunk(lo, hi, np.empty(hi - lo, np.float64))
                if isinstance(base, QuantParams):
                    base._chunk_cache = (lo, hi, arr)
            o += arr        # arr is read-only by contract: never mutated
        return o

    def to_f64(self, out: Optional[np.ndarray] = None) -> np.ndarray:
        n = self.layout.total_size
        if out is None:
            out = np.empty(n, np.float64)
        for lo in range(0, n, _QBLOCK):
            hi = min(lo + _QBLOCK, n)
            self.f64_chunk(lo, hi, out[lo:hi])
        return out

    def to_flat(self) -> FlatParams:
        """Materialize the logical fp32 FlatParams (one fresh buffer)."""
        out = FlatParams.zeros(self.layout)
        mv = out.math_view()
        tmp = np.empty(min(_QBLOCK, max(self.layout.total_size, 1)),
                       np.float64)
        n = self.layout.total_size
        for lo in range(0, n, _QBLOCK):
            hi = min(lo + _QBLOCK, n)
            mv[lo:hi] = self.f64_chunk(lo, hi, tmp)
        return out

    def to_arrays(self) -> NDArrays:
        return self.to_flat().to_arrays()

    def math_view(self) -> np.ndarray:
        raise TypeError(
            "quantized payloads have no raw math view; stream them through "
            "f64_chunk() or materialize with to_flat()")

    def nbytes(self) -> int:
        return int(self.data.nbytes
                   + (self.scales.nbytes if self.scales is not None else 0))

    def tile_source(self, lo: int = 0,
                    hi: Optional[int] = None) -> Optional[TileSource]:
        """Adapter for the Pallas aggregation backend: the still-compressed
        wire arrays, so the dequantize stays fused in the kernel.  A delta
        payload whose base is not attached yet returns ``None`` — the
        numpy path then raises its explanatory error.

        ``(lo, hi)`` selects an element range (shard-aware slicing, all
        zero-copy views).  For int8 payloads ``lo`` must sit on a scale-
        window boundary — :func:`repro.sharding.shard_bounds` aligns
        shard edges to ``qchunk`` exactly so this holds; a misaligned
        range returns ``None`` (numpy fallback) rather than mis-scaling.
        """
        if hi is None:
            hi = self.layout.total_size
        if self.is_delta and self.base is None:
            return None
        base = self.base if self.is_delta else None
        if self.mode == "bf16":
            return TileSource("float", self.data[lo:hi], base=base)
        if lo % self.qchunk:
            return None
        c0, c1 = lo // self.qchunk, -(-hi // self.qchunk)
        return TileSource("q8", self.data[lo:hi], self.scales[c0:c1],
                          self.qchunk, base)


# ---------------------------------------------------------------------------
# partial-aggregate payloads (wire codec 0xF4 — edge-aggregator tier)
# ---------------------------------------------------------------------------
class PartialSum:
    """Zero-copy view of a pre-reduced subtree payload (codec ``partial``).

    An edge aggregator folds its subtree's fit results with the same
    :class:`~repro.fl.agg_kernels.StreamingWeightedSum` chunk arithmetic
    the root uses and ships the *unscaled* fp64 accumulator — one vector
    ``sum_i w_i x_i`` plus the subtree's total weight ``W``, contributing
    client count, sorted node ids, and any per-node failures it absorbed.
    The root then folds O(#edges) of these (``acc += S_e``; one divide by
    the global W at finalize) instead of O(#clients) client payloads.

    Implements the chunked-read protocol (``layout`` / :meth:`f64_chunk` /
    :meth:`decode_chunk` / :meth:`nbytes`) so the kernels stream it like
    any payload; it is **not** parameters — decoders asked to materialize
    it as a model raise ``UnsupportedCodec`` (see ``messages._unframe``).
    """

    __slots__ = ("layout", "data", "total_w", "count", "node_ids",
                 "failures", "_memo_token")

    def __init__(self, layout: Layout, data: np.ndarray, total_w: float,
                 count: int, node_ids: Tuple[str, ...] = (),
                 failures: Tuple[Tuple[str, str], ...] = ()):
        assert data.dtype == np.float64 and data.ndim == 1
        assert data.size == layout.total_size, (data.size, layout)
        self.layout = layout
        self.data = data
        self.total_w = float(total_w)
        self.count = int(count)
        self.node_ids = tuple(node_ids)
        self.failures = tuple((str(n), str(r)) for n, r in failures)
        self._memo_token: Optional[str] = None

    @classmethod
    def from_buffer(cls, data, layout: Layout, total_w: float, count: int,
                    node_ids: Tuple[str, ...] = (),
                    failures: Tuple[Tuple[str, str], ...] = (),
                    offset: int = 0) -> "PartialSum":
        """Zero-copy wrap of a received frame payload (frozen view)."""
        vec = np.frombuffer(data, np.float64, count=layout.total_size,
                            offset=offset)
        vec.flags.writeable = False
        return cls(layout, vec, total_w, count, node_ids, failures)

    # ------------------------------------------------------------- protocol
    def f64_chunk(self, lo: int, hi: int, out: np.ndarray) -> np.ndarray:
        o = out[:hi - lo]
        np.copyto(o, self.data[lo:hi])
        return o

    def decode_chunk(self, lo: int, hi: int, out: np.ndarray) -> np.ndarray:
        return self.f64_chunk(lo, hi, out)

    def to_f64(self, out: Optional[np.ndarray] = None) -> np.ndarray:
        if out is None:
            return self.data.copy()
        np.copyto(out[:self.data.size], self.data)
        return out[:self.data.size]

    def nbytes(self) -> int:
        return int(self.data.nbytes)


# ---------------------------------------------------------------------------
# structured-sparse delta payloads (wire codec 0xF5 — TopK / adapter mode)
# ---------------------------------------------------------------------------
def topk_indices(mag: np.ndarray, k: int) -> np.ndarray:
    """Exactly-k largest-|magnitude| indices with deterministic
    tie-breaking, returned **sorted ascending**.

    ``np.argpartition`` orders equal-magnitude elements by memory layout,
    which varies across numpy builds; selecting ``mag >= thresh`` instead
    keeps *every* tie and overshoots k.  This helper takes all elements
    strictly above the k-th magnitude, then fills the remaining slots with
    the **lowest-index** elements equal to it — exactly k indices, bitwise
    reproducible across runs and platforms.  Shared by the 0xF5 encoder
    and :class:`repro.fl.mods.TopKCompressionMod`.
    """
    mag = np.ravel(mag)
    k = int(k)
    if k <= 0:
        return np.empty(0, np.int64)
    if k >= mag.size:
        return np.arange(mag.size, dtype=np.int64)
    thresh = np.partition(mag, mag.size - k)[mag.size - k]
    above = np.flatnonzero(mag > thresh)
    need = k - above.size
    ties = np.flatnonzero(mag == thresh)[:need]
    return np.sort(np.concatenate((above, ties))).astype(np.int64)


class SparseDelta:
    """Zero-copy view of a structured-sparse delta payload (codec 0xF5).

    The logical model is the uniform-fp32 :class:`Layout`; the payload is
    **always a delta** vs the round-start parameters (untraveled
    coordinates mean "delta == 0", so the server reconstructs
    ``base + scatter(values at indices)``).  Two index modes:

    - ``imode="coo"``: ``indices`` is a sorted, unique ``(nnz,)`` int64
      vector of element coordinates (TopK-sparse client updates);
    - ``imode="ranges"``: ``indices`` is a sorted, non-overlapping
      ``(R, 2)`` int64 array of ``[start, stop)`` element ranges — the
      adapter/LoRA-mask mode where only the trainable subset travels and
      ``values`` is the dense concatenation of those ranges.

    Two value modes: ``vmode="q8"`` reuses the PR 3 int8 machinery —
    ``values`` is int8 and ``scales`` one fp32 scale per
    :data:`QCHUNK`-element window **of the packed value stream** (error
    per traveled coordinate bounded by ``scale/2``) — and ``vmode="f32"``
    carries raw fp32 values (lossless given the selection).

    Implements the chunked-read protocol (``layout`` / :meth:`f64_chunk`
    / :meth:`decode_chunk` / :meth:`nbytes`) so the generic kernels can
    stream it; the aggregation fold uses :meth:`iter_spans` +
    :meth:`dequant_packed` instead for an O(nnz) fused
    scatter-dequantize-accumulate that never densifies
    (:meth:`StreamingWeightedSum.add_sparse <repro.fl.agg_kernels
    .StreamingWeightedSum.add_sparse>`).  :meth:`tile_source` returns
    ``None`` by design — a data-dependent scatter has no tile structure
    for the stacked Pallas kernels, so the dispatch layer's numpy/scatter
    fallback is the device path (see ``kernels.agg_reduce.scatter_wsum``).
    """

    is_delta = True      # always encoded vs the round-start parameters
    is_sparse = True

    __slots__ = ("layout", "imode", "vmode", "indices", "values", "scales",
                 "qchunk", "base", "_starts", "_stops", "_offsets",
                 "_memo_token")

    def __init__(self, layout: Layout, imode: str, indices: np.ndarray,
                 values: np.ndarray, scales: Optional[np.ndarray] = None,
                 qchunk: int = QCHUNK, base=None):
        assert imode in ("coo", "ranges"), imode
        self.layout = layout
        self.imode = imode
        self.indices = indices
        self.values = values
        self.scales = scales
        self.qchunk = int(qchunk)
        self.base = base
        self.vmode = "q8" if values.dtype == np.int8 else "f32"
        n = layout.total_size
        # validate the index structure up front: a byzantine payload with
        # unsorted/overlapping coordinates would silently break the
        # searchsorted windowing and the unique-scatter determinism — the
        # ValueError here demotes the sender to a per-node failure instead
        if imode == "coo":
            if indices.ndim != 1 or indices.size != values.size:
                raise ValueError("coo sparse delta: indices/values mismatch")
            if indices.size and (int(indices[0]) < 0
                                 or int(indices[-1]) >= n
                                 or np.any(np.diff(indices) <= 0)):
                raise ValueError(
                    "coo sparse delta: indices must be sorted, unique and "
                    "within the layout")
            self._starts = self._stops = self._offsets = None
        else:
            r = indices.reshape(-1, 2)
            if np.any(r[:, 0] >= r[:, 1]) or (r.size and (
                    int(r[0, 0]) < 0 or int(r[-1, 1]) > n
                    or np.any(r[1:, 0] < r[:-1, 1]))):
                raise ValueError(
                    "ranges sparse delta: [start, stop) ranges must be "
                    "sorted, non-overlapping and within the layout")
            lens = (r[:, 1] - r[:, 0]).astype(np.int64)
            if int(lens.sum()) != values.size:
                raise ValueError("ranges sparse delta: values length != "
                                 "total range coverage")
            self._starts = np.ascontiguousarray(r[:, 0])
            self._stops = np.ascontiguousarray(r[:, 1])
            off = np.zeros(len(r) + 1, np.int64)
            np.cumsum(lens, out=off[1:])
            self._offsets = off
        if self.vmode == "q8":
            nchunks = -(-values.size // self.qchunk)
            if scales is None or scales.size != nchunks:
                raise ValueError("q8 sparse delta: need one fp32 scale per "
                                 "qchunk window of the packed value stream")
        self._memo_token: Optional[str] = None

    @property
    def nnz(self) -> int:
        return int(self.values.size)

    # ------------------------------------------------------- O(nnz) access
    def iter_spans(self, lo: int, hi: int):
        """Yield ``(p0, p1, dest)`` for the traveled coordinates inside
        element window ``[lo, hi)``: packed value positions ``[p0, p1)``
        land at ``dest`` (an index array for coo, a slice for ranges —
        both usable as a numpy fancy/basic index **relative to lo**).
        This is the scatter side of the fused fold: cost is O(overlap),
        never O(hi - lo)."""
        if self.imode == "coo":
            i0, i1 = np.searchsorted(self.indices, (lo, hi))
            i0, i1 = int(i0), int(i1)
            if i1 > i0:
                yield i0, i1, self.indices[i0:i1] - lo
            return
        r0 = int(np.searchsorted(self._stops, lo, side="right"))
        r1 = int(np.searchsorted(self._starts, hi, side="left"))
        for r in range(r0, r1):
            s, e = int(self._starts[r]), int(self._stops[r])
            a, b = max(s, lo), min(e, hi)
            if b <= a:
                continue
            p0 = int(self._offsets[r]) + (a - s)
            yield p0, p0 + (b - a), slice(a - lo, b - lo)

    def dequant_packed(self, p0: int, p1: int,
                       out: np.ndarray) -> np.ndarray:
        """Packed values ``[p0, p1)`` as f64, written into ``out[:p1-p0]``
        — the ``_dequant_q8`` chain for q8 (one fp32 rounding, bitwise the
        client-side reconstruction), a plain exact widen for f32."""
        o = out[:p1 - p0]
        if self.vmode == "q8":
            _dequant_q8(self.values, self.scales, self.qchunk, p0, p1, o)
        else:
            np.copyto(o, self.values[p0:p1], casting="unsafe")
        return o

    # ------------------------------------------------------------- protocol
    def decode_chunk(self, lo: int, hi: int, out: np.ndarray) -> np.ndarray:
        """Codec decode of elements [lo, hi) — WITHOUT the base add: zeros
        everywhere except the traveled coordinates (delta semantics)."""
        o = out[:hi - lo]
        o[...] = 0.0
        buf = np.empty(min(hi - lo, max(self.nnz, 1)), np.float64)
        for p0, p1, dest in self.iter_spans(lo, hi):
            # unique destinations: assignment == accumulate-into-zeros
            o[dest] = self.dequant_packed(p0, p1, buf)
        return o

    def f64_chunk(self, lo: int, hi: int, out: np.ndarray) -> np.ndarray:
        """Fused decode + delta-base add of elements [lo, hi)."""
        o = self.decode_chunk(lo, hi, out)
        base = self.base
        if base is None:
            raise ValueError(
                "sparse-delta payload needs its round base attached "
                "(SparseDelta.base) before it can be read")
        arr = None
        if isinstance(base, QuantParams):
            c = base._chunk_cache
            if c is not None and c[0] == lo and c[1] == hi:
                arr = c[2]
        if arr is None:
            arr = base.f64_chunk(lo, hi, np.empty(hi - lo, np.float64))
            if isinstance(base, QuantParams):
                base._chunk_cache = (lo, hi, arr)
        o += arr            # arr is read-only by contract: never mutated
        return o

    def to_f64(self, out: Optional[np.ndarray] = None) -> np.ndarray:
        n = self.layout.total_size
        if out is None:
            out = np.empty(n, np.float64)
        for lo in range(0, n, _QBLOCK):
            hi = min(lo + _QBLOCK, n)
            self.f64_chunk(lo, hi, out[lo:hi])
        return out

    def math_view(self) -> np.ndarray:
        raise TypeError(
            "sparse-delta payloads have no raw math view; stream them "
            "through f64_chunk() / iter_spans()")

    def nbytes(self) -> int:
        return int(self.indices.nbytes + self.values.nbytes
                   + (self.scales.nbytes if self.scales is not None else 0))

    def tile_source(self, lo: int = 0, hi: Optional[int] = None) -> None:
        """Always ``None``: a data-dependent scatter has no tile structure
        for the stacked Pallas kernels — the fold routes sparse payloads
        through the O(nnz) scatter path instead (``add_sparse`` /
        ``kernels.agg_reduce.scatter_wsum``)."""
        return None

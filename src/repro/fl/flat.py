"""Flat-buffer parameter representation — the aggregation hot path.

A model's parameters cross every FL hop as ``List[np.ndarray]``; treating
them leaf-by-leaf makes each round O(clients x layers) in Python overhead
and copies the payload several times per hop.  :class:`FlatParams` instead
carries **one contiguous byte buffer** plus a :class:`Layout` (dtypes,
shapes, offsets).  Properties:

- pytree/NDArrays <-> flat conversion is a single ``concatenate`` (or free,
  when the arrays already view one buffer, e.g. straight off the wire);
- per-leaf access is a zero-copy ``view``/``reshape`` into the buffer;
- layouts are interned in a cache, so repeated rounds of the same model
  reuse one Layout object and comparisons are pointer comparisons;
- the math view (one fp64/native vector over all leaves) is what the
  vectorized strategy kernels in :mod:`repro.fl.agg_kernels` consume.

The byte buffer preserves leaves bitwise, so the Fig. 5 exactness guarantee
(native vs in-FLARE bit-identical) survives the representation change.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NDArrays = List[np.ndarray]


def np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including ml_dtypes extensions (bf16/fp8)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax dependency; provides bfloat16 et al.

        return np.dtype(getattr(ml_dtypes, name))


@dataclass(frozen=True)
class LeafSpec:
    dtype: str                  # dtype name ("float32", "bfloat16", ...)
    shape: Tuple[int, ...]
    offset: int                 # byte offset into the flat buffer
    nbytes: int
    eoffset: int                # element offset into the math vector
    size: int                   # number of elements


@dataclass(frozen=True)
class Layout:
    leaves: Tuple[LeafSpec, ...]
    total_bytes: int
    total_size: int             # total element count
    uniform_dtype: Optional[str]  # set when every leaf shares one dtype

    @property
    def signature(self) -> Tuple[Tuple[str, Tuple[int, ...]], ...]:
        return tuple((l.dtype, l.shape) for l in self.leaves)


_LAYOUT_CACHE: Dict[Tuple[Tuple[str, Tuple[int, ...]], ...], Layout] = {}


def layout_for(signature: Sequence[Tuple[str, Tuple[int, ...]]]) -> Layout:
    """Intern a Layout for a (dtype, shape) signature."""
    key = tuple((str(d), tuple(int(x) for x in s)) for d, s in signature)
    cached = _LAYOUT_CACHE.get(key)
    if cached is not None:
        return cached
    leaves = []
    off = eoff = 0
    for dname, shape in key:
        dt = np_dtype(dname)
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = size * dt.itemsize
        leaves.append(LeafSpec(dname, shape, off, nbytes, eoff, size))
        off += nbytes
        eoff += size
    dtypes = {l.dtype for l in leaves}
    layout = Layout(tuple(leaves), off, eoff,
                    dtypes.pop() if len(dtypes) == 1 else None)
    _LAYOUT_CACHE[key] = layout
    return layout


def layout_of(arrays: NDArrays) -> Layout:
    return layout_for([(a.dtype.name, a.shape) for a in arrays])


class FlatParams:
    """One contiguous uint8 buffer + a Layout describing the leaves."""

    __slots__ = ("buf", "layout")

    def __init__(self, buf: np.ndarray, layout: Layout):
        assert buf.dtype == np.uint8 and buf.ndim == 1
        assert buf.nbytes == layout.total_bytes, (buf.nbytes, layout)
        self.buf = buf
        self.layout = layout

    # ------------------------------------------------------------ builders
    @classmethod
    def from_arrays(cls, arrays: NDArrays,
                    layout: Optional[Layout] = None) -> "FlatParams":
        """Pack leaves into one contiguous buffer (a single copy).

        Messages decoded from the flat wire format never come through here —
        their FlatParams wraps the received payload zero-copy (see
        ``messages.decode_fit_res``); this is the entry point for freshly
        produced client/strategy arrays.
        """
        layout = layout or layout_of(arrays)
        buf = np.empty(layout.total_bytes, np.uint8)
        for spec, a in zip(layout.leaves, arrays):
            seg = buf[spec.offset:spec.offset + spec.nbytes]
            seg.view(np_dtype(spec.dtype))[...] = \
                np.ascontiguousarray(a).reshape(-1)
        return cls(buf, layout)

    @classmethod
    def from_buffer(cls, data, layout: Layout, offset: int = 0
                    ) -> "FlatParams":
        """Zero-copy wrap of ``data`` (bytes/memoryview/ndarray)."""
        buf = np.frombuffer(data, np.uint8, count=layout.total_bytes,
                            offset=offset)
        return cls(buf, layout)

    @classmethod
    def zeros(cls, layout: Layout) -> "FlatParams":
        return cls(np.zeros(layout.total_bytes, np.uint8), layout)

    # ------------------------------------------------------------- views
    def leaf(self, i: int) -> np.ndarray:
        spec = self.layout.leaves[i]
        seg = self.buf[spec.offset:spec.offset + spec.nbytes]
        return seg.view(np_dtype(spec.dtype)).reshape(spec.shape)

    def to_arrays(self) -> NDArrays:
        """Zero-copy per-leaf views (read-only iff the buffer is)."""
        return [self.leaf(i) for i in range(len(self.layout.leaves))]

    def math_view(self) -> np.ndarray:
        """The whole buffer as one 1-D vector of the uniform dtype.

        Zero-copy; only valid for uniform-dtype layouts (the common case —
        fp32 models, or uint64 SecAgg shares).
        """
        u = self.layout.uniform_dtype
        if u is None:
            raise ValueError("math_view() needs a uniform-dtype layout")
        return self.buf.view(np_dtype(u))

    def to_f64(self, out: Optional[np.ndarray] = None) -> np.ndarray:
        """All leaves as one float64 vector (one pass; ``out`` reusable)."""
        lo = self.layout
        if out is None:
            out = np.empty(lo.total_size, np.float64)
        if lo.uniform_dtype is not None:
            np.copyto(out, self.math_view(), casting="unsafe")
        else:
            for i, spec in enumerate(lo.leaves):
                np.copyto(out[spec.eoffset:spec.eoffset + spec.size],
                          self.leaf(i).reshape(-1), casting="unsafe")
        return out

    def nbytes(self) -> int:
        return self.layout.total_bytes


def unflatten_vector(vec: np.ndarray, layout: Layout) -> NDArrays:
    """Split a math vector back into leaves, cast to each leaf's dtype."""
    out = []
    for spec in layout.leaves:
        seg = vec[spec.eoffset:spec.eoffset + spec.size]
        out.append(seg.reshape(spec.shape).astype(np_dtype(spec.dtype)))
    return out

from repro.fl.messages import (  # noqa: F401
    FitIns, FitRes, EvaluateIns, EvaluateRes, TaskIns, TaskRes,
    UnsupportedCodec, WIRE_CODECS, QUANT_CODECS,
    arrays_to_bytes, bytes_to_arrays, params_to_arrays, arrays_to_params,
    encode_partial_fit_res, set_default_codec,
)
from repro.fl.flat import (  # noqa: F401
    FlatParams, Layout, PartialSum, QuantParams, layout_for, layout_of,
    quantize_int8, unflatten_vector,
)
from repro.fl.client import Client, ClientApp, NumPyClient  # noqa: F401
from repro.fl.server import ServerApp, ServerConfig, Driver  # noqa: F401
from repro.fl.registry import PopulationRegistry  # noqa: F401
from repro.fl.fedbuff import FedBuffBuffer  # noqa: F401
from repro.fl.strategy import (  # noqa: F401
    Strategy, FitAccumulator, QuorumNotMet, FedAvg, FedAdam, FedYogi,
    FedAvgM, FedProx, FedMedian, FedTrimmedMean, Krum, make_strategy,
    weighted_average,
)
from repro.fl.mods import (  # noqa: F401
    DPMod, SecAggMod, SecAggFedAvg, TopKCompressionMod,
)

"""Seed (pre-flat-buffer) per-layer reference implementations.

These are the original O(clients x layers) Python-loop strategy paths,
kept verbatim for two purposes:

- **equivalence tests** (`tests/test_flat.py`): every vectorized strategy
  in :mod:`repro.fl.strategy` must reproduce these outputs exactly or to
  within 1 ULP of the leaf dtype;
- **benchmark baselines** (`benchmarks/run.py` ``agg_throughput_*`` rows):
  the flat aggregation engine's speedup is measured against this path.

Do not "fix" or optimize anything here — being the slow-but-obviously-
correct reference is the point.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


NDArrays = List[np.ndarray]


def legacy_weighted_average(results: List[Tuple[NDArrays, float]]) -> NDArrays:
    total = float(sum(w for _, w in results))
    out = [np.zeros_like(a, dtype=np.float64) for a in results[0][0]]
    for arrays, w in results:
        for i, a in enumerate(arrays):
            out[i] += (w / total) * a.astype(np.float64)
    return [o.astype(results[0][0][i].dtype) for i, o in enumerate(out)]


class LegacyFedAvg:
    def __init__(self, min_fit_clients: int = 1):
        self.min_fit_clients = min_fit_clients

    def aggregate_fit(self, rnd, results, failures, current):
        if len(results) < self.min_fit_clients:
            raise RuntimeError(
                f"round {rnd}: {len(results)} results < min "
                f"{self.min_fit_clients} (failures: {failures})")
        agg = legacy_weighted_average(
            [(r.parameters, r.num_examples) for _, r in results])
        return agg, {"num_clients": len(results)}


class LegacyFedAvgM(LegacyFedAvg):
    def __init__(self, server_lr: float = 1.0, momentum: float = 0.9):
        super().__init__()
        self.server_lr = server_lr
        self.momentum = momentum
        self._velocity = None

    def aggregate_fit(self, rnd, results, failures, current):
        target, m = LegacyFedAvg.aggregate_fit(self, rnd, results, failures,
                                               current)
        delta = [t.astype(np.float64) - c.astype(np.float64)
                 for t, c in zip(target, current)]
        if self._velocity is None:
            self._velocity = [np.zeros_like(d) for d in delta]
        self._velocity = [self.momentum * v + d
                          for v, d in zip(self._velocity, delta)]
        new = [c.astype(np.float64) + self.server_lr * v
               for c, v in zip(current, self._velocity)]
        return [n.astype(c.dtype) for n, c in zip(new, current)], m


class _LegacyAdaptiveBase(LegacyFedAvg):
    def __init__(self, server_lr: float = 0.1, beta1: float = 0.9,
                 beta2: float = 0.99, tau: float = 1e-3):
        super().__init__()
        self.server_lr = server_lr
        self.beta1 = beta1
        self.beta2 = beta2
        self.tau = tau
        self._m = None
        self._v = None

    def _second_moment(self, v, d):
        raise NotImplementedError

    def aggregate_fit(self, rnd, results, failures, current):
        target, metrics = LegacyFedAvg.aggregate_fit(self, rnd, results,
                                                     failures, current)
        delta = [t.astype(np.float64) - c.astype(np.float64)
                 for t, c in zip(target, current)]
        if self._m is None:
            self._m = [np.zeros_like(d) for d in delta]
            self._v = [np.full_like(d, self.tau ** 2) for d in delta]
        self._m = [self.beta1 * m + (1 - self.beta1) * d
                   for m, d in zip(self._m, delta)]
        self._v = [self._second_moment(v, d) for v, d in zip(self._v, delta)]
        new = [c.astype(np.float64)
               + self.server_lr * m / (np.sqrt(v) + self.tau)
               for c, m, v in zip(current, self._m, self._v)]
        return [n.astype(c.dtype) for n, c in zip(new, current)], metrics


class LegacyFedAdam(_LegacyAdaptiveBase):
    def _second_moment(self, v, d):
        return self.beta2 * v + (1 - self.beta2) * np.square(d)


class LegacyFedYogi(_LegacyAdaptiveBase):
    def _second_moment(self, v, d):
        d2 = np.square(d)
        return v - (1 - self.beta2) * d2 * np.sign(v - d2)


class LegacyFedMedian(LegacyFedAvg):
    def aggregate_fit(self, rnd, results, failures, current):
        stacked = [np.median(np.stack([r.parameters[i].astype(np.float64)
                                       for _, r in results]), axis=0)
                   for i in range(len(results[0][1].parameters))]
        return ([s.astype(current[i].dtype) for i, s in enumerate(stacked)],
                {"num_clients": len(results)})


class LegacyFedTrimmedMean(LegacyFedAvg):
    def __init__(self, beta: float = 0.2):
        super().__init__()
        self.beta = beta

    def aggregate_fit(self, rnd, results, failures, current):
        k = int(self.beta * len(results))
        out = []
        for i in range(len(results[0][1].parameters)):
            stack = np.sort(np.stack([r.parameters[i].astype(np.float64)
                                      for _, r in results]), axis=0)
            sl = stack[k:len(results) - k] if len(results) > 2 * k else stack
            out.append(np.mean(sl, axis=0).astype(current[i].dtype))
        return out, {"num_clients": len(results), "trimmed_each_end": k}


class LegacyKrum(LegacyFedAvg):
    def __init__(self, num_byzantine: int = 0, num_selected: int = 1):
        super().__init__()
        self.num_byzantine = num_byzantine
        self.num_selected = num_selected

    def aggregate_fit(self, rnd, results, failures, current):
        vecs = [np.concatenate([a.astype(np.float64).ravel()
                                for a in r.parameters])
                for _, r in results]
        n = len(vecs)
        f = min(self.num_byzantine, max(0, (n - 3) // 2))
        scores = []
        for i in range(n):
            d = sorted(float(np.sum((vecs[i] - vecs[j]) ** 2))
                       for j in range(n) if j != i)
            scores.append(sum(d[: max(n - f - 2, 1)]))
        chosen = np.argsort(scores)[: max(self.num_selected, 1)]
        sel = [(results[i][1].parameters, results[i][1].num_examples)
               for i in chosen]
        return legacy_weighted_average(sel), \
            {"krum_selected": [int(c) for c in chosen]}


LEGACY_TABLE = {
    "fedavg": LegacyFedAvg, "fedavgm": LegacyFedAvgM,
    "fedadam": LegacyFedAdam, "fedyogi": LegacyFedYogi,
    "fedmedian": LegacyFedMedian, "fedtrimmedmean": LegacyFedTrimmedMean,
    "krum": LegacyKrum,
}

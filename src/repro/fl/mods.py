"""Client-side mods (Flower's built-in DP / SecAgg support, paper §1).

Mods wrap the client's task handling: ``mod(task_ins, call_next) ->
task_res``.  They compose; ClientApp applies them outermost-first.

All three mods operate on the **flat buffer** (one contiguous vector per
model, :class:`~repro.fl.flat.FlatParams`) rather than per-layer Python
loops: one L2 norm, one noise draw, one quantize pass per update.  SecAgg
mask *derivation* stays per-leaf (seed spawn keys) for bitwise wire
compatibility with older peers; only the application is vectorized.

- :class:`DPMod` — local DP: clip the client's model *delta* to an L2 bound
  and add gaussian noise (deterministic per (site, round) so experiments
  reproduce bitwise).
- :class:`SecAggMod` + :class:`SecAggFedAvg` — pairwise-mask secure
  aggregation with exact fixed-point arithmetic: each pair of sites derives
  a shared seed (from provisioning), masks are ±PRG(seed, round) in uint64
  mod-2^64 arithmetic over the whole flat buffer, so they cancel exactly in
  the server's sum and the server never sees an individual update.  The hot
  quantize+mask loop has a Pallas TPU kernel
  (``repro.kernels.secagg_mask``); this mod uses the numpy reference path
  (CPU container), kernels tests cross-check them.
- :class:`TopKCompressionMod` — magnitude Top-K delta sparsification,
  global over the flat delta (a single threshold for the whole model,
  which keeps the largest-magnitude coordinates regardless of layer).

Composition with the quantized wire codecs (0xF2/0xF3): mods run INSIDE
the mod chain on exact fp32 buffers; the negotiated lossy re-encode
happens once, after the chain, at the ClientApp boundary
(``ClientApp._maybe_compress``).  So DP noise/clipping and TopK
sparsification are applied exactly and only the final wire hop is
quantized, while SecAgg's masked shares — already in the quantized
**integer domain** (fixed-point uint64, masks cancelling mod 2^64) — are
not uniform fp32 and therefore ship on the lossless 0xF1 frame: pairwise
masks keep cancelling bit-exactly in the server's wrapping sum.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Sequence

import numpy as np

from repro.fl.flat import (FlatParams, layout_for, topk_indices,
                           unflatten_vector)
from repro.fl.messages import (TaskIns, TaskRes, decode_fit_ins,
                               decode_fit_res, encode_fit_res)

NDArrays = List[np.ndarray]

QUANT_BITS = 16                      # fixed-point fractional bits
QUANT_SCALE = np.uint64(1) << QUANT_BITS


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _l2(arrays: NDArrays) -> float:
    return float(np.sqrt(sum(float(np.sum(np.square(a.astype(np.float64))))
                             for a in arrays)))


def _prg_mask(seed: int, round_: int, leaf: int, shape, positive: bool
              ) -> np.ndarray:
    rng = np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(round_, leaf)))
    m = rng.integers(0, np.iinfo(np.uint64).max, size=shape, dtype=np.uint64,
                     endpoint=True)
    return m if positive else (np.uint64(0) - m)


def _prg_mask_flat(seed: int, round_: int, layout, positive: bool
                   ) -> np.ndarray:
    """Whole-model mask as one vector.

    Derivation is deliberately kept per-leaf with the seed's
    ``spawn_key=(round, leaf)`` so masked shares stay **bitwise identical**
    to what older (per-array codec) peers produce — a mixed-version fleet's
    masks must still cancel mod 2^64.  Only the application is flat.
    """
    out = np.empty(layout.total_size, np.uint64)
    for i, spec in enumerate(layout.leaves):
        out[spec.eoffset:spec.eoffset + spec.size] = \
            _prg_mask(seed, round_, i, spec.shape, positive).ravel()
    return out


def quantize(a: np.ndarray) -> np.ndarray:
    q = np.round(a.astype(np.float64) * float(QUANT_SCALE)).astype(np.int64)
    return q.view(np.uint64) if q.dtype == np.int64 else q.astype(np.uint64)


def dequantize(q: np.ndarray, count: int = 1) -> np.ndarray:
    signed = q.astype(np.uint64).view(np.int64).astype(np.float64)
    return (signed / float(QUANT_SCALE)).astype(np.float32)


def _u64_layout(layout):
    return layout_for([("uint64", l.shape) for l in layout.leaves])


# ---------------------------------------------------------------------------
# DP mod
# ---------------------------------------------------------------------------
@dataclass
class DPMod:
    clip_norm: float = 1.0
    noise_multiplier: float = 0.0
    site_id: int = 0
    seed: int = 0

    def __call__(self, task: TaskIns, call_next) -> TaskRes:
        if task.task_type != "fit":
            return call_next(task)
        ins = decode_fit_ins(task.payload)
        res = call_next(task)
        if res.error:
            return res
        fit = decode_fit_res(res.payload)
        ofp = _flat_of(fit)
        layout = ofp.layout
        base = ins.flat if ins.flat is not None else \
            FlatParams.from_arrays(ins.parameters)
        i64 = base.to_f64()
        delta = ofp.to_f64()
        delta -= i64
        norm = float(np.sqrt(np.dot(delta, delta)))
        scale = min(1.0, self.clip_norm / max(norm, 1e-12))
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed,
                                   spawn_key=(self.site_id, task.round)))
        sigma = self.noise_multiplier * self.clip_norm
        delta *= np.float64(scale)
        if sigma > 0:
            delta += rng.normal(0.0, sigma, size=delta.shape)
        i64 += delta
        fit.set_parameters(unflatten_vector(i64, layout))
        fit.metrics = dict(fit.metrics, dp_clip_scale=scale, dp_pre_norm=norm)
        return TaskRes("fit", task.round, encode_fit_res(fit),
                       task_id=task.task_id)


# ---------------------------------------------------------------------------
# Secure aggregation
# ---------------------------------------------------------------------------
@dataclass
class SecAggMod:
    """Masks the (num_examples-weighted) quantized flat buffer."""

    site: str = ""
    peers: Sequence[str] = ()
    pairwise_seed_fn: Callable[[str, str], int] = None  # from provisioning

    def __call__(self, task: TaskIns, call_next) -> TaskRes:
        if task.task_type != "fit":
            return call_next(task)
        res = call_next(task)
        if res.error:
            return res
        fit = decode_fit_res(res.payload)
        fp = _flat_of(fit)
        x = fp.to_f64()
        x *= np.float64(fit.num_examples)
        q = quantize(x)
        for peer in self.peers:
            if peer == self.site:
                continue
            seed = self.pairwise_seed_fn(self.site, peer)
            q += _prg_mask_flat(seed, task.round, fp.layout,
                                positive=self.site < peer)
        masked = FlatParams(q.view(np.uint8), _u64_layout(fp.layout))
        fit.set_parameters(masked.to_arrays(), flat=masked)
        fit.metrics = dict(fit.metrics, secagg=1)
        return TaskRes("fit", task.round, encode_fit_res(fit),
                       task_id=task.task_id)


from repro.fl.strategy import (FedAvg, FitAccumulator,  # noqa: E402
                               _flat_of)  # (avoid cycle at import top)


class _SecAggFitAcc(FitAccumulator):
    """Streaming mod-2^64 sum: each masked share folds into one uint64
    accumulator on arrival (masks cancel exactly), so the server never
    holds more than one share beyond the accumulator."""

    def __init__(self, strategy, rnd, current):
        super().__init__(strategy, rnd, current)
        self._acc = None
        self._layout = None
        self.total_w = 0.0
        self.count = 0

    def add(self, node, res):
        fp = _flat_of(res)
        if self._acc is None:
            self._layout = fp.layout
            self._acc = np.zeros(fp.layout.total_size, np.uint64)
        self._acc += fp.math_view()
        self.total_w += float(res.num_examples)
        self.count += 1

    def finalize(self, failures):
        if failures:
            raise RuntimeError(
                f"secure aggregation needs every masked share; missing "
                f"{[f for f, _ in failures]}")
        vec = dequantize(self._acc) / self.total_w
        out = [vec[l.eoffset:l.eoffset + l.size].reshape(l.shape)
               .astype(np.float32) for l in self._layout.leaves]
        return out, {"num_clients": self.count, "secagg": 1}


@dataclass
class SecAggFedAvg(FedAvg):
    """Server side of the pairwise-mask protocol: SUM the masked uint64
    flat buffers (masks cancel exactly mod 2^64), then dequantize and
    divide by the total example count."""

    def fit_accumulator(self, rnd, current):
        return _SecAggFitAcc(self, rnd, current)

    def aggregate_fit(self, rnd, results, failures, current):
        acc = _SecAggFitAcc(self, rnd, current)
        for node, r in results:
            acc.add(node, r)
        return acc.finalize(failures)


# ---------------------------------------------------------------------------
# Top-K compression
# ---------------------------------------------------------------------------
@dataclass
class TopKCompressionMod:
    """Magnitude Top-K delta sparsification, applied as a DENSE result
    (the non-kept coordinates are reset to the round base — the wire
    frame is still full-size).  For actually-sparse wire bytes use the
    negotiated ``sparse`` codec (0xF5), which ships only the kept
    index/value streams and supersedes this mod for bandwidth; this mod
    remains useful composed with DP/SecAgg, which need dense buffers.

    Selection uses :func:`repro.fl.flat.topk_indices` — exactly k
    coordinates, equal-magnitude ties broken by lowest index — so the
    kept set (and hence the aggregate) is bitwise reproducible across
    platforms.  The previous ``absd >= thresh`` mask kept EVERY tie at
    the threshold, making ``topk_kept_frac`` (and the result) depend on
    how many equal magnitudes the partition landed on."""

    fraction: float = 0.1

    def __call__(self, task: TaskIns, call_next) -> TaskRes:
        if task.task_type != "fit":
            return call_next(task)
        ins = decode_fit_ins(task.payload)
        res = call_next(task)
        if res.error:
            return res
        fit = decode_fit_res(res.payload)
        ofp = _flat_of(fit)
        layout = ofp.layout
        base = ins.flat if ins.flat is not None else \
            FlatParams.from_arrays(ins.parameters)
        i64 = base.to_f64()
        d = ofp.to_f64()
        d -= i64
        k = max(1, int(np.ceil(self.fraction * d.size)))
        idx = topk_indices(np.abs(d), k)
        keep = np.zeros(d.size, bool)
        keep[idx] = True
        i64 += d * keep
        fit.set_parameters(unflatten_vector(i64, layout))
        fit.metrics = dict(fit.metrics,
                           topk_kept_frac=idx.size / max(d.size, 1))
        return TaskRes("fit", task.round, encode_fit_res(fit),
                       task_id=task.task_id)

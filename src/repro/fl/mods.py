"""Client-side mods (Flower's built-in DP / SecAgg support, paper §1).

Mods wrap the client's task handling: ``mod(task_ins, call_next) ->
task_res``.  They compose; ClientApp applies them outermost-first.

- :class:`DPMod` — local DP: clip the client's model *delta* to an L2 bound
  and add gaussian noise (deterministic per (site, round) so experiments
  reproduce bitwise).
- :class:`SecAggMod` + :class:`SecAggFedAvg` — pairwise-mask secure
  aggregation with exact fixed-point arithmetic: each pair of sites derives
  a shared seed (from provisioning), masks are ±PRG(seed, round) in uint64
  mod-2^64 arithmetic, so they cancel exactly in the server's sum and the
  server never sees an individual update.  The hot quantize+mask loop has a
  Pallas TPU kernel (``repro.kernels.secagg_mask``); this mod uses the
  numpy/jnp reference path (CPU container), kernels tests cross-check them.
- :class:`TopKCompressionMod` — magnitude Top-K delta sparsification.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.fl.messages import (FitRes, TaskIns, TaskRes, decode_fit_ins,
                               decode_fit_res, encode_fit_ins, encode_fit_res)

NDArrays = List[np.ndarray]

QUANT_BITS = 16                      # fixed-point fractional bits
QUANT_SCALE = np.uint64(1) << QUANT_BITS


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _l2(arrays: NDArrays) -> float:
    return float(np.sqrt(sum(float(np.sum(np.square(a.astype(np.float64))))
                             for a in arrays)))


def _prg_mask(seed: int, round_: int, leaf: int, shape, positive: bool
              ) -> np.ndarray:
    rng = np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(round_, leaf)))
    m = rng.integers(0, np.iinfo(np.uint64).max, size=shape, dtype=np.uint64,
                     endpoint=True)
    return m if positive else (np.uint64(0) - m)


def quantize(a: np.ndarray) -> np.ndarray:
    q = np.round(a.astype(np.float64) * float(QUANT_SCALE)).astype(np.int64)
    return q.view(np.uint64) if q.dtype == np.int64 else q.astype(np.uint64)


def dequantize(q: np.ndarray, count: int = 1) -> np.ndarray:
    signed = q.astype(np.uint64).view(np.int64).astype(np.float64)
    return (signed / float(QUANT_SCALE)).astype(np.float32)


# ---------------------------------------------------------------------------
# DP mod
# ---------------------------------------------------------------------------
@dataclass
class DPMod:
    clip_norm: float = 1.0
    noise_multiplier: float = 0.0
    site_id: int = 0
    seed: int = 0

    def __call__(self, task: TaskIns, call_next) -> TaskRes:
        if task.task_type != "fit":
            return call_next(task)
        ins = decode_fit_ins(task.payload)
        res = call_next(task)
        if res.error:
            return res
        fit = decode_fit_res(res.payload)
        delta = [np.asarray(o, np.float64) - np.asarray(i, np.float64)
                 for o, i in zip(fit.parameters, ins.parameters)]
        norm = _l2(delta)
        scale = min(1.0, self.clip_norm / max(norm, 1e-12))
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed,
                                   spawn_key=(self.site_id, task.round)))
        sigma = self.noise_multiplier * self.clip_norm
        new_params = []
        for i, d in enumerate(delta):
            noised = d * scale
            if sigma > 0:
                noised = noised + rng.normal(0.0, sigma, size=d.shape)
            new_params.append(
                (np.asarray(ins.parameters[i], np.float64) + noised)
                .astype(fit.parameters[i].dtype))
        fit.parameters = new_params
        fit.metrics = dict(fit.metrics, dp_clip_scale=scale, dp_pre_norm=norm)
        return TaskRes("fit", task.round, encode_fit_res(fit),
                       task_id=task.task_id)


# ---------------------------------------------------------------------------
# Secure aggregation
# ---------------------------------------------------------------------------
@dataclass
class SecAggMod:
    """Masks the (num_examples-weighted) quantized parameters."""

    site: str = ""
    peers: Sequence[str] = ()
    pairwise_seed_fn: Callable[[str, str], int] = None  # from provisioning

    def __call__(self, task: TaskIns, call_next) -> TaskRes:
        if task.task_type != "fit":
            return call_next(task)
        res = call_next(task)
        if res.error:
            return res
        fit = decode_fit_res(res.payload)
        w = float(fit.num_examples)
        masked = []
        for leaf, a in enumerate(fit.parameters):
            q = quantize(np.asarray(a, np.float64) * w)
            for peer in self.peers:
                if peer == self.site:
                    continue
                seed = self.pairwise_seed_fn(self.site, peer)
                q = q + _prg_mask(seed, task.round, leaf, q.shape,
                                  positive=self.site < peer)
            masked.append(q)
        fit.parameters = masked
        fit.metrics = dict(fit.metrics, secagg=1)
        return TaskRes("fit", task.round, encode_fit_res(fit),
                       task_id=task.task_id)


from repro.fl.strategy import FedAvg  # noqa: E402  (avoid cycle at import top)


@dataclass
class SecAggFedAvg(FedAvg):
    """Server side of the pairwise-mask protocol: SUM the masked uint64
    tensors (masks cancel exactly mod 2^64), then dequantize and divide by
    the total example count."""

    def aggregate_fit(self, rnd, results, failures, current):
        if failures:
            raise RuntimeError(
                f"secure aggregation needs every masked share; missing "
                f"{[f for f, _ in failures]}")
        total_w = float(sum(r.num_examples for _, r in results))
        out = []
        for leaf in range(len(results[0][1].parameters)):
            acc = np.zeros_like(results[0][1].parameters[leaf], dtype=np.uint64)
            for _, r in results:
                acc = acc + r.parameters[leaf].astype(np.uint64)
            out.append((dequantize(acc) / total_w).astype(np.float32))
        return out, {"num_clients": len(results), "secagg": 1}


# ---------------------------------------------------------------------------
# Top-K compression
# ---------------------------------------------------------------------------
@dataclass
class TopKCompressionMod:
    fraction: float = 0.1

    def __call__(self, task: TaskIns, call_next) -> TaskRes:
        if task.task_type != "fit":
            return call_next(task)
        ins = decode_fit_ins(task.payload)
        res = call_next(task)
        if res.error:
            return res
        fit = decode_fit_res(res.payload)
        kept = 0
        total = 0
        new_params = []
        for o, i in zip(fit.parameters, ins.parameters):
            d = np.asarray(o, np.float64) - np.asarray(i, np.float64)
            k = max(1, int(np.ceil(self.fraction * d.size)))
            thresh = np.partition(np.abs(d).ravel(), -k)[-k]
            mask = np.abs(d) >= thresh
            kept += int(mask.sum())
            total += d.size
            new_params.append((np.asarray(i, np.float64) + d * mask
                               ).astype(o.dtype))
        fit.parameters = new_params
        fit.metrics = dict(fit.metrics, topk_kept_frac=kept / max(total, 1))
        return TaskRes("fit", task.round, encode_fit_res(fit),
                       task_id=task.task_id)

"""The paper's §5.1 demo workload, in JAX: a small classifier trained
federatedly (the PyTorch-CIFAR-quickstart analogue).

Used by examples/quickstart.py and benchmarks/repro_curves.py (Fig. 5) —
deliberately small so the native-vs-in-FLARE comparison runs in seconds.
Deterministic end to end: given (seed, site), fit() is a pure function of
the incoming parameters, so histories can be compared bitwise.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import make_classification
from repro.fl.client import ClientApp, NumPyClient
from repro.runtime.streaming import SummaryWriter

NDArrays = List[np.ndarray]


# ---------------------------------------------------------------------------
# model: 2-hidden-layer MLP classifier (jax, hand-rolled grads via jax.grad)
# ---------------------------------------------------------------------------
def init_mlp(key, dim: int, hidden: int, classes: int) -> NDArrays:
    k1, k2, k3 = jax.random.split(key, 3)
    s1, s2, s3 = dim ** -0.5, hidden ** -0.5, hidden ** -0.5
    return [
        np.asarray(jax.random.normal(k1, (dim, hidden)) * s1, np.float32),
        np.zeros((hidden,), np.float32),
        np.asarray(jax.random.normal(k2, (hidden, hidden)) * s2, np.float32),
        np.zeros((hidden,), np.float32),
        np.asarray(jax.random.normal(k3, (hidden, classes)) * s3, np.float32),
        np.zeros((classes,), np.float32),
    ]


def _forward(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h = jax.nn.relu(x @ w1 + b1)
    h = jax.nn.relu(h @ w2 + b2)
    return h @ w3 + b3


def _loss(params, x, y, ref_params=None, mu=0.0):
    logits = _forward(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ce = jnp.mean(logz - jnp.take_along_axis(logits, y[:, None], 1)[:, 0])
    if ref_params is not None:
        prox = sum(jnp.sum(jnp.square(p - jax.lax.stop_gradient(r)))
                   for p, r in zip(params, ref_params))
        ce = ce + 0.5 * mu * prox      # mu == 0 => exact plain FedAvg grads
    return ce


@jax.jit
def _sgd_epoch(params, x, y, lr, ref_params, mu):
    def body(p, idx):
        g = jax.grad(_loss)(p, x[idx], y[idx], ref_params, mu)
        return [pi - lr * gi for pi, gi in zip(p, g)], ()

    nb = x.shape[0] // 32
    idxs = jnp.arange(nb * 32).reshape(nb, 32)
    params, _ = jax.lax.scan(body, params, idxs)
    return params


@jax.jit
def _evaluate(params, x, y):
    logits = _forward(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    loss = jnp.mean(logz - jnp.take_along_axis(logits, y[:, None], 1)[:, 0])
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, acc


# ---------------------------------------------------------------------------
# the Flower-style client (paper Listing 2 shape)
# ---------------------------------------------------------------------------
class QuickstartClient(NumPyClient):
    def __init__(self, site: str, *, dim: int = 32, classes: int = 10,
                 n_train: int = 512, n_test: int = 256, seed: int = 7,
                 lr: float = 0.05, epochs: int = 1, skew: float = 0.3,
                 writer: Optional[SummaryWriter] = None):
        import re
        import zlib

        m = re.search(r"(\d+)$", site)
        site_idx = int(m.group(1)) if m else zlib.crc32(site.encode()) % 1000
        self.x_train, self.y_train = make_classification(
            n_train, dim, classes, seed=seed, site=site_idx, skew=skew,
            split=0)
        self.x_test, self.y_test = make_classification(
            n_test, dim, classes, seed=seed, site=site_idx, skew=skew,
            split=1)
        self.lr = lr
        self.epochs = epochs
        self.writer = writer
        self._step = 0

    def get_parameters(self, config) -> NDArrays:
        return init_mlp(jax.random.key(0), self.x_train.shape[1],
                        64, int(self.y_train.max()) + 1)

    def fit(self, parameters, config):
        params = [jnp.asarray(p) for p in parameters]
        ref = params
        mu = float(config.get("proximal_mu", 0.0))
        for _ in range(self.epochs):
            params = _sgd_epoch(params, jnp.asarray(self.x_train),
                                jnp.asarray(self.y_train),
                                jnp.asarray(self.lr, jnp.float32), ref,
                                jnp.asarray(mu, jnp.float32))
        loss, acc = _evaluate(params, jnp.asarray(self.x_train),
                              jnp.asarray(self.y_train))
        if self.writer is not None:    # §5.2 hybrid integration
            self.writer.add_scalar("train_loss", float(loss), self._step)
            self.writer.add_scalar("train_accuracy", float(acc), self._step)
            self._step += 1
        return ([np.asarray(p) for p in params], len(self.x_train),
                {"train_loss": float(loss)})

    def evaluate(self, parameters, config):
        params = [jnp.asarray(p) for p in parameters]
        loss, acc = _evaluate(params, jnp.asarray(self.x_test),
                              jnp.asarray(self.y_test))
        if self.writer is not None:
            self.writer.add_scalar("test_accuracy", float(acc), self._step)
        return float(loss), len(self.x_test), {"accuracy": float(acc)}


def make_client_app(site: str, mods=None, writer_fn=None, **client_kw) -> ClientApp:
    def client_fn(cid: str):
        writer = writer_fn(site) if writer_fn else None
        return QuickstartClient(site, writer=writer, **client_kw).to_client()

    return ClientApp(client_fn=client_fn, mods=mods)

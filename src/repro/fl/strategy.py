"""FL strategies (the Flower ecosystem the FLARE side gains access to).

All operate on ``NDArrays`` (list of numpy arrays) with float64 accumulation
so aggregation is deterministic and ordering-insensitive up to the sorted
client order the ServerApp enforces.

Implemented: FedAvg, FedAvgM (server momentum), FedAdam / FedYogi
(adaptive server optimizers, Reddi et al. 2021), FedProx (proximal client
regularization — the client reads ``config["proximal_mu"]``), robust
aggregation (coordinate-wise median, trimmed mean, Krum).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fl.messages import EvaluateIns, EvaluateRes, FitIns, FitRes

NDArrays = List[np.ndarray]


def weighted_average(results: List[Tuple[NDArrays, float]]) -> NDArrays:
    total = float(sum(w for _, w in results))
    out = [np.zeros_like(a, dtype=np.float64) for a in results[0][0]]
    for arrays, w in results:
        for i, a in enumerate(arrays):
            out[i] += (w / total) * a.astype(np.float64)
    return [o.astype(results[0][0][i].dtype) for i, o in enumerate(out)]


class Strategy:
    def initialize_parameters(self) -> Optional[NDArrays]:
        return None

    def configure_fit(self, rnd: int, parameters: NDArrays,
                      nodes: Sequence[str]) -> Dict[str, FitIns]:
        return {n: FitIns(parameters, {"round": rnd}) for n in nodes}

    def aggregate_fit(self, rnd: int, results: List[Tuple[str, FitRes]],
                      failures: List[Tuple[str, str]],
                      current: NDArrays) -> Tuple[NDArrays, Dict[str, Any]]:
        raise NotImplementedError

    def configure_evaluate(self, rnd: int, parameters: NDArrays,
                           nodes: Sequence[str]) -> Dict[str, EvaluateIns]:
        return {n: EvaluateIns(parameters, {"round": rnd}) for n in nodes}

    def aggregate_evaluate(self, rnd: int,
                           results: List[Tuple[str, EvaluateRes]],
                           failures: List[Tuple[str, str]]
                           ) -> Tuple[Optional[float], Dict[str, Any]]:
        if not results:
            return None, {}
        total = sum(r.num_examples for _, r in results)
        loss = sum(r.loss * r.num_examples for _, r in results) / total
        metrics: Dict[str, Any] = {}
        keys = set()
        for _, r in results:
            keys |= set(r.metrics)
        for k in sorted(keys):
            vals = [(r.metrics[k], r.num_examples) for _, r in results
                    if k in r.metrics and isinstance(r.metrics[k], (int, float))]
            if vals:
                metrics[k] = sum(v * n for v, n in vals) / sum(n for _, n in vals)
        return float(loss), metrics


@dataclass
class FedAvg(Strategy):
    initial_parameters: Optional[NDArrays] = None
    min_fit_clients: int = 1

    def initialize_parameters(self):
        return self.initial_parameters

    def aggregate_fit(self, rnd, results, failures, current):
        if len(results) < self.min_fit_clients:
            raise RuntimeError(
                f"round {rnd}: {len(results)} results < min {self.min_fit_clients}"
                f" (failures: {failures})")
        agg = weighted_average(
            [(r.parameters, r.num_examples) for _, r in results])
        return agg, {"num_clients": len(results)}


@dataclass
class FedAvgM(FedAvg):
    server_lr: float = 1.0
    momentum: float = 0.9
    _velocity: Optional[NDArrays] = field(default=None, repr=False)

    def aggregate_fit(self, rnd, results, failures, current):
        target, m = FedAvg.aggregate_fit(self, rnd, results, failures, current)
        delta = [t.astype(np.float64) - c.astype(np.float64)
                 for t, c in zip(target, current)]
        if self._velocity is None:
            self._velocity = [np.zeros_like(d) for d in delta]
        self._velocity = [self.momentum * v + d
                          for v, d in zip(self._velocity, delta)]
        new = [c.astype(np.float64) + self.server_lr * v
               for c, v in zip(current, self._velocity)]
        return [n.astype(c.dtype) for n, c in zip(new, current)], m


@dataclass
class _AdaptiveBase(FedAvg):
    """Server-side adaptive optimizers (FedOpt family)."""

    server_lr: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.99
    tau: float = 1e-3
    _m: Optional[NDArrays] = field(default=None, repr=False)
    _v: Optional[NDArrays] = field(default=None, repr=False)

    def _second_moment(self, v, d):
        raise NotImplementedError

    def aggregate_fit(self, rnd, results, failures, current):
        target, metrics = FedAvg.aggregate_fit(self, rnd, results, failures,
                                               current)
        delta = [t.astype(np.float64) - c.astype(np.float64)
                 for t, c in zip(target, current)]
        if self._m is None:
            self._m = [np.zeros_like(d) for d in delta]
            self._v = [np.full_like(d, self.tau ** 2) for d in delta]
        self._m = [self.beta1 * m + (1 - self.beta1) * d
                   for m, d in zip(self._m, delta)]
        self._v = [self._second_moment(v, d) for v, d in zip(self._v, delta)]
        new = [c.astype(np.float64)
               + self.server_lr * m / (np.sqrt(v) + self.tau)
               for c, m, v in zip(current, self._m, self._v)]
        return [n.astype(c.dtype) for n, c in zip(new, current)], metrics


@dataclass
class FedAdam(_AdaptiveBase):
    def _second_moment(self, v, d):
        return self.beta2 * v + (1 - self.beta2) * np.square(d)


@dataclass
class FedYogi(_AdaptiveBase):
    def _second_moment(self, v, d):
        d2 = np.square(d)
        return v - (1 - self.beta2) * d2 * np.sign(v - d2)


@dataclass
class FedProx(FedAvg):
    """FedAvg aggregation; clients get proximal_mu in their fit config."""

    proximal_mu: float = 0.01

    def configure_fit(self, rnd, parameters, nodes):
        return {n: FitIns(parameters,
                          {"round": rnd, "proximal_mu": self.proximal_mu})
                for n in nodes}


@dataclass
class FedMedian(FedAvg):
    def aggregate_fit(self, rnd, results, failures, current):
        stacked = [np.median(np.stack([r.parameters[i].astype(np.float64)
                                       for _, r in results]), axis=0)
                   for i in range(len(results[0][1].parameters))]
        return ([s.astype(current[i].dtype) for i, s in enumerate(stacked)],
                {"num_clients": len(results)})


@dataclass
class FedTrimmedMean(FedAvg):
    beta: float = 0.2      # fraction trimmed at each end

    def aggregate_fit(self, rnd, results, failures, current):
        k = int(self.beta * len(results))
        out = []
        for i in range(len(results[0][1].parameters)):
            stack = np.sort(np.stack([r.parameters[i].astype(np.float64)
                                      for _, r in results]), axis=0)
            sl = stack[k:len(results) - k] if len(results) > 2 * k else stack
            out.append(np.mean(sl, axis=0).astype(current[i].dtype))
        return out, {"num_clients": len(results), "trimmed_each_end": k}


@dataclass
class Krum(FedAvg):
    """Multi-Krum (Blanchard et al. 2017): pick the update closest to its
    n-f-2 nearest neighbours; tolerates f byzantine clients."""

    num_byzantine: int = 0
    num_selected: int = 1

    def aggregate_fit(self, rnd, results, failures, current):
        vecs = [np.concatenate([a.astype(np.float64).ravel()
                                for a in r.parameters])
                for _, r in results]
        n = len(vecs)
        f = min(self.num_byzantine, max(0, (n - 3) // 2))
        scores = []
        for i in range(n):
            d = sorted(float(np.sum((vecs[i] - vecs[j]) ** 2))
                       for j in range(n) if j != i)
            scores.append(sum(d[: max(n - f - 2, 1)]))
        chosen = np.argsort(scores)[: max(self.num_selected, 1)]
        sel = [(results[i][1].parameters, results[i][1].num_examples)
               for i in chosen]
        return weighted_average(sel), {"krum_selected": [int(c) for c in chosen]}


def make_strategy(name: str, **kw) -> Strategy:
    table = {"fedavg": FedAvg, "fedavgm": FedAvgM, "fedadam": FedAdam,
             "fedyogi": FedYogi, "fedprox": FedProx, "fedmedian": FedMedian,
             "fedtrimmedmean": FedTrimmedMean, "krum": Krum}
    if name not in table:
        raise KeyError(f"unknown strategy {name!r}; have {sorted(table)}")
    return table[name](**kw)

"""FL strategies (the Flower ecosystem the FLARE side gains access to).

All public APIs still speak ``NDArrays`` (list of numpy arrays), but every
aggregation hot path now runs on :class:`~repro.fl.flat.FlatParams` — one
contiguous buffer per model — through the vectorized kernels in
:mod:`repro.fl.agg_kernels`:

- FedAvg is a cache-blocked weighted sum whose output is **bitwise
  identical** to the seed per-layer loop (see ``legacy.py``);
- FedAvgM / FedAdam / FedYogi keep their server state (velocity, moments)
  as single fp64 vectors and apply fused elementwise updates;
- FedMedian / FedTrimmedMean reduce chunk-stacked (clients, CHUNK) tiles;
- Krum computes all pairwise distances from one chunk-accumulated Gram
  matrix instead of the O(n^2) Python loop.

Strategies also expose :meth:`Strategy.fit_accumulator`, the incremental
aggregation protocol the ServerApp drives: results are folded in (or
referenced zero-copy) as they arrive instead of being stacked into
per-layer Python lists.  Aggregation stays deterministic and
ordering-insensitive up to the sorted client order the ServerApp enforces.

Implemented: FedAvg, FedAvgM (server momentum), FedAdam / FedYogi
(adaptive server optimizers, Reddi et al. 2021), FedProx (proximal client
regularization — the client reads ``config["proximal_mu"]``), robust
aggregation (coordinate-wise median, trimmed mean, Krum).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fl import agg_kernels as kernels
from repro.fl.flat import (QCHUNK, FlatParams, dequantize_int8, quantize_int8,
                           unflatten_vector)
from repro.fl.messages import EvaluateIns, EvaluateRes, FitIns, FitRes

NDArrays = List[np.ndarray]


class QuorumNotMet(RuntimeError):
    """Raised at finalize when fewer successful results arrived than the
    strategy's failure-tolerance knob (``min_available`` /
    ``min_fit_clients``) allows.  Stragglers and dead nodes land in
    ``failures`` and the round continues — unless the quorum breaks."""


def _flat_of(res: FitRes):
    """The FitRes's zero-copy view — FlatParams for raw payloads, the
    still-compressed QuantParams for quantized ones (the kernels stream
    either through the fused ``f64_chunk`` protocol) — packing only if it
    has neither."""
    if res.partial is not None:
        # pre-reduced sums are not per-client updates; strategies that
        # need every update (median/trim/Krum, SecAgg) must not receive
        # them — the ServerApp only requests the edge tier when
        # strategy.supports_partial() says the fold is a weighted sum
        raise ValueError(
            "partial-aggregate result reached a per-client accumulator; "
            "this strategy cannot fold pre-reduced sums")
    if res.sparse is not None:
        # a structured-sparse delta (0xF5); StreamingWeightedSum.add
        # routes it to the O(nnz) scatter fold via its is_sparse attr
        return res.sparse
    if res.flat is not None:
        return res.flat
    if res.quant is not None:
        return res.quant
    return FlatParams.from_arrays(res.parameters)


def _check_shapes(fp, current: NDArrays, node: str) -> None:
    """Reject a result whose tensor shapes don't match the global model.

    Raised at ``add`` time so the ServerApp demotes the byzantine/buggy
    node to a per-node failure instead of crashing in the aggregation
    kernel at finalize (deferred kernels would otherwise surface the
    mismatch rounds of work later, aborting the run)."""
    got = [tuple(leaf.shape) for leaf in fp.layout.leaves]
    want = [tuple(a.shape) for a in current]
    if got != want:
        raise ValueError(
            f"node {node}: result shapes {got} != model shapes {want}")


def weighted_average(results: List[Tuple[NDArrays, float]]) -> NDArrays:
    """Weighted mean of NDArrays lists (flat fast path, legacy-exact)."""
    pairs = [(FlatParams.from_arrays(arrays), w) for arrays, w in results]
    return kernels.weighted_mean(pairs, pairs[0][0].layout).to_arrays()


# ---------------------------------------------------------------------------
# incremental aggregation protocol
# ---------------------------------------------------------------------------
class FitAccumulator:
    """Consumes FitRes one at a time; finalize() yields the new params.

    The base implementation simply collects and defers to the strategy's
    ``aggregate_fit`` — the compatibility path for strategies that only
    implement the batch API.
    """

    def __init__(self, strategy: "Strategy", rnd: int, current: NDArrays):
        self.strategy = strategy
        self.rnd = rnd
        self.current = current
        self.results: List[Tuple[str, FitRes]] = []

    def add(self, node: str, res: FitRes) -> None:
        self.results.append((node, res))

    def finalize(self, failures: List[Tuple[str, str]]
                 ) -> Tuple[NDArrays, Dict[str, Any]]:
        # results may have streamed in arrival order; canonicalize so the
        # aggregate is independent of who finished first (bitwise repro)
        self.results.sort(key=lambda nr: nr[0])
        for _, res in self.results:
            if res.parameters is None:
                # batch-API strategies predate the compressed wire format
                # and read res.parameters directly — honor that contract
                res.materialize()
        return self.strategy.aggregate_fit(self.rnd, self.results, failures,
                                           self.current)


class Strategy:
    def initialize_parameters(self) -> Optional[NDArrays]:
        return None

    def supports_partial(self) -> bool:
        """True when this strategy's fit aggregate is a weighted sum, so
        edge aggregators may pre-reduce their subtree into one
        partial-sum payload (0xF4).  Strategies that need every client's
        update (median/trimmed-mean/Krum, SecAgg) return False — the
        ServerApp then never requests the pre-reduction and edges fall
        back to forwarding a plain weighted-mean result."""
        return False

    def configure_fit(self, rnd: int, parameters: NDArrays,
                      nodes: Sequence[str]) -> Dict[str, FitIns]:
        return {n: FitIns(parameters, {"round": rnd}) for n in nodes}

    def fit_accumulator(self, rnd: int, current: NDArrays) -> FitAccumulator:
        """Incremental aggregation entry point used by the ServerApp."""
        return FitAccumulator(self, rnd, current)

    def aggregate_fit(self, rnd: int, results: List[Tuple[str, FitRes]],
                      failures: List[Tuple[str, str]],
                      current: NDArrays) -> Tuple[NDArrays, Dict[str, Any]]:
        raise NotImplementedError

    def configure_evaluate(self, rnd: int, parameters: NDArrays,
                           nodes: Sequence[str]) -> Dict[str, EvaluateIns]:
        return {n: EvaluateIns(parameters, {"round": rnd}) for n in nodes}

    def aggregate_evaluate(self, rnd: int,
                           results: List[Tuple[str, EvaluateRes]],
                           failures: List[Tuple[str, str]]
                           ) -> Tuple[Optional[float], Dict[str, Any]]:
        if not results:
            return None, {}
        total = sum(r.num_examples for _, r in results)
        loss = sum(r.loss * r.num_examples for _, r in results) / total
        metrics: Dict[str, Any] = {}
        keys = set()
        for _, r in results:
            keys |= set(r.metrics)
        for k in sorted(keys):
            vals = [(r.metrics[k], r.num_examples) for _, r in results
                    if k in r.metrics and isinstance(r.metrics[k], (int, float))]
            if vals:
                metrics[k] = sum(v * n for v, n in vals) / sum(n for _, n in vals)
        return float(loss), metrics


# ---------------------------------------------------------------------------
# FedAvg family (weighted-sum kernel + optional server optimizer)
# ---------------------------------------------------------------------------
class _WeightedFitAcc(FitAccumulator):
    """FedAvg-family accumulator.

    Default mode keeps zero-copy FlatParams references (no per-layer
    stacking; memory is just the already-received payload bytes) and runs
    the bitwise-legacy-exact deferred kernel at finalize.  ``low_memory``
    folds each result into one fp64 accumulator on arrival instead, so
    peak memory is a single model-size vector.
    """

    def __init__(self, strategy: "FedAvg", rnd: int, current: NDArrays):
        super().__init__(strategy, rnd, current)
        self.pairs: List[Tuple[str, FlatParams, float]] = []
        self.partials: List[Tuple[str, Any]] = []   # (node, PartialSum)
        self.sparses: List[Tuple[str, Any, float]] = []  # (node, SparseDelta, w)
        self._streaming: Optional[kernels.StreamingWeightedSum] = None
        self._count = 0
        self._payloads = 0

    def _make_streaming(self, layout) -> kernels.StreamingWeightedSum:
        st = self.strategy
        return kernels.StreamingWeightedSum(
            layout, backend=st.backend, shards=st.shards,
            mesh=st.shard_mesh, overlap=st.overlap_decode)

    def add(self, node: str, res: FitRes) -> None:
        if res.partial is not None:
            # edge-tier pre-reduced sum: buffered and folded in canonical
            # node order at finalize, so the aggregate is independent of
            # which edge finished first.  Counts its whole subtree toward
            # quorum.
            ps = res.partial
            _check_shapes(ps, self.current, node)
            self.partials.append((node, ps))
            self._count += ps.count
            self._payloads += 1
            return
        if res.sparse is not None:
            # structured-sparse delta (0xF5): buffered (O(nnz) bytes)
            # and folded in canonical node order at finalize so the
            # scatter fold is bitwise-invariant across arrival order
            sp = res.sparse
            _check_shapes(sp, self.current, node)
            self.sparses.append((node, sp, float(res.num_examples)))
            self._count += 1
            self._payloads += 1
            return
        fp = _flat_of(res)
        _check_shapes(fp, self.current, node)
        w = float(res.num_examples)
        st = self.strategy
        if st.low_memory or kernels.resolve_shards(st.shards, st.shard_mesh):
            # fold on arrival: order-dependent by <=1 ULP of the fp64
            # accumulator (invisible after the fp32 cast) — documented
            # trade for O(1)-model-size peak memory.  Sharding implies
            # streaming: the per-shard accumulators ARE the low-memory
            # server state.
            if self._streaming is None:
                self._streaming = self._make_streaming(fp.layout)
            self._streaming.add(fp, w)      # payload is droppable after this
        else:
            self.pairs.append((node, fp, w))
        self._count += 1        # only after the fold/append succeeded
        self._payloads += 1

    def finalize(self, failures: List[Tuple[str, str]]
                 ) -> Tuple[NDArrays, Dict[str, Any]]:
        st = self.strategy
        need = st.quorum()
        if self._count < need:
            raise QuorumNotMet(
                f"round {self.rnd}: {self._count} results < quorum "
                f"{need} (failures: {failures})")
        if self.partials or self.sparses:
            # any partial or sparse delta forces the streaming fold (a
            # pre-reduced sum has no per-client rows for the deferred
            # kernel; a sparse delta scatters into the fp64 accumulator):
            # leaves first in canonical node order, then sparse deltas,
            # then partials likewise — one edge over the whole fleet
            # continues the flat low-memory fold bitwise (acc = 0 + S_e;
            # one divide by W), and the sparse scatter is invariant
            # across arrival order by construction
            if self._streaming is None:
                layout = (self.partials[0][1].layout if self.partials
                          else self.sparses[0][1].layout)
                self._streaming = self._make_streaming(layout)
            self.pairs.sort(key=lambda p: p[0])
            for _, fp, w in self.pairs:
                self._streaming.add(fp, w)
            self.pairs = []
            self.sparses.sort(key=lambda s: s[0])
            for _, sp, w in self.sparses:
                self._streaming.add_sparse(sp, w)
            self.sparses = []
            self.partials.sort(key=lambda p: p[0])
            for _, ps in self.partials:
                self._streaming.add_partial(ps)
        if self._streaming is not None:
            target = self._streaming.finalize()
        else:
            # canonical node order -> aggregate independent of arrival order
            self.pairs.sort(key=lambda p: p[0])
            pairs = [(fp, w) for _, fp, w in self.pairs]
            target = kernels.weighted_mean(pairs, pairs[0][0].layout,
                                           backend=st.backend)
        metrics = {"num_clients": self._count,
                   "num_payloads": self._payloads}
        sub_failures = sorted(
            (n, r) for _, ps in self.partials for n, r in ps.failures)
        if sub_failures:
            # subtree failures the edges absorbed, surfaced round-level
            metrics["subtree_failures"] = [list(x) for x in sub_failures]
        return st._server_opt(self.rnd, target, self.current), metrics


@dataclass
class FedAvg(Strategy):
    initial_parameters: Optional[NDArrays] = None
    min_fit_clients: int = 1
    # failure-tolerance knob: how many *successful* results a round needs
    # before finalize may aggregate; the effective quorum is
    # max(min_fit_clients, min_available) (min_fit_clients is the seed
    # API, kept for compatibility).  Robust aggregators set min_available
    # to insist on a quorum — their byzantine tolerance assumes a minimum
    # population (Krum additionally floors it at 2f+3).
    min_available: int = 0
    low_memory: bool = False
    # aggregation kernel backend: "numpy" | "pallas" | None (auto — the
    # Pallas path on TPU hosts, numpy elsewhere; see
    # repro.fl.agg_kernels "Backend dispatch").  ServerConfig.agg_backend
    # sets it fleet-wide without touching strategy construction.
    backend: Optional[str] = None
    # server-state sharding: split the round accumulator (and any FedOpt
    # moments) into this many contiguous qchunk-aligned ranges — each
    # ~1/shards of the single-host fp64 footprint, folded by its own
    # per-shard kernel.  None/0 keeps the single-host reference state.
    # ``shard_mesh`` (a jax Mesh) derives the count from its "data" axis
    # and pins each shard's kernel to the matching device.
    # ServerConfig.agg_shards / shard_mesh set these fleet-wide.
    shards: Optional[int] = None
    shard_mesh: Optional[Any] = None
    # decode/reduce overlap for the sharded streaming fold: None = auto
    # (on for multi-core hosts), True/False forces (see
    # StreamingWeightedSum)
    overlap_decode: Optional[bool] = None

    def quorum(self) -> int:
        return max(self.min_fit_clients, self.min_available, 1)

    def _num_shards(self) -> int:
        return kernels.resolve_shards(self.shards, self.shard_mesh)

    def _shard_bounds(self, total: int):
        from repro.sharding import shard_bounds

        return shard_bounds(total, self._num_shards(), align=QCHUNK)

    def initialize_parameters(self):
        return self.initial_parameters

    def supports_partial(self) -> bool:
        # the weighted-sum pre-reduction is only sound when the fit
        # aggregate IS the weighted sum; a subclass that overrode the
        # batch API gets the conservative default
        return type(self).aggregate_fit is FedAvg.aggregate_fit

    def fit_accumulator(self, rnd, current):
        if type(self).aggregate_fit is not FedAvg.aggregate_fit:
            # subclass overrode the batch API only — honor it
            return FitAccumulator(self, rnd, current)
        return _WeightedFitAcc(self, rnd, current)

    def aggregate_fit(self, rnd, results, failures, current):
        acc = _WeightedFitAcc(self, rnd, current)
        for node, r in results:
            acc.add(node, r)
        return acc.finalize(failures)

    # hook: turn the weighted mean into the next global model
    def _server_opt(self, rnd: int, target: FlatParams,
                    current: NDArrays) -> NDArrays:
        return target.to_arrays()


@dataclass
class FedAvgM(FedAvg):
    server_lr: float = 1.0
    momentum: float = 0.9
    _velocity: Optional[np.ndarray] = field(default=None, repr=False)
    # sharded server state (one velocity vector per shard range) when
    # ``shards``/``shard_mesh`` is set; the update is elementwise, so the
    # sharded result is bitwise the single-vector one
    _shard_vel: Optional[list] = field(default=None, repr=False)

    def _server_opt(self, rnd, target, current):
        if self._num_shards():
            return self._server_opt_sharded(rnd, target, current)
        cur = FlatParams.from_arrays(current, target.layout).to_f64()
        delta = target.to_f64()
        delta -= cur
        if self._velocity is None:
            self._velocity = np.zeros_like(delta)
        self._velocity *= np.float64(self.momentum)
        self._velocity += delta
        cur += np.float64(self.server_lr) * self._velocity
        return unflatten_vector(cur, target.layout)

    def _server_opt_sharded(self, rnd, target, current):
        cur_fp = FlatParams.from_arrays(current, target.layout)
        bounds = self._shard_bounds(target.layout.total_size)
        if self._shard_vel is None:
            self._shard_vel = [np.zeros(hi - lo) for lo, hi in bounds]
        out = np.empty(target.layout.total_size, np.float64)
        mom, lr = np.float64(self.momentum), np.float64(self.server_lr)
        for (lo, hi), vel in zip(bounds, self._shard_vel):
            if hi <= lo:
                continue
            cur = cur_fp.f64_chunk(lo, hi, np.empty(hi - lo))
            delta = target.f64_chunk(lo, hi, np.empty(hi - lo))
            delta -= cur
            vel *= mom
            vel += delta
            cur += lr * vel
            out[lo:hi] = cur
        return unflatten_vector(out, target.layout)


@dataclass
class _AdaptiveBase(FedAvg):
    """Server-side adaptive optimizers (FedOpt family), fused over the
    flat fp64 state vectors.

    With ``shards``/``shard_mesh`` set, the ``_m``/``_v`` moments live as
    one vector per shard range (the same qchunk-aligned partition the
    streaming accumulator uses).  The update is elementwise, so the
    sharded trajectory is **bitwise** the single-vector one —
    ``tests/test_shard_agg.py`` pins it over multiple rounds.
    ``quantize_moments`` additionally stores each shard's moments as
    int8 + per-chunk fp32 scales (the PR 3 quant wire layout): ~1/8 the
    fp64 state footprint, at a per-coordinate error bounded by scale/2
    per round — opt-in for servers where moment memory binds.
    """

    server_lr: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.99
    tau: float = 1e-3
    quantize_moments: bool = False
    _m: Optional[np.ndarray] = field(default=None, repr=False)
    _v: Optional[np.ndarray] = field(default=None, repr=False)
    # per-shard [m, v] state; each entry a fp64 vector or, quantized,
    # an (int8 data, fp32 scales) tuple
    _shard_mv: Optional[list] = field(default=None, repr=False)

    def _second_moment(self, v: np.ndarray, d: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def _load_moment(self, st, n: int, init: float) -> np.ndarray:
        if st is None:
            return np.full(n, init, np.float64)
        if isinstance(st, tuple):
            return dequantize_int8(st[0], st[1], QCHUNK)
        return st

    def _store_moment(self, vec: np.ndarray):
        if self.quantize_moments:
            return quantize_int8(vec)
        return vec

    def _server_opt(self, rnd, target, current):
        if self._num_shards():
            return self._server_opt_sharded(rnd, target, current)
        cur = FlatParams.from_arrays(current, target.layout).to_f64()
        d = target.to_f64()
        d -= cur
        if self._m is None:
            self._m = np.zeros_like(d)
            self._v = np.full_like(d, self.tau ** 2)
        self._m *= np.float64(self.beta1)
        self._m += np.float64(1 - self.beta1) * d
        self._v = self._second_moment(self._v, d)
        cur += np.float64(self.server_lr) * self._m \
            / (np.sqrt(self._v) + np.float64(self.tau))
        return unflatten_vector(cur, target.layout)

    def _server_opt_sharded(self, rnd, target, current):
        cur_fp = FlatParams.from_arrays(current, target.layout)
        bounds = self._shard_bounds(target.layout.total_size)
        if self._shard_mv is None:
            self._shard_mv = [[None, None] for _ in bounds]
        out = np.empty(target.layout.total_size, np.float64)
        b1 = np.float64(self.beta1)
        lr, tau = np.float64(self.server_lr), np.float64(self.tau)
        for (lo, hi), st in zip(bounds, self._shard_mv):
            if hi <= lo:
                continue
            n = hi - lo
            cur = cur_fp.f64_chunk(lo, hi, np.empty(n))
            d = target.f64_chunk(lo, hi, np.empty(n))
            d -= cur
            m = self._load_moment(st[0], n, 0.0)
            v = self._load_moment(st[1], n, self.tau ** 2)
            m *= b1
            m += np.float64(1 - self.beta1) * d
            v = self._second_moment(v, d)
            st[0] = self._store_moment(m)
            st[1] = self._store_moment(v)
            cur += lr * m / (np.sqrt(v) + tau)
            out[lo:hi] = cur
        return unflatten_vector(out, target.layout)


@dataclass
class FedAdam(_AdaptiveBase):
    def _second_moment(self, v, d):
        return np.float64(self.beta2) * v \
            + np.float64(1 - self.beta2) * np.square(d)


@dataclass
class FedYogi(_AdaptiveBase):
    def _second_moment(self, v, d):
        d2 = np.square(d)
        return v - np.float64(1 - self.beta2) * d2 * np.sign(v - d2)


@dataclass
class FedProx(FedAvg):
    """FedAvg aggregation; clients get proximal_mu in their fit config."""

    proximal_mu: float = 0.01

    def configure_fit(self, rnd, parameters, nodes):
        return {n: FitIns(parameters,
                          {"round": rnd, "proximal_mu": self.proximal_mu})
                for n in nodes}


# ---------------------------------------------------------------------------
# robust aggregation (stacked-tile kernels)
# ---------------------------------------------------------------------------
class _StackedFitAcc(FitAccumulator):
    """Keeps zero-copy flat references; finalize hands them to the
    strategy's stacked kernel in one call."""

    def __init__(self, strategy, rnd, current):
        super().__init__(strategy, rnd, current)
        self.entries: List[Tuple[str, FlatParams, float]] = []

    def add(self, node, res):
        if res.sparse is not None:
            # median/trim/Krum need every client's dense update row;
            # negotiation never picks "sparse" for these strategies
            # (supports_partial() is False), so a sparse arrival here is
            # a protocol violation — demote the node, don't misfold
            raise ValueError(
                "sparse-delta result reached a stacked accumulator; "
                "this strategy needs dense per-client updates")
        fp = _flat_of(res)
        _check_shapes(fp, self.current, node)
        self.entries.append((node, fp, float(res.num_examples)))

    def finalize(self, failures):
        need = self.strategy.quorum()
        if len(self.entries) < need:
            raise QuorumNotMet(
                f"round {self.rnd}: {len(self.entries)} results < quorum "
                f"{need} (failures: {failures})")
        # canonical node order -> aggregate independent of arrival order
        self.entries.sort(key=lambda e: e[0])
        nodes = [n for n, _, _ in self.entries]
        flats = [fp for _, fp, _ in self.entries]
        weights = [w for _, _, w in self.entries]
        return self.strategy._aggregate_flats(self.rnd, flats, weights,
                                              failures, nodes)


class _StackedStrategyMixin:
    def supports_partial(self) -> bool:
        return False    # median/trim/Krum need every client's update

    def fit_accumulator(self, rnd, current):
        return _StackedFitAcc(self, rnd, current)

    def aggregate_fit(self, rnd, results, failures, current):
        acc = _StackedFitAcc(self, rnd, current)
        for node, r in results:
            acc.add(node, r)
        return acc.finalize(failures)


@dataclass
class FedMedian(_StackedStrategyMixin, FedAvg):
    def _aggregate_flats(self, rnd, flats, weights, failures, nodes=None):
        out = kernels.median(flats, flats[0].layout, backend=self.backend)
        return out.to_arrays(), {"num_clients": len(flats)}


@dataclass
class FedTrimmedMean(_StackedStrategyMixin, FedAvg):
    beta: float = 0.2      # fraction trimmed at each end

    def _aggregate_flats(self, rnd, flats, weights, failures, nodes=None):
        k = int(self.beta * len(flats))
        out = kernels.trimmed_mean(flats, flats[0].layout, k,
                                   backend=self.backend)
        return out.to_arrays(), {"num_clients": len(flats),
                                 "trimmed_each_end": k}


@dataclass
class Krum(_StackedStrategyMixin, FedAvg):
    """Multi-Krum (Blanchard et al. 2017): pick the update closest to its
    n-f-2 nearest neighbours; tolerates f byzantine clients."""

    num_byzantine: int = 0
    num_selected: int = 1

    def quorum(self) -> int:
        # Krum's tolerance of f byzantine clients assumes n >= 2f + 3
        # (Blanchard et al. 2017).  Under partial participation the round
        # must abort (QuorumNotMet) rather than silently clamp f and let a
        # byzantine survivor be selected.
        floor = 2 * self.num_byzantine + 3 if self.num_byzantine else 1
        return max(super().quorum(), floor)

    def _aggregate_flats(self, rnd, flats, weights, failures, nodes=None):
        layout = flats[0].layout
        D = kernels.krum_distances(flats, layout, backend=self.backend)
        scores = kernels.krum_scores(D, self.num_byzantine)
        chosen = np.argsort(scores)[: max(self.num_selected, 1)]
        sel = [(flats[i], weights[i]) for i in chosen]
        out = kernels.weighted_mean(sel, layout, backend=self.backend)
        # report node ids, not positions: positions depend on arrival order
        picked = ([nodes[i] for i in chosen] if nodes is not None
                  else [int(c) for c in chosen])
        return out.to_arrays(), {"krum_selected": picked}


def make_strategy(name: str, **kw) -> Strategy:
    table = {"fedavg": FedAvg, "fedavgm": FedAvgM, "fedadam": FedAdam,
             "fedyogi": FedYogi, "fedprox": FedProx, "fedmedian": FedMedian,
             "fedtrimmedmean": FedTrimmedMean, "krum": Krum}
    if name not in table:
        raise KeyError(f"unknown strategy {name!r}; have {sorted(table)}")
    return table[name](**kw)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input shape) step function against
the production meshes — 16x16 single pod and 2x16x16 multi-pod — with
ShapeDtypeStruct inputs only (no allocation: the 236B model never
materializes a weight).  Prints ``memory_analysis()`` (fits/doesn't fit)
and ``cost_analysis()`` (FLOPs/bytes for §Roofline), parses the compiled
HLO for collective bytes, and appends one JSON record per run to --out.

Usage:
  python -m repro.launch.dryrun --arch h2o-danube-1.8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
  python -m repro.launch.dryrun --arch X --shape train_4k --fl-round  # tight FL
"""
import argparse
import json
import re
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shlib
from repro.config import (INPUT_SHAPES, InputShape, TrainConfig,
                          get_model_config, list_archs, shape_supported)
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.shardings import (batch_shardings, cache_shardings,
                                    rules_for_shape, state_shardings)
from repro.models.api import build_model
from repro.train.steps import (abstract_train_state, make_decode_step,
                               make_prefill_step, make_train_step)

HBM_PER_CHIP = 16e9   # v5e

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s64": 8,
                "u64": 8, "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """name -> body text, for every HLO computation block.

    A computation header is a column-0 line ending in "{"; the name is its
    first %token (headers may contain nested parens in tuple-typed params,
    so no attempt to parse the signature)."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.search(r"%([\w\.\-]+)", line) or \
                re.search(r"ENTRY\s+([\w\.\-]+)", line)
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(1) if m else f"_anon{len(comps)}"
            cur_lines = [line]
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _loop_multipliers(hlo_text: str, comps: Dict[str, str]) -> Dict[str, float]:
    """computation name -> execution count (scan bodies execute trip times).

    HLO cost analysis counts while bodies once; we recover trip counts from
    each while's condition (compare against a constant) and propagate
    multiplicatively through nested loops via the call graph.
    """
    mult: Dict[str, float] = {}
    whiles = []   # (enclosing_comp, body_name, cond_name)
    for cname, body in comps.items():
        for m in re.finditer(r"while\((?:[^)]*)\).*?condition=%?([\w\.\-_]+).*?"
                             r"body=%?([\w\.\-_]+)", body):
            whiles.append((cname, m.group(2), m.group(1)))
        for m in re.finditer(r"body=%?([\w\.\-_]+).*?condition=%?([\w\.\-_]+)",
                             body):
            whiles.append((cname, m.group(1), m.group(2)))

    def trip_of(cond_name: str) -> float:
        cond = comps.get(cond_name, "")
        consts = [int(x) for x in re.findall(r"constant\((\d+)\)", cond)]
        return float(max(consts)) if consts else 1.0

    # iterate to fixpoint over nesting (bounded depth)
    for _ in range(4):
        for encl, body_name, cond_name in whiles:
            base = mult.get(encl, 1.0)
            mult[body_name] = base * trip_of(cond_name)
    return mult


_CONVERT_RE = re.compile(
    r"%wrapped_convert[\w\.]*\s*=\s*f32\[([0-9,]+)\]\S*\s+fusion\(")


def cpu_convert_artifact_bytes(hlo_text: str) -> float:
    """bf16->f32 whole-tensor converts inserted by the CPU backend's dot
    legalization (hoisted out of scans).  TPU MXUs consume bf16 operands
    directly, so these buffers do not exist on the target hardware — the
    dry-run subtracts them from the fits-in-HBM estimate (and records them).
    """
    total = 0.0
    for m in _CONVERT_RE.finditer(hlo_text):
        n = 1
        for tok in m.group(1).split(","):
            if tok:
                n *= int(tok)
        b = n * 4
        if b >= 256e6:            # only whole-cache/weight scale converts
            total += b
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective bytes with loop-trip correction.

    Also records "_f32_bytes": the share carried at f32.  The CPU backend
    legalizes bf16 dots by converting operands to f32 *before* the
    surrounding collectives, so residual/weight gathers that move bf16 on
    TPU are measured here at 2x — the roofline uses the bf16-adjusted total
    (f32 share halved) and keeps the raw numbers in the record."""
    comps = _split_computations(hlo_text)
    mults = _loop_multipliers(hlo_text, comps)
    out: Dict[str, float] = {}
    f32b = 0.0
    for cname, body in comps.items():
        k = mults.get(cname, 1.0)
        for m in _COLL_RE.finditer(body):
            dt, shape_s, op = m.groups()
            n = 1
            if shape_s:
                for tok in shape_s.split(","):
                    if tok:
                        n *= int(tok)
            b = k * n * _DTYPE_BYTES.get(dt, 4)
            out[op] = out.get(op, 0.0) + b
            if dt == "f32":
                f32b += b
    out["_f32_bytes"] = f32b
    return out


def adjusted_collective_total(coll: Dict[str, float]) -> float:
    raw = sum(v for k, v in coll.items() if not k.startswith("_"))
    return raw - 0.5 * coll.get("_f32_bytes", 0.0)


def roofline_terms(flops: float, hbm_bytes: float, coll: Dict[str, float],
                   ici_links: int = 4) -> Dict[str, float]:
    """Per-device seconds for each roofline term (v5e constants)."""
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hbm_bytes / HBM_BW
    t_coll = adjusted_collective_total(coll) / (ICI_BW * ici_links)
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dom}


def model_flops(cfg, shape: InputShape) -> float:
    """6*N_active*D for train; 2*N_active*D for inference (per step)."""
    model = build_model(cfg)
    n_active = model.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # decode: 1 token each


def build_step(arch: str, shape: InputShape, mesh, fl_round: bool = False):
    """Returns (jitted_fn, example_args_abstract) ready to .lower()."""
    cfg = get_model_config(arch)
    if shape.kind in ("prefill", "decode"):
        # serving runs bf16 weights (no fp32 master / optimizer resident)
        cfg = cfg.replace(param_dtype="bfloat16")
    model = build_model(cfg)
    rules = rules_for_shape(shape)
    # §Perf iteration E: grad-accumulation for the archs whose activations
    # exceed HBM at global_batch 256 (values from the hillclimb log)
    micro = {"deepseek-v2-236b": 4, "recurrentgemma-2b": 2}.get(arch, 1)
    train_cfg = TrainConfig(global_batch=shape.global_batch,
                            seq_len=shape.seq_len, optimizer="adamw",
                            microbatches=micro if shape.kind == "train" else 1)

    if fl_round:
        from repro.core.collective import make_fl_round_step, pod_stacked_state

        # state is pod-stacked (leading num_pods dim = the site axis); the
        # vmapped local steps see only ("data","model") — no pod constraint
        shlib.set_activation_mesh(mesh, batch_axes=("data",))
        n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
        k_local = 4
        step = make_fl_round_step(model, train_cfg, mesh, local_steps=k_local)
        state = pod_stacked_state(abstract_train_state(model, train_cfg),
                                  n_pods)
        batch = model.input_struct(shape)
        # (pods, K, B/pods, ...) — each pod trains on its own site's stream
        batches = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (n_pods, k_local, s.shape[0] // n_pods) + s.shape[1:],
                s.dtype), batch)
        base_sh = state_shardings(model, train_cfg, mesh, rules=rules)
        st_sh = jax.tree.map(
            lambda ns: NamedSharding(mesh, P(
                "pod" if "pod" in mesh.axis_names else None, *ns.spec)),
            base_sh)
        bspec = {k: NamedSharding(mesh, P(
            "pod" if "pod" in mesh.axis_names else None, None, "data"))
            for k in batches}
        fn = jax.jit(step, in_shardings=(st_sh, bspec),
                     out_shardings=(st_sh, None), donate_argnums=(0,))
        return fn, (state, batches)

    if shape.kind == "train":
        step = make_train_step(model, train_cfg)
        state = abstract_train_state(model, train_cfg)
        batch = model.input_struct(shape)
        st_sh = state_shardings(model, train_cfg, mesh, rules=rules)
        b_sh = batch_shardings(batch, mesh, rules=rules)
        fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None), donate_argnums=(0,))
        return fn, (state, batch)

    if shape.kind == "prefill":
        step = make_prefill_step(model, max_len=shape.seq_len)
        params = model.abstract()
        batch = model.input_struct(shape)
        from repro.launch.shardings import params_shardings

        p_sh = params_shardings(model, mesh, rules=rules)
        b_sh = batch_shardings(batch, mesh, rules=rules)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh))
        return fn, (params, batch)

    # decode
    step = make_decode_step(model)
    params = model.abstract()
    from repro.launch.shardings import params_shardings

    p_sh = params_shardings(model, mesh, rules=rules)
    c_sh, cache = cache_shardings(model, shape.global_batch, shape.seq_len,
                                  mesh, rules=rules)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_sh = batch_shardings({"tokens": tokens}, mesh, rules=rules)["tokens"]
    fn = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                 out_shardings=(None, c_sh), donate_argnums=(1,))
    return fn, (params, cache, tokens)


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            fl_round: bool = False, verbose: bool = True) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    cfg = get_model_config(arch)
    ok, why = shape_supported(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "fl_round": fl_round,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["skip_reason"] = why
        return rec
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    shlib.clear_fallbacks()
    rules = rules_for_shape(shape)
    try:
        shlib.set_activation_mesh(mesh, batch_axes=tuple(
            a for a in rules["batch"] if a in mesh.axis_names))
        with mesh:
            fn, args = build_step(arch, shape, mesh, fl_round=fl_round)
            lowered = fn.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
    except Exception as e:  # noqa: BLE001
        rec["status"] = "FAILED"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        if verbose:
            print(json.dumps(rec, indent=1)[:3000], file=sys.stderr)
        return rec
    finally:
        shlib.set_activation_mesh(None)

    n_dev = int(np.prod(mesh.devices.shape))
    model_axis = mesh.devices.shape[-1]
    coll = collective_bytes(hlo)
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    peak = arg_b + tmp_b + out_b - alias_b
    cpu_artifact = cpu_convert_artifact_bytes(hlo)
    peak_tpu = max(peak - cpu_artifact, arg_b)
    mf = model_flops(cfg, shape)

    from repro.launch.analytic import estimate

    est = estimate(cfg, shape, n_dev, model_axis=model_axis)
    k_round = 4 if fl_round else 1          # fl-round = K local steps
    terms = roofline_terms(k_round * est.flops_per_device,
                           k_round * est.hbm_bytes_per_device, coll)

    rec.update({
        "status": "ok",
        "devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            # raw HLO cost analysis (NB: XLA counts scan bodies once —
            # see launch/analytic.py; analytic numbers drive the roofline)
            "hlo_flops_raw": hlo_flops,
            "hlo_bytes_accessed_raw": hlo_bytes,
            "analytic_flops": est.flops_per_device,
            "analytic_hbm_bytes": est.hbm_bytes_per_device,
            "argument_bytes": arg_b,
            "temp_bytes": tmp_b,
            "output_bytes": out_b,
            "alias_bytes": alias_b,
            "peak_bytes_est": peak,
            "cpu_convert_artifact_bytes": cpu_artifact,
            "peak_bytes_tpu_est": peak_tpu,
            "fits_16GB": bool(peak_tpu <= HBM_PER_CHIP),
            "collective_bytes": coll,
        },
        "roofline": terms,
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_frac": ((mf / n_dev) / est.flops_per_device
                              if est.flops_per_device else None),
        "sharding_fallbacks": dict(shlib.FALLBACKS),
    })
    if verbose:
        print(f"[{arch} x {shape_name} x {rec['mesh']}"
              f"{' fl-round' if fl_round else ''}] "
              f"compile={t_compile:.0f}s aflops/dev={est.flops_per_device:.3g} "
              f"abytes/dev={est.hbm_bytes_per_device:.3g} "
              f"peak={peak/1e9:.2f}GB tpu~{peak_tpu/1e9:.2f}GB "
              f"fits={rec['per_device']['fits_16GB']} "
              f"coll={ {k: f'{v:.3g}' for k, v in coll.items()} } "
              f"dom={terms['dominant']} "
              f"useful={rec['useful_flops_frac'] and round(rec['useful_flops_frac'],2)}",
              flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fl-round", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    runs = []
    if args.all:
        pairs = [(a, s) for a in list_archs() if a != "flower-quickstart"
                 for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape in pairs:
        for mp in meshes:
            rec = run_one(arch, shape, multi_pod=mp, fl_round=args.fl_round)
            runs.append(rec)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    n_fail = sum(r["status"] == "FAILED" for r in runs)
    n_ok = sum(r["status"] == "ok" for r in runs)
    n_skip = sum(r["status"] == "skipped" for r in runs)
    print(f"dry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Roofline report generator (deliverable g).

Reads the dry-run JSONL (launch/dryrun.py --out) and emits the §Roofline
table: per (arch x shape x mesh) the three terms
    compute    = analytic_FLOPs/dev / 197 TF
    memory     = analytic_HBM_bytes/dev / 819 GB/s
    collective = HLO collective bytes/dev / (4 x 50 GB/s ICI)
plus the dominant term, MODEL_FLOPS/HLO ratio ("useful fraction"), memory
fit, and a one-line "what would move the dominant term" suggestion.

Usage: python -m repro.launch.roofline --in dryrun_results.jsonl [--md]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

SUGGEST = {
    ("compute",): "raise arithmetic intensity: bigger per-device batch or "
                  "reduce remat recompute; already near the right regime for "
                  "training",
    ("memory",): "cut HBM traffic: fuse elementwise chains (flash kernel), "
                 "quantize KV cache to int8, or larger decode batch to "
                 "amortize weight reads",
    ("collective",): "cut bytes on the wire: avoid FSDP regathers "
                     "(weight-stationary layout), overlap collectives with "
                     "compute, or int8-compress the FL round all-reduce",
}


def load(path: str) -> List[Dict]:
    recs = [json.loads(l) for l in open(path)]
    last = {}
    for r in recs:               # keep the LAST record per key (post-fix runs)
        last[(r["arch"], r["shape"], r["mesh"], r.get("fl_round", False))] = r
    return [last[k] for k in sorted(last)]


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1.0:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def make_table(recs: List[Dict], md: bool = True) -> str:
    head = ["arch", "shape", "mesh", "t_compute", "t_memory", "t_collective",
            "dominant", "useful", "peak(TPU)GB", "fits16GB"]
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            rows.append([r["arch"], r["shape"], r["mesh"], "-", "-", "-",
                         f"SKIP: {r['skip_reason'][:36]}", "-", "-", "-"])
            continue
        if r["status"] != "ok":
            rows.append([r["arch"], r["shape"], r["mesh"], "-", "-", "-",
                         "FAILED", "-", "-", "-"])
            continue
        t = r["roofline"]
        pd = r["per_device"]
        uf = r.get("useful_flops_frac")
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            fmt_s(t["t_compute_s"]), fmt_s(t["t_memory_s"]),
            fmt_s(t["t_collective_s"]), t["dominant"],
            f"{uf:.2f}" if uf else "-",
            f"{pd.get('peak_bytes_tpu_est', pd['peak_bytes_est'])/1e9:.1f}",
            "Y" if pd.get("fits_16GB") else "N",
        ])
    if md:
        out = ["| " + " | ".join(head) + " |",
               "|" + "---|" * len(head)]
        out += ["| " + " | ".join(str(c) for c in row) + " |" for row in rows]
        return "\n".join(out)
    w = [max(len(str(r[i])) for r in rows + [head]) for i in range(len(head))]
    lines = ["  ".join(str(h).ljust(w[i]) for i, h in enumerate(head))]
    lines += ["  ".join(str(c).ljust(w[i]) for i, c in enumerate(row))
              for row in rows]
    return "\n".join(lines)


def summarize(recs: List[Dict]) -> str:
    out = []
    ok = [r for r in recs if r["status"] == "ok"]
    for dom in ("compute", "memory", "collective"):
        sub = [r for r in ok if r["roofline"]["dominant"] == dom]
        out.append(f"{dom}-bound: {len(sub)} pairs")
    worst = sorted(ok, key=lambda r: (r.get("useful_flops_frac") or 1.0))[:3]
    out.append("lowest useful-FLOPs fraction: " + ", ".join(
        f"{r['arch']}x{r['shape']}x{r['mesh']}"
        f"({(r.get('useful_flops_frac') or 0):.2f})" for r in worst))
    collbound = sorted(ok, key=lambda r: -r["roofline"]["t_collective_s"])[:3]
    out.append("largest collective term: " + ", ".join(
        f"{r['arch']}x{r['shape']}x{r['mesh']}"
        f"({fmt_s(r['roofline']['t_collective_s'])})" for r in collbound))
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="dryrun_results.jsonl")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 or 2x16x16")
    args = ap.parse_args()
    recs = load(args.inp)
    if args.mesh:
        recs = [r for r in recs if r["mesh"] == args.mesh]
    print(make_table(recs, md=args.md))
    print()
    print(summarize(recs))


if __name__ == "__main__":
    main()

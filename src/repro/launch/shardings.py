"""Sharding-tree construction for step functions.

Maps every leaf of (TrainState | params | batch | cache) to a NamedSharding
via the logical-axis rules in ``repro.sharding``.  Cache leaves get their
logical axes from their key name + rank (the cache layout is defined by
``transformer.init_cache`` / ``encdec.init_cache``).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import InputShape
from repro.models.api import Model
from repro.optim.optimizers import AdamState
from repro.sharding import DEFAULT_RULES, spec_for
from repro.train.steps import TrainState


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def params_shardings(model: Model, mesh: Mesh, rules=None):
    axes = model.axes()
    abstract = model.abstract()
    fsdp = model.cfg.fsdp_hint
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msz = sizes.get("model", 1)

    def one(ax, leaf):
        spec = spec_for(ax, leaf.shape, mesh, rules=rules, fsdp=fsdp,
                        name="param")
        # salvage pass: a big weight whose every rule-assigned dim fell back
        # (e.g. Yi's 56 heads on model=16) would be fully replicated — shard
        # its largest model-divisible dim instead (§Perf iteration D:
        # replicated q/o projections cost yi-34b decode +12GB/device).
        # The byte estimate uses the leaf's own itemsize: a hard-coded
        # bf16 "* 2" made fp32/fp64 params dodge or mis-trigger the 8 MB
        # replication threshold.
        if (all(e is None for e in spec)
                and leaf.size * leaf.dtype.itemsize >= 8e6
                and msz > 1):
            cand = [i for i, d in enumerate(leaf.shape) if d % msz == 0]
            if cand:
                best = max(cand, key=lambda i: leaf.shape[i])
                entries = [None] * len(leaf.shape)
                entries[best] = "model"
                from jax.sharding import PartitionSpec as _P

                spec = _P(*entries)
        return _named(mesh, spec)

    return jax.tree.map(one, axes, abstract,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def state_shardings(model: Model, train_cfg, mesh: Mesh, rules=None):
    """TrainState sharding: opt-state moments shard like their params."""
    p_shard = params_shardings(model, mesh, rules=rules)
    from repro.optim import make_optimizer

    opt = make_optimizer(train_cfg)
    opt_state = jax.eval_shape(opt.init, model.abstract())

    if isinstance(opt_state, AdamState):
        opt_shard = AdamState(mu=p_shard, nu=p_shard)
    elif opt_state == ():
        opt_shard = ()
    else:
        # adafactor/momentum: factored dims — replicate conservative fallback
        opt_shard = jax.tree.map(lambda _: _named(mesh, P()), opt_state)
    return TrainState(params=p_shard, opt_state=opt_shard,
                      step=_named(mesh, P()))


# ---------------------------------------------------------------------------
# batch + cache
# ---------------------------------------------------------------------------
def batch_shardings(struct: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh,
                    rules=None) -> Dict[str, NamedSharding]:
    out = {}
    for k, v in struct.items():
        ax = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = _named(mesh, spec_for(ax, v.shape, mesh, rules=rules,
                                       name=f"batch.{k}"))
    return out


_CACHE_AXES_BY_KEY = {
    # name -> logical axes WITHOUT the leading stack dim (added by rank)
    "k": ("batch", "cache_seq", "kv_heads", None),
    "v": ("batch", "cache_seq", "kv_heads", None),
    "slot_pos": ("batch", "cache_seq"),
    "ckv": ("batch", "cache_seq", None),
    "kpe": ("batch", "cache_seq", None),
    "h": ("batch", "mlp"),
    "conv": ("batch", None, "mlp"),
    # mLSTM matrix state: shard the dk dim over "model" ("mlp" rule) — it
    # must match the (salvaged) wq/wk sharding or every decode layer
    # regathers the full (H,dk,dv) state (§Perf iteration B: 1.1e8 B/step)
    "C": ("batch", "heads", "mlp", None),
    "n": ("batch", "heads", "mlp"),
    "m": ("batch", "heads"),
    "sc": ("batch", None),
    "sn": ("batch", None),
    "sm": ("batch", None),
    "sh": ("batch", None),
    "pos": ("batch",),
    "cross_k": ("batch", None, "heads", None),
    "cross_v": ("batch", None, "heads", None),
}
# sLSTM uses c/n/m/h at rank 2 with plain (batch, d) — the table above
# already matches by name; "n"/"m" for sLSTM get ("batch","heads")/... which
# fall back to replication when indivisible, which is fine.


def cache_shardings(model: Model, batch: int, max_len: int, mesh: Mesh,
                    rules=None):
    cache = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    shardings = []
    for path, leaf in flat:
        key = None
        for p in reversed(path):
            if hasattr(p, "key"):
                key = p.key
                break
        base = _CACHE_AXES_BY_KEY.get(key, ())
        ax = list(base)
        # pad/trim to rank: leading extra dims are layer-stack dims
        while len(ax) < len(leaf.shape):
            ax.insert(0, None)
        ax = ax[-len(leaf.shape):] if len(ax) > len(leaf.shape) else ax
        shardings.append(_named(mesh, spec_for(
            ax, leaf.shape, mesh, rules=rules, name=f"cache.{key}")))
    return jax.tree_util.tree_unflatten(treedef, shardings), cache


def rules_for_shape(shape: InputShape) -> Dict[str, Tuple[str, ...]]:
    """Shape-dependent rule overrides (DESIGN.md §5)."""
    rules = dict(DEFAULT_RULES)
    if shape.name == "long_500k":
        # batch=1: sequence-parallel cache (flash-decoding style); batch
        # stays on pod only
        rules["batch"] = ("pod",)
        rules["cache_seq"] = ("data",)
    elif shape.kind == "decode":
        # decode_32k: GQA kv counts (1/8) cannot shard over model=16, so the
        # 0.5TB cache shards its sequence dim there (flash-decoding): scores
        # reduce over the sharded seq via a small per-step all-reduce
        rules["cache_seq"] = ("model",)
    else:
        rules["cache_seq"] = ()
    if shape.kind == "decode":
        # weight-stationary serving: per-step FSDP all-gathers of the whole
        # model dominated decode (observed 69GB/step gathers); dense weights
        # live TP-sharded on "model" only, expert banks stay FSDP over
        # "data" (gathered per scanned layer — they cannot fit otherwise)
        rules["embed"] = ()
        rules["embed_expert"] = ("data",)
    return rules

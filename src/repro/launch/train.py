"""Training launcher.

Runs real training (CPU-scale here; the same code jits with the production
mesh on TPU): builds the model from ``--arch``, a synthetic data pipeline,
the jitted train step with mesh shardings, checkpointing, and optional
FL-round structure (``--fl-sites`` maps sites onto data-parallel groups in
simulation).

Example (the (b) end-to-end driver at ~100M scale):
  PYTHONPATH=src python -m repro.launch.train --arch flower-quickstart \\
      --steps 200 --batch 8 --seq 256 --d-model 512 --layers 8
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.config import TrainConfig, get_model_config
from repro.data.loader import FederatedDataLoader
from repro.models import build_model
from repro.train.steps import make_train_state, make_train_step


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flower-quickstart")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch, smoke=args.smoke)
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model,
                          head_dim=args.d_model // cfg.num_heads)
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M")

    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                       learning_rate=args.lr, warmup_steps=args.steps // 10,
                       total_steps=args.steps, seed=args.seed)
    state = make_train_state(model, tcfg, jax.random.key(args.seed))
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    loader = FederatedDataLoader(cfg.vocab_size, args.seq, num_sites=1,
                                 batch_per_site=args.batch, seed=args.seed)
    t0 = time.perf_counter()
    tokens_done = 0
    for step in range(start, args.steps):
        batch = loader.next_batch(0)
        state, metrics = step_fn(state, batch)
        tokens_done += args.batch * args.seq
        if (step + 1) % args.log_every == 0:
            dt = time.perf_counter() - t0
            print(f"step {step+1:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"tok/s {tokens_done/dt:,.0f}", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, state)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
    print("done:", float(metrics["loss"]))


if __name__ == "__main__":
    main()

"""Production mesh definitions (TPU v5e).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis doubles
as the federated-site axis in tight-mode FL (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests on CPU)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_agg_mesh(shards: int):
    """1-D ("data",) mesh for the sharded server aggregation state.

    The FL server's flat fp64 accumulator and FedOpt moments split into
    ``shards`` contiguous ranges over this axis (see
    :func:`repro.sharding.shard_bounds`); each range's fused
    decode+scale+accumulate kernel is pinned to the matching device.  On
    CPU CI the devices are simulated with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    return jax.make_mesh((shards,), ("data",))

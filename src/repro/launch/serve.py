"""Serving launcher: batched prefill + decode loop.

CPU-scale demo of the serving path the decode dry-run shapes lower: a
request queue is batched, prefilled once, then decoded token-by-token with
the KV cache / recurrent state.  ``--arch`` selects any registered
architecture (smoke variant by default — full configs only lower on the
production mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_model_config
from repro.models import build_model
from repro.train.steps import make_decode_step, make_prefill_step


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    max_len = args.prompt_len + args.new_tokens

    prefill = jax.jit(make_prefill_step(model, max_len=max_len))
    decode = jax.jit(make_decode_step(model))

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                    (args.batch, args.prompt_len),
                                    dtype=np.int32)}
    if cfg.frontend == "vision":
        batch["extra_embeds"] = rng.normal(size=(
            args.batch, cfg.num_prefix_tokens, cfg.d_model)).astype(np.float32)
    if cfg.is_encoder_decoder:
        batch["audio_embeds"] = rng.normal(size=(
            args.batch, cfg.encoder_seq or 32, cfg.d_model)).astype(np.float32)

    t0 = time.perf_counter()
    last, cache = prefill(params, batch)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(last[..., : cfg.vocab_size], -1)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        last, cache = decode(params, cache, tok)
        tok = jnp.argmax(last[..., : cfg.vocab_size], -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    toks = np.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode/max(args.new_tokens-1,1)*1e3:.2f} ms/token")
    print("generated ids (req 0):", toks[0].tolist())


if __name__ == "__main__":
    main()

"""Analytic FLOP / HBM-byte models per (arch, shape) — the napkin math.

Why this exists: XLA's HLO cost analysis counts a ``while`` (scan) body
ONCE, so for an L-layer scanned trunk ``compiled.cost_analysis()``
under-reports per-step FLOPs/bytes by ~L×.  The §Roofline compute/memory
terms therefore come from these first-principles models (documented
formulas below), while the dry-run records raw HLO numbers alongside for
cross-checking (they should be ≈ analytic/L-ish) and parses collectives
with explicit loop-trip correction.

Conventions:
- FLOPs: one MAC = 2 FLOPs.  backward = 2x forward (grad wrt params +
  activations); train = 3x forward of the token stream.
- "per device": tokens divide over (pod x data); matmul work divides over
  "model" when the corresponding dim is sharded (we apply the model-axis
  division globally — correct for every sharded dim, slightly optimistic
  for the few replicated-attention archs, noted per-arch in fallbacks).
- HBM bytes (per device, per step): weight traffic (bf16 reads fwd+bwd,
  fp32 optimizer read+write) + activation traffic (remat: ~2x writes+reads
  of layer I/O) + KV-cache traffic for decode.  These are lower-bound-style
  estimates; their role is to rank the three roofline terms, not to be
  exact to the percent.
"""
from __future__ import annotations

from dataclasses import dataclass


from repro.config import (ATTN, LOCAL_ATTN, MLA, MLSTM, RGLRU, SLSTM, SWA,
                          InputShape, ModelConfig)


def _attn_ctx(kind: str, cfg: ModelConfig, seq: int, decode: bool) -> float:
    """Average attended context length per token."""
    if kind in (SWA, LOCAL_ATTN) and cfg.window:
        return float(min(cfg.window, seq)) if decode else min(cfg.window, seq / 2)
    return float(seq) if decode else seq / 2.0


def layer_flops_per_token(cfg: ModelConfig, kind: str, moe_layer: bool,
                          seq: int, decode: bool) -> float:
    d, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ctx = _attn_ctx(kind, cfg, seq, decode)
    fl = 0.0
    if kind == MLA:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        fl += 2 * d * m.q_lora_rank + 2 * m.q_lora_rank * H * qk
        fl += 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim)
        if decode:
            # absorbed: scores against the latent cache directly
            fl += 2 * m.kv_lora_rank * H * m.qk_nope_head_dim          # q absorb
            fl += 2 * ctx * H * (m.kv_lora_rank + m.qk_rope_head_dim)  # scores
            fl += 2 * ctx * H * m.kv_lora_rank                          # values
            fl += 2 * m.kv_lora_rank * H * m.v_head_dim                 # out expand
        else:
            fl += 2 * m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
            fl += 2 * ctx * H * qk + 2 * ctx * H * m.v_head_dim
        fl += 2 * H * m.v_head_dim * d
    elif kind in (ATTN, SWA, LOCAL_ATTN):
        fl += 2 * d * (H + 2 * KV) * hd          # qkv proj
        fl += 2 * ctx * H * hd * 2               # scores + values
        fl += 2 * H * hd * d                     # out proj
    elif kind == RGLRU:
        w = cfg.lru_width or d
        fl += 2 * d * w * 2                      # wx, wy
        fl += 2 * cfg.conv_width * w             # temporal conv
        fl += 2 * w * (w // H) * 2               # block-diag gates
        fl += 12 * w                             # recurrence/gating elementwise
        fl += 2 * w * d                          # out proj
    elif kind == MLSTM:
        di = int(cfg.mlstm_proj_factor * d)
        dk = di // H
        fl += 2 * d * 2 * di                     # up proj
        fl += 2 * cfg.conv_width * di
        fl += 3 * 2 * di * di                    # q, k, v
        fl += 2 * di * 2 * H
        if decode:
            fl += 2 * H * dk * dk * 3            # C update + readout
        else:
            fl += 2 * ctx * di * 2 + 4 * ctx * H  # quadratic parallel form
        fl += 2 * di * d                         # down proj
    elif kind == SLSTM:
        fl += 4 * 2 * d * d                      # input projections
        fl += 4 * 2 * d * (d // H)               # block-diag recurrent
        fl += 2 * 3 * d * int(cfg.slstm_proj_factor * d)  # gated FFN
    # MLP
    if kind in (ATTN, SWA, LOCAL_ATTN, MLA, RGLRU):
        if moe_layer:
            m = cfg.moe
            nmat = 3
            fl += 2 * d * m.num_experts                       # router
            fl += 2 * nmat * d * m.d_ff * m.experts_per_token
            fl += 2 * nmat * d * m.d_ff * m.num_shared_experts
        elif cfg.d_ff:
            nmat = 3 if cfg.gated_mlp else 2
            fl += 2 * nmat * d * cfg.d_ff
    return fl


def forward_flops_per_token(cfg: ModelConfig, seq: int, decode: bool) -> float:
    kinds = cfg.layer_kinds
    n_pre = cfg.moe.first_dense_layers if cfg.moe.enabled else 0
    fl = 0.0
    for i, kind in enumerate(kinds):
        fl += layer_flops_per_token(cfg, kind, cfg.moe.enabled and i >= n_pre,
                                    seq, decode)
    fl += 2 * cfg.d_model * cfg.vocab_size       # lm head
    return fl


def encoder_flops(cfg: ModelConfig) -> float:
    """Whisper encoder forward FLOPs per *sequence* (1500 frames)."""
    if not cfg.is_encoder_decoder:
        return 0.0
    F = cfg.encoder_seq or 1500
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    nmat = 3 if cfg.gated_mlp else 2
    per_tok = (2 * d * 3 * H * hd + 2 * H * hd * d       # qkv + out
               + 2 * F * H * hd * 2                       # full bidir attn
               + 2 * nmat * d * cfg.d_ff)
    return per_tok * F * (cfg.num_encoder_layers or cfg.num_layers)


def cross_attn_flops_per_token(cfg: ModelConfig) -> float:
    if not cfg.is_encoder_decoder:
        return 0.0
    F = cfg.encoder_seq or 1500
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    return cfg.num_layers * (2 * d * H * hd * 2 + 2 * F * H * hd * 2
                             + 2 * H * hd * d)


@dataclass
class CostEstimate:
    flops_total: float
    flops_per_device: float
    hbm_bytes_per_device: float
    tokens: float


def estimate(cfg: ModelConfig, shape: InputShape, n_devices: int,
             model_axis: int = 16) -> CostEstimate:
    from repro.models.api import build_model

    decode = shape.kind == "decode"
    B, S = shape.global_batch, shape.seq_len
    tokens = float(B) if decode else float(B * S)

    fwd = forward_flops_per_token(cfg, S, decode) * tokens
    fwd += cross_attn_flops_per_token(cfg) * tokens
    if cfg.is_encoder_decoder and not decode:
        fwd += encoder_flops(cfg) * B
    mult = 3.0 if shape.kind == "train" else 1.0
    total = fwd * mult

    model = build_model(cfg)
    p_total = model.param_count()
    p_dev = p_total / n_devices                       # fully sharded storage

    # ---- HBM bytes per device -------------------------------------------
    d = cfg.d_model
    tok_dev = tokens / max(n_devices / model_axis, 1)  # tokens per data-shard
    if shape.kind == "train":
        weight_traffic = p_dev * (2 + 2 + 2 + 16 + 8)  # fwd bf16 + bwd read +
        # grad write (bf16) + adam m,v fp32 r/w + master fp32 r/w
        # layer I/O saved + reread + recompute writes (remat), bf16
        act_traffic = cfg.num_layers * tok_dev * d * 2.0 * 6
        hbm = weight_traffic + act_traffic
    elif shape.kind == "prefill":
        weight_traffic = p_dev * 2.0
        act_traffic = cfg.num_layers * tok_dev * d * 2.0 * 4
        # attention reads K/V per query block ~ O(S * ctx) handled by flash
        # tiling; HBM-side it is ~2x the KV bytes:
        kv = _kv_cache_bytes(cfg, B, S) / n_devices
        hbm = weight_traffic + act_traffic + 2 * kv
    else:  # decode: every step reads all (sharded) weights + the whole cache
        weight_traffic = p_dev * 2.0
        cache_traffic = _kv_cache_bytes(cfg, B, S) / n_devices
        hbm = weight_traffic + cache_traffic
    return CostEstimate(total, total / n_devices, hbm, tokens)


def _kv_cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind == MLA:
            total += B * S * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * 2
        elif kind in (ATTN,):
            total += B * S * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * 2
        elif kind in (SWA, LOCAL_ATTN):
            w = min(cfg.window or S, S)
            total += B * w * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * 2
        elif kind == RGLRU:
            w = cfg.lru_width or cfg.d_model
            total += B * w * 4 + B * (cfg.conv_width - 1) * w * 2
        elif kind == MLSTM:
            di = int(cfg.mlstm_proj_factor * cfg.d_model)
            dk = di // cfg.num_heads
            total += B * cfg.num_heads * dk * dk * 4
        elif kind == SLSTM:
            total += B * cfg.d_model * 4 * 4
    if cfg.is_encoder_decoder:
        F = cfg.encoder_seq or 1500
        total += cfg.num_layers * B * F * cfg.num_heads \
            * cfg.resolved_head_dim * 2 * 2
    return total

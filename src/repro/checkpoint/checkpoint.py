"""Sharding-aware numpy checkpointing.

Orbax/tensorstore are not available offline, so checkpoints are stored as an
``.npz`` per save plus a JSON manifest describing the pytree structure and,
when saving under a mesh, the PartitionSpec of every leaf (so a restore on a
different topology can re-shard).  Writes are atomic (tmp + rename) — the
FLARE-style runtime resumes jobs from the latest complete step.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":      # ml_dtypes (bf16, fp8): store
            arr = np.asarray(jnp.asarray(arr).astype(jnp.float32))  # upcast
        out[jax.tree_util.keystr(path)] = arr
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, treedef = _flatten(tree)
    manifest = {
        "step": int(step),
        "keys": sorted(flat.keys()),
        "extra": extra or {},
    }
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **{k: v for k, v in flat.items()})
    final = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    os.replace(tmp, final)
    with open(os.path.join(ckpt_dir, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(fn[5:13]) for fn in os.listdir(ckpt_dir)
             if fn.startswith("ckpt_") and fn.endswith(".npz")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like_tree, step: Optional[int] = None):
    """Restore into the structure of `like_tree` (dtypes preserved from it)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for path, ref in flat:
        key = jax.tree_util.keystr(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {ref.shape}")
        leaves.append(jnp.asarray(arr).astype(ref.dtype))   # jnp handles bf16
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like_tree), leaves), step

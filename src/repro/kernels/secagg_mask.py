"""Fused quantize + pairwise-mask kernel for secure aggregation.

Every FL upload in the SecAgg protocol (fl/mods.py) runs:
    q   = round(x * weight * 2^bits)  as int32 (two's complement wrap)
    out = q + sum_p mask_p            (mod 2^32, masks cancel server-side)

Done naively that is P+1 HBM round-trips over a multi-GB update; the kernel
fuses quantization and the P-peer mask reduction into one pass with
(block,)-sized VMEM tiles.  Grid: (num_blocks,); the peer loop runs inside
the kernel over the (P, block) mask tile.

TPU note: int32 add wraps (two's complement) on the VPU, matching the
mod-2^32 field the protocol needs; the uint64 variant in fl/mods.py is the
host-side reference field (tests map between them).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, m_ref, w_ref, o_ref, *, quant_bits: int):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[0]
    q = jnp.round(x * w * (1 << quant_bits))
    # clamp to int32 range before cast (jnp cast of out-of-range is UB-ish);
    # the protocol guarantees |q| < 2^31 by clipping updates client-side.
    q = jnp.clip(q, -(2.0 ** 31), 2.0 ** 31 - 1).astype(jnp.int32)
    total = jnp.sum(m_ref[...], axis=0, dtype=jnp.int32)
    o_ref[...] = q + total


def secagg_mask(x, masks, weight, *, quant_bits: int = 16, block: int = 4096,
                interpret: bool = True):
    """x: (N,) float32; masks: (P, N) int32; weight: scalar -> (N,) int32."""
    N = x.shape[0]
    P = masks.shape[0] if masks.size else 0
    block = min(block, N)
    while N % block:
        block -= 1
    grid = (N // block,)
    if P == 0:
        masks = jnp.zeros((1, N), jnp.int32)
        P = 1
    w = jnp.asarray(weight, jnp.float32).reshape(1)

    return pl.pallas_call(
        functools.partial(_kernel, quant_bits=quant_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((P, block), lambda i: (0, i)),
            pl.BlockSpec((1,), lambda i: (0,)),   # scalar weight, broadcast
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(x, masks, w)

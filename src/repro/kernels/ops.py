"""Public jit'd wrappers around the Pallas kernels."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.secagg_mask import secagg_mask as _secagg
from repro.kernels.rglru_scan import rglru_scan as _rglru


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = True):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_kv=block_kv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("quant_bits", "block",
                                             "interpret"))
def secagg_mask(x, masks, weight, *, quant_bits: int = 16, block: int = 4096,
                interpret: bool = True):
    return _secagg(x, masks, weight, quant_bits=quant_bits, block=block,
                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "block_w",
                                             "interpret"))
def rglru_scan(a, b, h0, *, block_s: int = 256, block_w: int = 512,
               interpret: bool = True):
    return _rglru(a, b, h0, block_s=block_s, block_w=block_w,
                  interpret=interpret)

"""Pure-jnp oracles for every kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,S,H,hd) pre-scaled; k/v: (B,S,KV,hd) -> (B,S,H,hd_v)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, S, KV, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return ctx.reshape(B, S, H, v.shape[-1])


def secagg_mask_ref(x, masks, weight: float, quant_bits: int = 16):
    """x: (N,) float; masks: (P, N) int32 (signed per peer already applied).

    out = int32 wraparound( round(x * weight * 2^bits) + sum_p masks[p] )."""
    q = jnp.round(x.astype(jnp.float32) * weight * (1 << quant_bits))
    q = jnp.clip(q, -(2.0 ** 31), 2.0 ** 31 - 1).astype(jnp.int32)
    total = masks.astype(jnp.int32).sum(0, dtype=jnp.int32) if masks.size else 0
    return q + total                                    # int32 wraps


def rglru_scan_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t.  a,b: (B,S,W) fp32; h0: (B,W)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    aT = jnp.swapaxes(a, 0, 1)
    bT = jnp.swapaxes(b, 0, 1)
    hT, ys = jax.lax.scan(step, h0, (aT, bT))
    return jnp.swapaxes(ys, 0, 1), hT

"""Chunked RG-LRU linear recurrence kernel: h_t = a_t * h_{t-1} + b_t.

Grid: (batch, width_blocks, seq_blocks) with the SEQ axis minor/sequential
("arbitrary" semantics): the carry h lives in VMEM scratch and persists
across seq blocks; within a block the kernel runs a fori_loop over the
block's timesteps entirely in VMEM.  This is the TPU-native adaptation of
the recurrence (a GPU impl would parallel-scan across SMs; on TPU the
block-sequential scan with the 8x128 VPU lanes across width is the natural
layout — DESIGN.md §6).

a, b: (B, S, W) fp32;  h0: (B, W) fp32  ->  (ys (B,S,W), h_final (B,W)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _kernel(h0_ref, a_ref, b_ref, y_ref, hout_ref, h_ref, *,
            block_s: int, num_seq_blocks: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0]                       # (1, bw) -> (bw,) carry

    a = a_ref[0]                                     # (bs, bw)
    b = b_ref[0]

    def step(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t, :] = h
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h

    @pl.when(si == num_seq_blocks - 1)
    def _finalize():
        hout_ref[0] = h


def rglru_scan(a, b, h0, *, block_s: int = 256, block_w: int = 512,
               interpret: bool = True):
    B, S, W = a.shape
    block_s = min(block_s, S)
    while S % block_s:
        block_s -= 1
    block_w = min(block_w, W)
    while W % block_w:
        block_w -= 1
    ns, nw = S // block_s, W // block_w

    ys, hf = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, num_seq_blocks=ns),
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, block_w), lambda bi, wi, si: (bi, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, block_w), lambda bi, wi, si: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(h0, a, b)
    return ys, hf

"""Flash attention Pallas TPU kernel (causal / GQA / sliding-window).

Grid: (batch * q_heads, num_q_blocks, num_kv_blocks) — the kv dimension is
the minor (sequential / "arbitrary") grid axis, so the kernel revisits the
same output block while streaming K/V blocks HBM->VMEM and maintaining the
online-softmax state (m, l, acc) in VMEM scratch.  Tiles are MXU-aligned
(block_q x head_dim and block_kv x head_dim, multiples of 128 at real
sizes; tests use smaller shapes, which interpret mode permits).

VMEM working set per step:
    q block   block_q  * hd * 4
    k,v block block_kv * hd * 4 * 2
    acc/m/l   block_q * (hd + 2) * 4
e.g. block_q=block_kv=512, hd=128: ~1.6 MB — well inside the ~16MB VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _choose_block(S: int, requested: int) -> int:
    """Pick a block size for a sequence of length ``S``.

    Prefer the largest divisor of ``S`` that is <= ``requested`` and keeps
    tiles lane-aligned (multiple of 8), falling back to ``S`` itself when it
    is small. If no aligned divisor exists (prime/odd ``S``), keep the
    requested block and let the caller pad the sequence up to a multiple of
    it — never degrade toward block size 1, which serializes the grid.
    """
    b = max(1, min(requested, S))
    if S % b == 0:
        return b
    for cand in range(b, 7, -1):
        if S % cand == 0 and cand % 8 == 0:
            return cand
    return b


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, block_q: int, block_kv: int,
            num_kv_blocks: int, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                 # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T)                              # (bq, bkv) on the MXU
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_len                # padded kv positions contribute 0
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (exp(NEG_INF - NEG_INF) would be 1)
    row_any = jnp.any(mask, axis=1, keepdims=True)
    p = jnp.where(row_any, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = True):
    """q: (B,S,H,hd) pre-scaled; k/v: (B,S,KV,hd) -> (B,S,H,hd_v)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    hdv = v.shape[-1]
    block_q = _choose_block(S, block_q)
    block_kv = _choose_block(S, block_kv)
    # smallest common padded length (equals S whenever both blocks divide S)
    l = math.lcm(block_q, block_kv)
    S_pad = -(-S // l) * l
    nq = S_pad // block_q
    nk = S_pad // block_kv

    # flatten (B, H) into the major grid axis; kv head = q head // g
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * KV, S, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * KV, S, hdv)
    if S_pad != S:
        pad = ((0, 0), (0, S_pad - S), (0, 0))
        qf, kf, vf = (jnp.pad(x, pad) for x in (qf, kf, vf))

    def q_index(h, i, j):
        return (h, i, 0)

    def kv_index(h, i, j):
        return ((h // g), j, 0)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, num_kv_blocks=nk, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_index),
            pl.BlockSpec((1, block_kv, hd), kv_index),
            pl.BlockSpec((1, block_kv, hdv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hdv), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, S_pad, hdv), q.dtype),
        scratch_shapes=[
            # online-softmax state persists across the kv (minor) grid axis
            pltpu.VMEM((block_q, 1), jnp.float32),      # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),      # running sum l
            pltpu.VMEM((block_q, hdv), jnp.float32),    # output accumulator
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out[:, :S].reshape(B, H, S, hdv), 1, 2)

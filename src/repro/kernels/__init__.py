"""Pallas TPU kernels for the substrate's compute hot spots.

Three kernels (DESIGN.md §6), each with a pure-jnp oracle in ``ref.py`` and
a jit'd public wrapper in ``ops.py``:

- ``flash_attention``: blockwise causal/GQA/sliding-window attention with
  online softmax (HBM->VMEM streaming of K/V blocks, MXU-aligned tiles).
- ``secagg_mask``: fused fixed-point quantize + pairwise-mask reduction for
  secure aggregation — the elementwise hot path of every FL upload.
- ``rglru_scan``: chunked RG-LRU linear recurrence h_t = a_t*h_{t-1} + b_t.

Plus ``agg_reduce``: the server-side aggregation reductions (fused
weighted sum, sorted median/trimmed tiles, Krum Gram matrix) over flat
parameter buffers, differential-tested bitwise against the numpy kernels
in :mod:`repro.fl.agg_kernels` (its reference path and dispatch layer).

This container is CPU-only: kernels are VALIDATED with
``pl.pallas_call(..., interpret=True)`` which executes the kernel body in
Python; the BlockSpecs/grids are written for real TPU execution.
"""
from repro.kernels import ops, ref  # noqa: F401

"""Pallas TPU kernels for flat-buffer FL aggregation (one pass over HBM).

The numpy kernels in :mod:`repro.fl.agg_kernels` stream every client's
payload through an L2-blocked fp64 accumulator.  On TPU the same
reductions become Pallas grid kernels over the (clients x total_params)
logical matrix, one column-block per grid step, with the wire decode
**fused into the tile read**:

- :func:`weighted_sum` — FedAvg's sum(w_i * x_i) (optionally continuing a
  running accumulator, the streaming arrival-order fold).  Per block:
  dequantize(+delta-base) + scale into an fp32->fp64 tile, then fold the
  client rows sequentially.
- :func:`sort_reduce` — coordinate-wise median / trimmed sum on the
  sorted (clients, block) tile (the host divides a trimmed *sum* by the
  row count so the final divide matches numpy's ``np.mean`` bitwise).
- :func:`gram` — the Krum Gram matrix: each tile is centered on its first
  row and accumulated as ``G += t @ t.T`` across grid steps (MXU matmul,
  fp64 accumulation).

Inputs arrive as already-stacked host arrays (see
``FlatParams.tile_source`` / ``QuantParams.tile_source`` — the chunk->tile
adapters): ``data`` is (clients, N) in the wire dtype (fp32/bf16/fp64 or
int8), ``scales`` the per-``qchunk`` fp32 scales for int8 payloads, and
``base`` the shared fp64 round-start vector for delta payloads.

Exactness contract (what `tests/test_agg_pallas.py` pins): every kernel
reproduces the numpy reference **bitwise** (<=1 ULP guaranteed, 0
observed) except the Gram matrix, whose matmul reduction order is
hardware-defined.  Two implementation details make that possible and must
not be "simplified" away:

- accumulation happens in a ``fori_loop`` whose trip count is a *runtime
  scalar* (``n_ref``).  XLA:CPU compiles fused elementwise graphs with
  LLVM fast-math, which contracts ``a*b + c`` into FMA and reassociates
  unrolled add chains — up to ~1.5k fp64 ULP of drift under cancellation.
  A while loop with a dynamic trip count cannot be unrolled, so the
  multiply (materialized before the loop) and each add (one per
  iteration) round exactly like the numpy fold.
- int8 dequantization multiplies in fp32 and widens afterwards:
  ``f64(f32(q * scale))`` is bitwise the numpy ``_dequant_q8`` chain
  (the exact product fits fp64, then rounds through fp32 once).

This container is CPU-only: kernels are validated with
``pl.pallas_call(..., interpret=True)`` (fp64 under a scoped
``jax.experimental.enable_x64``); the BlockSpecs/grids are the TPU
configuration under test.  On real TPUs fp64 VPU throughput is emulated —
:func:`weighted_sum` therefore offers ``tile_dtype="float32"`` (fp32
tiles + fp64 carry: the decode/scale tile math at fp32 VPU rate, only
the accumulate widened), selected by the sharded streaming fold on TPU
hosts; the interpret-mode fp64 path stays the bitwise cross-check oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.experimental
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_QCHUNK = 1024
#: auto block sizing keeps the 1-D grid at most this long — interpret mode
#: replays the kernel body once per grid step, so a 50M-element buffer
#: must not become thousands of steps (on TPU the same bound keeps the
#: per-step DMA large enough to hide latency)
_MAX_GRID = 64
_MIN_BLOCK = 8192
_MAX_BLOCK = 1 << 21


def choose_block(n: int, qchunk: int = 1) -> int:
    """Column-block size: a multiple of ``qchunk`` (int8 scale windows
    never straddle blocks) with at most ``_MAX_GRID`` grid steps."""
    blk = -(-_MIN_BLOCK // qchunk) * qchunk
    while blk * _MAX_GRID < n and blk < _MAX_BLOCK:
        blk *= 2
    return blk


def _pad_cols(a: np.ndarray, total: int, fill=0) -> np.ndarray:
    """Zero/fill-pad the last axis out to ``total`` columns."""
    if a.shape[-1] == total:
        return a
    out = np.full(a.shape[:-1] + (total,), fill, a.dtype)
    out[..., : a.shape[-1]] = a
    return out


def _decode_tile(d_ref, s_ref, b_ref, *, qchunk: int,
                 tile_dtype=jnp.float64) -> jnp.ndarray:
    """The fused wire decode: (C, blk) wire-dtype tile -> ``tile_dtype``.

    int8 payloads dequantize through fp32 (one rounding, matching the
    numpy ``_dequant_q8`` chain bitwise); float payloads widen exactly.
    A delta payload's shared round base is added in fp64 afterwards, like
    ``QuantParams.f64_chunk``.

    ``tile_dtype=float32`` is the TPU production scheme (fp32 tiles +
    fp64 carry): fp64 VPU throughput is emulated on TPU, so the tile math
    (decode, scale) runs at fp32 rate and only the per-element
    accumulate widens to the fp64 carry.  It is NOT bitwise against the
    fp64 path (each product carries one extra fp32 rounding) — the
    interpret-mode fp64 path stays the cross-check oracle.
    """
    raw = d_ref[...]
    if raw.dtype == jnp.int8:
        c, blk = raw.shape
        dq = (raw.astype(jnp.float32).reshape(c, blk // qchunk, qchunk)
              * s_ref[...][:, :, None]).reshape(c, blk)
        t = dq.astype(tile_dtype)
    else:
        t = raw.astype(tile_dtype)
    if b_ref is not None:
        t = t + b_ref[...][None, :]
    return t


def _assemble(data: np.ndarray, *, lead: int,
              scales: Optional[np.ndarray], qchunk: int,
              base: Optional[np.ndarray], acc: Optional[np.ndarray],
              block: Optional[int]):
    """Shared grid assembly for all three kernels: pick the block, pad
    every operand to a whole number of blocks, and build the (args,
    in_specs) lists in the order :func:`_unpack` consumes them —
    ``(lead scalar, [acc], data, [scales], [base])``.  Returns
    ``(blk, total, args, specs)``; callers append their tail operands.
    """
    c, n = data.shape
    q8 = data.dtype == np.int8
    blk = block or choose_block(n, qchunk if q8 else 1)
    if q8:
        blk = -(-blk // qchunk) * qchunk
    total = -(-n // blk) * blk
    args = [np.array([lead], np.int32)]
    specs = [pl.BlockSpec((1,), lambda i: (0,))]
    if acc is not None:
        if not isinstance(acc, np.ndarray) and acc.shape[-1] == total:
            # already-padded device array (streaming out_padded chain):
            # pass through untouched so successive arrivals stay one
            # async dispatch chain — no host sync, no copy
            args.append(acc)
        else:
            args.append(_pad_cols(np.asarray(acc, np.float64), total))
        specs.append(pl.BlockSpec((blk,), lambda i: (i,)))
    args.append(_pad_cols(data, total))
    specs.append(pl.BlockSpec((c, blk), lambda i: (0, i)))
    if q8:
        args.append(_pad_cols(np.asarray(scales, np.float32),
                              total // qchunk, fill=1))
        specs.append(pl.BlockSpec((c, blk // qchunk), lambda i: (0, i)))
    if base is not None:
        args.append(_pad_cols(np.asarray(base, np.float64), total))
        specs.append(pl.BlockSpec((blk,), lambda i: (i,)))
    return blk, total, args, specs


def _unpack(refs, *, q8: bool, has_base: bool, extra: int):
    """(n_ref, [acc/extra...], data, [scales], [base], tail...)"""
    it = iter(refs)
    n_ref = next(it)
    head = [next(it) for _ in range(extra)]
    d_ref = next(it)
    s_ref = next(it) if q8 else None
    b_ref = next(it) if has_base else None
    return n_ref, head, d_ref, s_ref, b_ref, list(it)


# ---------------------------------------------------------------------------
# fused weighted sum (FedAvg / streaming fold)
# ---------------------------------------------------------------------------
def _wsum_kernel(*refs, q8: bool, has_base: bool, has_acc: bool,
                 qchunk: int, tile_dtype):
    n_ref, head, d_ref, s_ref, b_ref, (w_ref, o_ref) = _unpack(
        refs, q8=q8, has_base=has_base, extra=1 if has_acc else 0)
    t = _decode_tile(d_ref, s_ref, b_ref, qchunk=qchunk,
                     tile_dtype=tile_dtype)
    # fp32 tiles: weights cast down so the scale multiply runs at VPU
    # rate; the fp64-dtype cast below is the identity and preserves the
    # bitwise contract of the default path
    t = t * w_ref[...].astype(t.dtype)[:, None]

    def body(c, a):
        row = jax.lax.dynamic_index_in_dim(t, c, 0, keepdims=False)
        return a + row.astype(jnp.float64)     # the fp64 carry

    if has_acc:
        init, lo = head[0][...], 0
    else:
        init, lo = t[0].astype(jnp.float64), 1
    # n_ref (a runtime scalar) keeps the loop a genuine while loop — see
    # the module docstring for why unrolling would break bitwise parity;
    # the det-fori-trip rule (docs/INVARIANTS.md) rejects any rewrite
    # that makes this bound constant-foldable
    o_ref[...] = jax.lax.fori_loop(lo, n_ref[0], body, init)


def weighted_sum(data: np.ndarray, weights: np.ndarray, *,
                 scales: Optional[np.ndarray] = None,
                 qchunk: int = DEFAULT_QCHUNK,
                 base: Optional[np.ndarray] = None,
                 acc: Optional[np.ndarray] = None,
                 block: Optional[int] = None,
                 interpret: bool = True,
                 out_padded: bool = False,
                 tile_dtype: str = "float64") -> np.ndarray:
    """``(acc +) sum_c weights[c] * decode(data[c])`` as one fused pass.

    ``data``: (C, N) fp32/fp64/bf16 or int8 (with ``scales`` (C, S)).
    ``base``: shared (N,) fp64 round-start vector for delta payloads.
    ``acc``: (N,) fp64 running accumulator (the streaming arrival-order
    fold); when given, all C rows fold *into* it.  Returns (N,) fp64.

    ``out_padded=True`` returns the block-padded device array itself
    (length a multiple of the block size) instead of a sliced host copy;
    feeding it back as ``acc`` under the same geometry skips the
    per-arrival pad + slice + host round-trip entirely, so successive
    streaming arrivals form one asynchronous dispatch chain (decode of
    arrival k+1 overlaps the device fold of arrival k).

    ``tile_dtype="float32"`` runs the decode/scale tile math in fp32 with
    an fp64 accumulate (the TPU production scheme — see `_decode_tile`);
    it requires ``base=None`` and relaxes the bitwise contract to a
    relative tolerance.
    """
    if tile_dtype not in ("float64", "float32"):
        raise ValueError(f"tile_dtype {tile_dtype!r}")
    if tile_dtype == "float32" and base is not None:
        raise ValueError("tile_dtype='float32' requires base=None "
                         "(defer the delta base to finalize)")
    c, n = data.shape
    if n == 0:
        return np.zeros(0, np.float64) if acc is None else np.asarray(acc)
    blk, total, args, specs = _assemble(
        data, lead=c, scales=scales, qchunk=qchunk, base=base, acc=acc,
        block=block)
    args.append(np.asarray(weights, np.float64))
    specs.append(pl.BlockSpec((c,), lambda i: (0,)))

    kern = functools.partial(_wsum_kernel, q8=data.dtype == np.int8,
                             has_base=base is not None,
                             has_acc=acc is not None, qchunk=qchunk,
                             tile_dtype=np.dtype(tile_dtype))
    with jax.experimental.enable_x64():
        out = pl.pallas_call(
            kern, grid=(total // blk,), in_specs=specs,
            out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((total,), jnp.float64),
            interpret=interpret,
        )(*args)
        if out_padded:
            return out                  # padded device array, no sync
        return np.array(out[:n])        # writable copy


# ---------------------------------------------------------------------------
# stacked-tile sort reductions (median / trimmed mean)
# ---------------------------------------------------------------------------
def _sort_kernel(*refs, q8: bool, has_base: bool, qchunk: int,
                 kind: str, trim_k: int):
    n_ref, _, d_ref, s_ref, b_ref, (o_ref,) = _unpack(
        refs, q8=q8, has_base=has_base, extra=0)
    t = _decode_tile(d_ref, s_ref, b_ref, qchunk=qchunk)
    t = jnp.sort(t, axis=0)
    c = t.shape[0]
    if kind == "median":
        if c % 2:
            o_ref[...] = t[c // 2]
        else:
            o_ref[...] = (t[c // 2 - 1] + t[c // 2]) / 2.0
        return

    def body(r, a):
        return a + jax.lax.dynamic_index_in_dim(t, r, 0, keepdims=False)

    # trimmed SUM of sorted rows [trim_k, n_ref[0]); the host divides by
    # the row count so the mean's final divide is numpy's own
    o_ref[...] = jax.lax.fori_loop(trim_k + 1, n_ref[0], body, t[trim_k])


def sort_reduce(data: np.ndarray, *, kind: str = "median", trim_k: int = 0,
                scales: Optional[np.ndarray] = None,
                qchunk: int = DEFAULT_QCHUNK,
                base: Optional[np.ndarray] = None,
                block: Optional[int] = None,
                interpret: bool = True) -> np.ndarray:
    """Coordinate-wise sorted reduction over the (C, N) stack.

    ``kind="median"`` returns the per-coordinate median;
    ``kind="trim_sum"`` returns the per-coordinate SUM of the sorted rows
    ``[trim_k, C - trim_k)`` (the caller divides — see `_sort_kernel`).
    """
    assert kind in ("median", "trim_sum"), kind
    c, n = data.shape
    if n == 0:
        return np.zeros(0, np.float64)
    blk, total, args, specs = _assemble(
        data, lead=c - trim_k, scales=scales, qchunk=qchunk, base=base,
        acc=None, block=block)

    kern = functools.partial(_sort_kernel, q8=data.dtype == np.int8,
                             has_base=base is not None,
                             qchunk=qchunk, kind=kind, trim_k=trim_k)
    with jax.experimental.enable_x64():
        out = pl.pallas_call(
            kern, grid=(total // blk,), in_specs=specs,
            out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((total,), jnp.float64),
            interpret=interpret,
        )(*args)
        return np.array(out[:n])        # writable copy


# ---------------------------------------------------------------------------
# Krum Gram matrix
# ---------------------------------------------------------------------------
def _gram_kernel(*refs, q8: bool, has_base: bool, qchunk: int):
    _, _, d_ref, s_ref, b_ref, (o_ref,) = _unpack(
        refs, q8=q8, has_base=has_base, extra=0)
    t = _decode_tile(d_ref, s_ref, b_ref, qchunk=qchunk)
    # center on the first row: pairwise distances are translation
    # invariant, and removing the common component keeps the
    # ||a||^2+||b||^2-2<a,b> expansion from cancelling catastrophically
    t = t - t[0]
    g = jnp.dot(t, t.T, preferred_element_type=jnp.float64)
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[...] = g

    @pl.when(i > 0)
    def _():
        o_ref[...] += g


def gram(data: np.ndarray, *,
         scales: Optional[np.ndarray] = None,
         qchunk: int = DEFAULT_QCHUNK,
         base: Optional[np.ndarray] = None,
         block: Optional[int] = None,
         interpret: bool = True) -> np.ndarray:
    """(C, C) fp64 Gram matrix of the row-0-centered client stack,
    accumulated one column block per grid step (the Krum distance
    kernel's MXU half; the host expands distances and scores)."""
    c, n = data.shape
    if n == 0:
        return np.zeros((c, c), np.float64)
    blk, total, args, specs = _assemble(
        data, lead=c, scales=scales, qchunk=qchunk, base=base, acc=None,
        block=block)

    kern = functools.partial(_gram_kernel, q8=data.dtype == np.int8,
                             has_base=base is not None, qchunk=qchunk)
    with jax.experimental.enable_x64():
        out = pl.pallas_call(
            kern, grid=(total // blk,), in_specs=specs,
            out_specs=pl.BlockSpec((c, c), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((c, c), jnp.float64),
            interpret=interpret,
        )(*args)
        return np.array(out)            # writable copy


# ---------------------------------------------------------------------------
# structured-sparse scatter fold (0xF5 payloads)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("q8",))
def _sparse_contrib(vals, escales, w, *, q8: bool):
    if q8:
        # exact int8*fp32 product in fp64, then ONE fp32 rounding — the
        # numpy ``_dequant_q8`` chain bitwise (module docstring)
        t = vals.astype(jnp.float64) * escales.astype(jnp.float64)
        r = t.astype(jnp.float32).astype(jnp.float64)
    else:
        r = vals.astype(jnp.float64)
    return r * w


def _pad_pow2(a: np.ndarray) -> np.ndarray:
    """Zero-pad to the next power of two so `_sparse_contrib` compiles
    once per size class instead of once per span length."""
    n = a.size
    p = 1
    while p < n:
        p *= 2
    if p == n:
        return a
    out = np.zeros(p, a.dtype)
    out[:n] = a
    return out


def scatter_wsum(acc: np.ndarray, dest, vals: np.ndarray, w: float, *,
                 scales: Optional[np.ndarray] = None,
                 qchunk: int = DEFAULT_QCHUNK, pos0: int = 0) -> None:
    """``acc[dest] += w * dequant(vals)`` — the 0xF5 sparse-delta fold.

    Deliberately NOT a ``pl.pallas_call``: a data-dependent scatter has
    no tile structure (the destination indices are runtime values, so
    there is no BlockSpec that maps grid steps to disjoint output
    blocks).  Instead the O(nnz) dequantize+scale chain runs as a jitted
    XLA elementwise graph under scoped x64 — mirroring the numpy
    ``_dequant_q8`` rounding chain bitwise — and the final unique-index
    scatter-add happens on the host accumulator, where `+=` with unique
    indices has no reduction-order ambiguity.

    ``acc``: fp64 accumulator segment (mutated in place).  ``dest``: a
    slice or unique index array *relative to acc*.  ``vals``: packed
    int8 (with ``scales``, one per ``qchunk`` window of the packed
    stream; ``pos0`` is the packed position of ``vals[0]``) or fp32.
    """
    n = vals.size
    if n == 0:
        return
    q8 = vals.dtype == np.int8
    if q8:
        # per-element scale of the packed stream (host gather, O(nnz))
        esc = np.asarray(scales, np.float32)[
            (pos0 + np.arange(n, dtype=np.int64)) // qchunk]
        esc = _pad_pow2(esc)
    else:
        esc = np.zeros(0, np.float32)
    with jax.experimental.enable_x64():
        contrib = _sparse_contrib(_pad_pow2(vals), esc,
                                  jnp.float64(w), q8=q8)
        acc[dest] += np.asarray(contrib)[:n]

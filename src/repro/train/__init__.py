from repro.train.steps import (  # noqa: F401
    cross_entropy_loss,
    make_train_step,
    make_prefill_step,
    make_decode_step,
    TrainState,
)

"""Step functions: loss, train step, prefill step, decode step.

These are the functions the launcher jits (with in/out shardings) and the
dry-run lowers.  They are mesh-agnostic: distribution comes entirely from
the shardings attached at jit time (pjit-style; DESIGN.md §5).

:func:`get_train_step` is the federated entry point: a process-wide cache
of compiled train steps keyed by ``(model_cfg, train_cfg, impl, mesh)``,
so N simulated FL clients with identical configs share ONE jitted (and,
with a mesh, mesh-sharded) step instead of re-tracing per client.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models.api import Model
from repro.optim import make_optimizer
from repro.optim.optimizers import clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def cross_entropy_loss(logits, labels, z_loss: float = 0.0):
    """Mean next-token CE in fp32 (+ optional z-loss). logits: (B,S,V).

    The gold logit is gathered with a one-hot contraction, NOT
    take_along_axis: under pjit the vocab dim is sharded over "model", and
    a gather across a sharded dim forces GSPMD to replicate the full
    (B,S,V) fp32 logits (observed +100GB/device in the dry-run).  The
    one-hot einsum keeps the reduction local + one small all-reduce."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    ce = jnp.mean(logz - gold)
    if z_loss:
        ce = ce + z_loss * jnp.mean(jnp.square(logz))
    return ce


def make_train_state(model: Model, train_cfg: TrainConfig, key) -> TrainState:
    params = model.init(key)
    opt = make_optimizer(train_cfg)
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))


def abstract_train_state(model: Model, train_cfg: TrainConfig) -> TrainState:
    """ShapeDtypeStruct TrainState — dry-run path, zero allocation."""
    params = model.abstract()
    opt = make_optimizer(train_cfg)
    opt_state = jax.eval_shape(opt.init, params)
    return TrainState(params, opt_state,
                      jax.ShapeDtypeStruct((), jnp.int32))


def make_train_step(model: Model, train_cfg: TrainConfig, impl: str = "xla"):
    opt = make_optimizer(train_cfg)
    M = max(train_cfg.microbatches, 1)

    def loss_fn(params, batch):
        logits, _, metrics = model.apply(params, batch, mode="train", impl=impl)
        loss = cross_entropy_loss(logits, batch["labels"], train_cfg.z_loss)
        loss = loss + metrics.get("aux_loss", 0.0)
        return loss, metrics

    def grad_fn(params, batch):
        """Grad accumulation over M microbatches (§Perf iteration E: the
        live activation set shrinks ~M x; grads accumulate in fp32)."""
        if M == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch)

        def one(acc, mb):
            (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            g32 = jax.tree.map(lambda a: a.astype(jnp.float32), g)
            acc_g, acc_l, acc_aux = acc
            return (jax.tree.map(jnp.add, acc_g, g32), acc_l + l,
                    acc_aux + met.get("aux_loss", 0.0)), ()

        zero = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params),
                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
        (gsum, lsum, auxsum), _ = jax.lax.scan(one, zero, micro)
        grads = jax.tree.map(lambda g, p: (g / M).astype(p.dtype), gsum,
                             params)
        return (lsum / M, {"aux_loss": auxsum / M}), grads

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        (loss, metrics), grads = grad_fn(state.params, batch)
        if train_cfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        updates, opt_state = opt.update(grads, state.opt_state, state.params,
                                        state.step)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state.params, updates)
        out_metrics = {"loss": loss, "grad_norm": gnorm,
                       "aux_loss": metrics.get("aux_loss", jnp.zeros(()))}
        return TrainState(params, opt_state, state.step + 1), out_metrics

    return train_step


# ---------------------------------------------------------------------------
# shared compiled steps (federated clients: one trace per config, not per
# client) + mesh-sharded jit
# ---------------------------------------------------------------------------
_STEP_LOCK = threading.Lock()
_STEP_CACHE: Dict[Any, Any] = {}        # guarded-by: _STEP_LOCK


def _mesh_key(mesh) -> Any:
    """Hashable identity of a mesh: axis names, shape, and the concrete
    device ids (two meshes over different devices must not share a
    compiled step)."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


def get_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig,
                   impl: str = "xla", mesh=None):
    """The compiled train step for ``(model_cfg, train_cfg, impl, mesh)``.

    Process-wide cache: every FL client with the same config tuple gets
    the SAME jitted callable, so an N-client simulation traces and
    compiles once instead of N times (the configs are frozen dataclasses
    — hashable cache keys).  With a mesh, the step is jitted with
    fsdp-sharded in/out shardings (:func:`make_sharded_train_step`);
    without one, a plain ``jax.jit``.
    """
    key = (model_cfg, train_cfg, impl, _mesh_key(mesh))
    with _STEP_LOCK:
        fn = _STEP_CACHE.get(key)
    if fn is not None:
        return fn
    from repro.models.api import build_model

    model = build_model(model_cfg)
    if mesh is None:
        fn = jax.jit(make_train_step(model, train_cfg, impl=impl))
    else:
        fn = make_sharded_train_step(model, train_cfg, mesh, impl=impl)
    with _STEP_LOCK:
        # racing builders may both compile; first write wins so every
        # caller shares one callable afterwards
        return _STEP_CACHE.setdefault(key, fn)


def make_sharded_train_step(model: Model, train_cfg: TrainConfig, mesh,
                            impl: str = "xla"):
    """Jit the train step with mesh shardings attached (pjit-style).

    ``launch/shardings.py`` maps every TrainState leaf (params AND Adam
    moments — the moments shard exactly like their params) plus the
    token/label batch onto the mesh's fsdp "data"/"model" axes; the
    returned callable constrains its inputs and outputs to those
    shardings, so client fit steps on a (1,1) local mesh and a
    production (16,16) mesh run the same code path.
    """
    from repro.launch.shardings import batch_shardings, state_shardings

    st_sh = state_shardings(model, train_cfg, mesh)
    B = train_cfg.global_batch
    S = train_cfg.seq_len
    b_sh = batch_shardings(
        {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
         "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}, mesh)
    step = make_train_step(model, train_cfg, impl=impl)
    return jax.jit(step, in_shardings=(st_sh, b_sh),
                   out_shardings=(st_sh, None))


def make_eval_step(model: Model, impl: str = "xla"):
    def eval_step(params, batch):
        logits, _, _ = model.apply(params, batch, mode="train", impl=impl)
        loss = cross_entropy_loss(logits, batch["labels"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]
                        ).astype(jnp.float32))
        return {"loss": loss, "accuracy": acc}

    return eval_step


def make_prefill_step(model: Model, max_len: Optional[int] = None,
                      impl: str = "xla"):
    def prefill_step(params, batch):
        logits, cache, _ = model.apply(params, batch, mode="prefill",
                                       impl=impl, prefill_max_len=max_len,
                                       last_logit_only=True)
        # only the last-position logits (the generation frontier) were built
        return logits[:, 0], cache

    return prefill_step


def make_decode_step(model: Model, impl: str = "xla"):
    """One new token against an existing cache — the serve_step the decode
    shapes lower (decode_32k / long_500k)."""

    def decode_step(params, cache, tokens):
        logits, cache, _ = model.apply(params, {"tokens": tokens},
                                       mode="decode", cache=cache, impl=impl)
        return logits[:, 0], cache

    return decode_step


def greedy_generate(model: Model, params, prompt_tokens, num_new: int,
                    max_len: Optional[int] = None, impl: str = "xla"):
    """Reference end-to-end generation loop (prefill + decode steps)."""
    B, S = prompt_tokens.shape
    max_len = max_len or (S + num_new)
    prefill = make_prefill_step(model, max_len=max_len, impl=impl)
    decode = make_decode_step(model, impl=impl)
    batch = {"tokens": prompt_tokens}
    if model.cfg.is_encoder_decoder:
        raise NotImplementedError("use decode from init_cache for enc-dec")
    last, cache = prefill(params, batch)
    toks = [jnp.argmax(last, -1)[:, None]]
    for _ in range(num_new - 1):
        last, cache = decode(params, cache, toks[-1])
        toks.append(jnp.argmax(last, -1)[:, None])
    return jnp.concatenate(toks, axis=1)

"""Configuration system.

Every architecture is described by a :class:`ModelConfig`; training /
serving / federated-learning behaviour by :class:`TrainConfig`,
:class:`ServeConfig` and :class:`FLConfig`.  Architectures register
themselves in :data:`ARCH_REGISTRY` (populated by ``repro.configs``) and are
selectable everywhere via ``--arch <id>``.

The four assigned input shapes are fixed here as :data:`INPUT_SHAPES`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Block kinds understood by the transformer stack.
# ---------------------------------------------------------------------------
ATTN = "attn"            # global self attention (full / GQA / MQA)
SWA = "swa"              # sliding-window attention
MLA = "mla"              # multi-head latent attention (DeepSeek-V2)
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block
RGLRU = "rglru"          # RecurrentGemma RG-LRU block
LOCAL_ATTN = "local"     # local attention (RecurrentGemma flavour of SWA)

RECURRENT_KINDS = (MLSTM, SLSTM, RGLRU)
ATTENTION_KINDS = (ATTN, SWA, MLA, LOCAL_ATTN)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for one MoE layer family."""

    num_experts: int = 0              # routed experts
    experts_per_token: int = 0        # top-k
    num_shared_experts: int = 0       # always-on shared experts
    d_ff: int = 0                     # per-expert hidden size
    router_aux_loss: float = 0.01     # load-balance loss coefficient
    router_z_loss: float = 1e-3
    first_dense_layers: int = 0       # leading dense layers (DeepSeek-V2: 1)
    capacity_factor: float = 0.0      # 0 => dropless dense-dispatch baseline

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention settings."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    # identity ------------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"            # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                 # citation

    # trunk ----------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                # 0 => d_model // num_heads
    d_ff: int = 1024                 # dense MLP hidden (0 for pure xLSTM)
    vocab_size: int = 32000
    act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU / plain)
    gated_mlp: bool = True           # False => classic 2-matrix MLP (GPT/Whisper)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    qk_norm: bool = False            # Qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10000.0
    rope: bool = True

    # layer pattern ---------------------------------------------------------
    # ``block_pattern`` repeats until num_layers is reached, e.g.
    # ("rglru","rglru","local") for RecurrentGemma, 7x"mlstm"+1x"slstm" for
    # xLSTM.  Empty => all layers are ``attn`` (or ``swa`` if window>0).
    block_pattern: Tuple[str, ...] = ()
    window: int = 0                  # sliding/local attention window (tokens)

    # family-specific -------------------------------------------------------
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: Optional[MLAConfig] = None
    # recurrent blocks
    lru_width: int = 0               # RG-LRU recurrence width (0 => d_model)
    conv_width: int = 4              # temporal conv in RG-LRU block
    mlstm_proj_factor: float = 2.0   # xLSTM mLSTM up-projection
    slstm_proj_factor: float = 4.0 / 3.0

    # encoder-decoder (whisper) ----------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # frames fed to the encoder (post-frontend)

    # modality frontend stub --------------------------------------------------
    frontend: str = ""               # "" | "vision" | "audio"
    num_prefix_tokens: int = 0       # vision patch embeddings prepended

    # numerics / memory --------------------------------------------------------
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # master params
    remat: bool = True               # checkpoint each layer in the scan
    logits_softcap: float = 0.0
    fsdp_hint: bool = True           # shard params over the data axis (big models)

    # ---------------------------------------------------------------- helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards
        over the model axis (un-padded 49155/151655 vocabs otherwise force
        replicated multi-GB logits).  Losses/serving mask the pad columns
        to -inf, so the math is exact."""
        return -(-self.vocab_size // 256) * 256

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, length == num_layers (decoder trunk)."""
        if self.block_pattern:
            pat = self.block_pattern
        elif self.window > 0:
            pat = (SWA,)
        elif self.mla is not None:
            pat = (MLA,)
        else:
            pat = (ATTN,)
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]

    @property
    def is_subquadratic(self) -> bool:
        """True when decode over very long context needs no full attention."""
        kinds = set(self.layer_kinds)
        return not (ATTN in kinds or MLA in kinds) and not self.is_encoder_decoder

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 8
    seq_len: int = 128
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    optimizer: str = "adamw"         # sgd | momentum | adam | adamw | adafactor
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    z_loss: float = 0.0
    microbatches: int = 1            # grad-accumulation splits (memory lever)
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    context_len: int = 2048          # KV cache length for decode
    prefill_len: int = 2048


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (1, 1)
    axes: Tuple[str, ...] = ("data", "model")
    fsdp: bool = True                # shard params over the data axis too

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning round structure (paper layer)."""

    num_sites: int = 2
    rounds: int = 3
    local_steps: int = 10
    strategy: str = "fedavg"         # fedavg | fedadam | fedyogi | fedprox | ...
    sync_mode: str = "loose"         # loose (runtime relay) | tight (pod psum)
    proximal_mu: float = 0.0
    server_lr: float = 1.0
    dp_clip: float = 0.0             # 0 disables the DP mod
    dp_noise_multiplier: float = 0.0
    secagg: bool = False
    seed: int = 0


@dataclass(frozen=True)
class Experiment:
    """Everything `--arch X --shape Y` resolves to."""

    model: ModelConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)
    fl: FLConfig = field(default_factory=FLConfig)


# ---------------------------------------------------------------------------
# Assigned input shapes.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture registry.
# ---------------------------------------------------------------------------
ARCH_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
SMOKE_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(arch_id: str, full: Callable[[], ModelConfig],
                  smoke: Callable[[], ModelConfig]) -> None:
    ARCH_REGISTRY[arch_id] = full
    SMOKE_REGISTRY[arch_id] = smoke


def get_model_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)

    reg = SMOKE_REGISTRY if smoke else ARCH_REGISTRY
    if arch_id not in reg:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCH_REGISTRY)}")
    return reg[arch_id]()


def list_archs() -> Sequence[str]:
    import repro.configs  # noqa: F401

    return sorted(ARCH_REGISTRY)


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is part of the dry-run matrix (DESIGN.md §4)."""
    if shape.name == "long_500k" and not (cfg.is_subquadratic or cfg.window > 0):
        return False, "full attention is quadratic at 500k context"
    if shape.name == "long_500k" and cfg.is_encoder_decoder:
        return False, "enc-dec decoder uses full self+cross attention"
    return True, ""

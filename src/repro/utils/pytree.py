"""Pytree helpers used across the FL stack and the training substrate."""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of parameters."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def tree_flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def tree_global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_to_numpy(tree):
    return jax.tree.map(lambda x: np.asarray(x), tree)


def tree_from_numpy(tree, like=None):
    if like is None:
        return jax.tree.map(jnp.asarray, tree)
    return jax.tree.map(lambda x, ref: jnp.asarray(x, dtype=ref.dtype), tree, like)


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_weighted_mean(trees: List[Any], weights: List[float]):
    """Weighted average of a list of pytrees — the heart of FedAvg."""
    total = float(sum(weights))
    if total <= 0:
        raise ValueError("weights must sum to a positive value")
    ws = [w / total for w in weights]
    out = tree_scale(trees[0], ws[0])
    for t, w in zip(trees[1:], ws[1:]):
        out = tree_add(out, tree_scale(t, w))
    return out


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
               for x, y in zip(la, lb))

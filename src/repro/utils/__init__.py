from repro.utils.pytree import (  # noqa: F401
    tree_bytes,
    tree_count,
    tree_flatten_with_paths,
    tree_global_norm,
    tree_to_numpy,
    tree_from_numpy,
    tree_zeros_like,
    tree_add,
    tree_sub,
    tree_scale,
    tree_weighted_mean,
    tree_allclose,
)

"""``dead-name``: unused imports in ``src/`` (pyflakes-level).

Advisory by default, gating under ``--strict`` (the CI analysis lane).
``__init__.py`` re-export surfaces are exempt, as is any import line
carrying a ``# noqa`` marker.  Names listed in ``__all__`` count as
used.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, Set, Tuple

from repro.analysis.core import Check, Finding, Module


def _binding(alias: ast.alias) -> str:
    if alias.asname:
        return alias.asname
    return alias.name.split(".")[0]


class DeadNameCheck(Check):
    rules = ("dead-name",)

    def scope(self, mod: Module) -> bool:
        return "repro" in mod.segments and mod.basename != "__init__.py"

    def visit(self, mod: Module) -> Iterable[Finding]:
        imports: Dict[str, Tuple[int, int, str]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                if isinstance(node, ast.ImportFrom) \
                        and node.module == "__future__":
                    continue
                comment = mod.comments.get(node.lineno, "")
                end_comment = mod.comments.get(node.end_lineno or
                                               node.lineno, "")
                if "noqa" in comment or "noqa" in end_comment:
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    imports[_binding(alias)] = (
                        node.lineno, node.col_offset, alias.name)
        if not imports:
            return
        used: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Name) and not isinstance(
                    node.ctx, ast.Store):
                used.add(node.id)
        # __all__ strings count as usage (module re-export surface)
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "__all__"
                            for t in node.targets)
                    and isinstance(node.value, (ast.List, ast.Tuple))):
                used.update(e.value for e in node.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str))
        for name, (line, col, target) in sorted(imports.items()):
            if name not in used:
                yield Finding(
                    "dead-name", mod.path, line, col,
                    f"imported name {name!r} ({target}) is never used")

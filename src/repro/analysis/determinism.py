"""Determinism lint for the aggregation fold and kernel modules.

The fig5 claim is *bitwise* equality across transports/backends, and the
fold defends it with canonical node order, fp64 accumulation, and a
runtime fori_loop trip count (kernels/agg_reduce.py docstring).  These
rules flag the patterns that silently break it:

- ``det-set-iter``: iterating a ``set`` (arrival/hash order) in an
  aggregation module — node ids must be sorted before folding;
- ``det-entropy``: ``time.*`` / ``random.*`` / legacy global
  ``np.random.*`` in fold paths (seeded ``np.random.default_rng`` and
  explicit ``Generator``/``SeedSequence`` plumbing are fine);
- ``det-float-accum``: builtin ``sum()``/``math.fsum()`` inside a traced
  (jnp/lax/pallas-using) function — Python-float reduction order is
  invisible to the fold's pairing contract;
- ``det-fori-trip``: a ``fori_loop`` upper bound that the tracer can
  constant-fold (a literal, or shape arithmetic) — XLA then unrolls the
  loop and LLVM's reassociation re-enables the FMA contraction the
  runtime trip count exists to defeat.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import Check, Finding, Module

#: np.random attributes that are deterministic-by-construction plumbing
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "Philox",
                 "PCG64", "bit_generator", "BitGenerator"}

#: fold-path modules for the accumulation-order rules
_FOLD_BASENAMES = {"agg_kernels.py", "strategy.py", "legacy.py", "flat.py"}


def _attr_chain(node: ast.AST):
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _uses_tracing(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id in ("jnp", "lax", "pl"):
            return True
        if isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if chain and chain[0] == "jax":
                return True
    return False


def _foldable_bound(node: ast.AST) -> bool:
    """True if the tracer sees this expression as a compile-time constant
    (literals and array-shape arithmetic; any plain Name keeps it
    runtime-valued and is accepted)."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute):
        return node.attr == "shape" or _foldable_bound(node.value)
    if isinstance(node, ast.Subscript):
        # x.shape[0] folds; x[0] on a runtime ref does not
        return _foldable_bound(node.value) \
            and isinstance(node.value, (ast.Attribute, ast.Subscript))
    if isinstance(node, ast.BinOp):
        return _foldable_bound(node.left) and _foldable_bound(node.right)
    if isinstance(node, ast.UnaryOp):
        return _foldable_bound(node.operand)
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("len", "int"):
            return all(_foldable_bound(a) for a in node.args)
    return False


class DeterminismCheck(Check):
    rules = ("det-set-iter", "det-entropy", "det-float-accum",
             "det-fori-trip")

    def scope(self, mod: Module) -> bool:
        return ("fl" in mod.segments or "kernels" in mod.segments
                or mod.basename == "sharding.py")

    def visit(self, mod: Module) -> Iterable[Finding]:
        yield from self._set_iter(mod)
        yield from self._entropy(mod)
        if mod.basename in _FOLD_BASENAMES or "kernels" in mod.segments:
            yield from self._float_accum(mod)
            yield from self._fori_trip(mod)

    # ------------------------------------------------------------------
    def _set_iter(self, mod: Module) -> Iterable[Finding]:
        def iter_exprs():
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    yield node.iter
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.DictComp, ast.GeneratorExp)):
                    for gen in node.generators:
                        yield gen.iter
        for it in iter_exprs():
            bad = isinstance(it, (ast.Set, ast.SetComp)) or (
                isinstance(it, ast.Call)
                and isinstance(it.func, ast.Name)
                and it.func.id in ("set", "frozenset"))
            if bad:
                yield Finding(
                    "det-set-iter", mod.path, it.lineno, it.col_offset,
                    "iterating a set in an aggregation module: hash "
                    "order leaks into the fold — sort first "
                    "(sorted(...))")

    def _entropy(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if not chain:
                continue
            if chain[0] == "time" and len(chain) == 2:
                yield Finding(
                    "det-entropy", mod.path, node.lineno, node.col_offset,
                    f"time.{chain[1]}() in a fold path: aggregation "
                    "must not depend on the clock")
            elif chain[0] == "random" and len(chain) == 2:
                yield Finding(
                    "det-entropy", mod.path, node.lineno, node.col_offset,
                    f"random.{chain[1]}() uses ambient global state; "
                    "thread a seeded np.random.Generator instead")
            elif (len(chain) >= 3 and chain[0] in ("np", "numpy")
                    and chain[1] == "random"
                    and chain[2] not in _NP_RANDOM_OK):
                yield Finding(
                    "det-entropy", mod.path, node.lineno, node.col_offset,
                    f"legacy global np.random.{chain[2]}() is ambient "
                    "state; use np.random.default_rng(seed)")

    def _float_accum(self, mod: Module) -> Iterable[Finding]:
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _uses_tracing(fn):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                is_sum = isinstance(f, ast.Name) and f.id == "sum"
                chain = _attr_chain(f)
                is_fsum = chain == ("math", "fsum")
                if is_sum or is_fsum:
                    yield Finding(
                        "det-float-accum", mod.path, node.lineno,
                        node.col_offset,
                        "builtin sum()/math.fsum() inside a traced "
                        "function accumulates in Python-float order; "
                        "use the fold's fp64 accumulator (jnp.sum / "
                        "fori_loop carry)")

    def _fori_trip(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) or len(node.args) < 2:
                continue
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else \
                f.id if isinstance(f, ast.Name) else None
            if name != "fori_loop":
                continue
            if _foldable_bound(node.args[1]):
                yield Finding(
                    "det-fori-trip", mod.path, node.lineno,
                    node.col_offset,
                    "fori_loop trip count is constant-foldable: XLA "
                    "unrolls it and LLVM re-enables FMA reassociation "
                    "(the hazard the runtime n_ref[0] bound defeats) — "
                    "pass the count through a runtime ref")

"""``monotonic-clock``: wall clock is banned for deadlines and TTLs.

``time.time()`` jumps under NTP steps/leap smearing; a backwards jump can
abort a healthy round, a forward jump expires every tombstone at once.
Runtime code must use ``time.monotonic()`` for deadline/TTL arithmetic
and ``time.perf_counter()`` for duration measurement.  Wall clock is
allowed only where a timestamp is *reported to humans* — suppress those
sites with ``# repro: allow[monotonic-clock] reason=...``.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import Check, Finding, Module


class ClockCheck(Check):
    rules = ("monotonic-clock",)

    def scope(self, mod: Module) -> bool:
        # runtime source tree only (tests may freely measure wall time)
        return "repro" in mod.segments

    def visit(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in ("time", "time_ns")
                        and isinstance(f.value, ast.Name)
                        and f.value.id == "time"):
                    yield Finding(
                        "monotonic-clock", mod.path, node.lineno,
                        node.col_offset,
                        f"time.{f.attr}() is wall clock: use "
                        "time.monotonic() for deadlines/TTLs or "
                        "time.perf_counter() for durations (allow only "
                        "for human-reported timestamps)")
            elif isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in ("time", "time_ns"):
                        yield Finding(
                            "monotonic-clock", mod.path, node.lineno,
                            node.col_offset,
                            "importing wall-clock time.time directly "
                            "hides deadline hazards; import the module "
                            "and use time.monotonic()")

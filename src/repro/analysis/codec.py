"""Codec-byte registry rules (``codec-literal``, ``codec-dispatch``).

The wire reserves ``0xF0``–``0xFF`` as version bytes (legacy msgpack
frames can never start there).  Every byte in that range must originate
from the single registry table ``WIRE_MAGICS`` in ``fl/flat.py``; a hex
literal anywhere else is how two files silently claim the same byte.
Decoder dispatches over the payload magics must be exhaustive: cover
every registered payload codec or raise ``UnsupportedCodec``.

Detection notes:

- only literals *written in hex* are flagged (``0xF1``), so ordinary
  decimal ints 240–255 (counts, clip bounds) never false-positive;
- ``NAME = WIRE_MAGICS["key"]`` assignments register ``NAME`` as a magic
  alias project-wide (imports then just use the name);
- a *dispatch* is a function comparing one subject against >= 2 distinct
  payload-magic aliases with ``==``; membership predicates
  (``b[0] in (A, B)``) and single comparisons are not dispatches.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Check, Finding, Module

# decimal on purpose: the rule below flags hex-written bytes in this range
MAGIC_LO, MAGIC_HI = 240, 255


def _is_hex_literal(mod: Module, node: ast.Constant) -> bool:
    return mod.src_at(node.lineno, node.col_offset, 2).lower() == "0x"


def _registry_lines(tree: ast.AST) -> Set[int]:
    """Line span of the WIRE_MAGICS / WIRE_MAGIC_LO / WIRE_MAGIC_HI /
    PAYLOAD_CODEC_MAGICS assignments (the only place bytes may appear)."""
    lines: Set[int] = set()
    for node in ast.walk(tree):
        names = _assign_names(node)
        if names & {"WIRE_MAGICS", "WIRE_MAGIC_LO", "WIRE_MAGIC_HI",
                    "PAYLOAD_CODEC_MAGICS"}:
            lines.update(range(node.lineno, (node.end_lineno or
                                             node.lineno) + 1))
    return lines


def _assign_names(node: ast.AST) -> Set[str]:
    if isinstance(node, ast.Assign):
        return {t.id for t in node.targets if isinstance(t, ast.Name)}
    if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
        return {node.target.id}
    return set()


def _raises_unsupported(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Raise) and node.exc is not None:
            exc = node.exc
            name = None
            if isinstance(exc, ast.Call):
                exc = exc.func
            if isinstance(exc, ast.Name):
                name = exc.id
            elif isinstance(exc, ast.Attribute):
                name = exc.attr
            if name == "UnsupportedCodec":
                return True
    return False


class CodecCheck(Check):
    rules = ("codec-literal", "codec-dispatch")

    def __init__(self):
        #: alias name -> registry key, from ``X = WIRE_MAGICS["k"]``
        self.magic_names: Dict[str, str] = {}
        #: payload-codec keys declared by the registry module
        self.payload_keys: Set[str] = set()
        self.registry_path: Optional[str] = None
        #: (mod, funcdef, compared alias names, has raise) per candidate
        self.dispatches: List[Tuple[Module, ast.AST, Set[str], bool]] = []

    def visit(self, mod: Module) -> Iterable[Finding]:
        allowed: Set[int] = set()
        defines_registry = any(
            "WIRE_MAGICS" in _assign_names(n) for n in ast.walk(mod.tree))
        if defines_registry:
            if mod.basename == "flat.py" and self.registry_path is None:
                self.registry_path = mod.path
                allowed = _registry_lines(mod.tree)
                self._read_payload_keys(mod.tree)
            else:
                yield Finding(
                    "codec-literal", mod.path, 1, 0,
                    "WIRE_MAGICS registry redefined here; fl/flat.py is "
                    "the single source of truth for 0xF0-0xFF")
        # alias definitions: NAME = WIRE_MAGICS["key"] (any module)
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Subscript)):
                sub = node.value
                base = sub.value
                base_name = base.attr if isinstance(base, ast.Attribute) \
                    else base.id if isinstance(base, ast.Name) else None
                if (base_name == "WIRE_MAGICS"
                        and isinstance(sub.slice, ast.Constant)
                        and isinstance(sub.slice.value, str)):
                    self.magic_names[node.targets[0].id] = sub.slice.value
        # hex version-byte literals outside the registry table
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Constant)
                    and type(node.value) is int
                    and MAGIC_LO <= node.value <= MAGIC_HI
                    and node.lineno not in allowed
                    and _is_hex_literal(mod, node)):
                yield Finding(
                    "codec-literal", mod.path, node.lineno,
                    node.col_offset,
                    f"raw version byte 0x{node.value:02X}: wire bytes "
                    "0xF0-0xFF must come from WIRE_MAGICS in fl/flat.py "
                    "(import the named constant)")
        # candidate dispatch functions (judged in finalize once the
        # registry module has declared the payload set)
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                compared = self._eq_compared_names(node)
                if len(compared) >= 2:
                    self.dispatches.append(
                        (mod, node, compared, _raises_unsupported(node)))

    def _read_payload_keys(self, tree: ast.AST) -> None:
        for node in ast.walk(tree):
            if (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name)
                            and t.id == "PAYLOAD_CODEC_MAGICS"
                            for t in node.targets)
                    and isinstance(node.value, (ast.Tuple, ast.List))):
                self.payload_keys = {
                    e.value for e in node.value.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)}

    @staticmethod
    def _eq_compared_names(fn: ast.AST) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Compare) and any(
                    isinstance(op, ast.Eq) for op in node.ops):
                for side in [node.left, *node.comparators]:
                    if isinstance(side, ast.Name):
                        names.add(side.id)
        return names

    def finalize(self) -> Iterable[Finding]:
        if not self.payload_keys:
            self.dispatches.clear()
            return
        for mod, fn, compared, has_raise in self.dispatches:
            keys = {self.magic_names[n] for n in compared
                    if n in self.magic_names
                    and self.magic_names[n] in self.payload_keys}
            if len(keys) < 2:
                continue            # predicate, not a dispatch
            if keys == self.payload_keys or has_raise:
                continue
            missing = sorted(self.payload_keys - keys)
            yield Finding(
                "codec-dispatch", mod.path, fn.lineno, fn.col_offset,
                f"function {fn.name!r} dispatches on payload magics "
                f"{sorted(keys)} but neither covers "
                f"{missing} nor raises UnsupportedCodec on the rest")
        self.dispatches.clear()

"""Checker framework: module loading, suppressions, runner, CLI.

Design (kept deliberately small):

- A :class:`Module` is one parsed file: source text, AST, the comment map
  (line -> text) and the ``# repro: allow[...]`` pragmas found on it.
- A :class:`Check` sees every in-scope module via :meth:`Check.visit` and
  may emit more findings from :meth:`Check.finalize` once the whole tree
  has been seen (cross-module rules: codec registry, lock graphs).
- Suppression is applied at the very end: a finding on line *L* is
  suppressed by an ``allow`` pragma on *L* or on a comment-only line
  *L - 1*.  Meta findings (``bare-allow``/``unknown-rule``/parse errors)
  are never suppressible.
"""
from __future__ import annotations

import argparse
import ast
import io
import json
import re
import sys
import tokenize
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: directory names the walker never descends into (the fixture corpus is
#: full of seeded violations — it is analyzed only when passed explicitly)
SKIP_DIRS = {"__pycache__", "_analysis_fixtures", ".git", ".venv",
             "node_modules", ".mypy_cache", ".pytest_cache"}

_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\[([^\]]*)\]\s*(?:reason=(.*))?")
_GUARDED_BY_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")

#: rules that gate only under ``--strict`` (advisory otherwise)
ADVISORY_RULES = frozenset({"dead-name"})

#: rules whose findings can never be suppressed with an allow pragma
META_RULES = frozenset({"bare-allow", "unknown-rule", "parse-error"})


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def to_json(self) -> Dict[str, object]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"


@dataclass(frozen=True)
class Allow:
    rules: Tuple[str, ...]      # rule ids, or "*"
    has_reason: bool
    line: int


class Module:
    """One parsed source file plus its comment/pragma side tables."""

    def __init__(self, path: str, text: str, tree: ast.AST,
                 comments: Dict[int, str]):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = tree
        self.comments = comments
        self.allows: Dict[int, List[Allow]] = {}
        #: lines holding ``# guarded-by: _lock`` annotations -> lock name
        self.guard_notes: Dict[int, str] = {
            ln: m.group(1) for ln, c in comments.items()
            if (m := _GUARDED_BY_RE.search(c))}
        parts = Path(path).parts
        self.segments = frozenset(parts)
        self.basename = parts[-1] if parts else path

    def src_at(self, line: int, col: int, length: int = 4) -> str:
        """Raw source text at a node position (hex-literal detection)."""
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1][col:col + length]
        return ""

    def is_suppressed(self, f: Finding) -> bool:
        if f.rule in META_RULES:
            return False
        for ln in (f.line, f.line - 1):
            if ln == f.line - 1:
                # only a comment-only line above counts
                text = self.lines[ln - 1].strip() if ln >= 1 else ""
                if not text.startswith("#"):
                    continue
            for a in self.allows.get(ln, ()):
                if "*" in a.rules or f.rule in a.rules:
                    return True
        return False


class Check:
    """Base class: override ``rules``, ``scope``, ``visit``, ``finalize``."""

    rules: Tuple[str, ...] = ()

    def scope(self, mod: Module) -> bool:
        return True

    def visit(self, mod: Module) -> Iterable[Finding]:
        return ()

    def finalize(self) -> Iterable[Finding]:
        return ()


def _collect_comments(text: str) -> Dict[int, str]:
    comments: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                comments[tok.start[0]] = tok.string
    except (tokenize.TokenError, IndentationError, SyntaxError):
        # fall back to a naive scan; good enough for pragma collection
        for i, line in enumerate(text.splitlines(), 1):
            if "#" in line:
                comments[i] = line[line.index("#"):]
    return comments


def _parse_allows(mod: Module, known_rules: frozenset
                  ) -> List[Finding]:
    """Fill ``mod.allows``; bare/unknown pragmas are findings."""
    meta: List[Finding] = []
    for ln, comment in mod.comments.items():
        m = _ALLOW_RE.search(comment)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        reason = (m.group(2) or "").strip()
        col = mod.lines[ln - 1].index("#") if ln <= len(mod.lines) else 0
        if not reason:
            meta.append(Finding(
                "bare-allow", mod.path, ln, col,
                "suppression without a reason= justification: "
                "write `# repro: allow[rule] reason=<why it is safe>`"))
        for r in rules:
            if r != "*" and r not in known_rules:
                meta.append(Finding(
                    "unknown-rule", mod.path, ln, col,
                    f"allow names unknown rule {r!r} (known: "
                    f"{', '.join(sorted(known_rules))})"))
        mod.allows.setdefault(ln, []).append(
            Allow(rules, bool(reason), ln))
    return meta


def iter_files(roots: Sequence[str]) -> List[str]:
    out: List[str] = []
    for root in roots:
        p = Path(root)
        if p.is_file():
            if p.suffix == ".py":
                out.append(p.as_posix())
            continue
        if not p.is_dir():
            raise FileNotFoundError(f"no such file or directory: {root}")
        for sub in sorted(p.rglob("*.py")):
            rel = sub.relative_to(p)
            if any(part in SKIP_DIRS for part in rel.parts[:-1]):
                continue
            out.append(sub.as_posix())
    # stable order, no duplicates
    seen = set()
    uniq = []
    for f in out:
        if f not in seen:
            seen.add(f)
            uniq.append(f)
    return uniq


def load_module(path: str) -> Tuple[Optional[Module], List[Finding]]:
    try:
        text = Path(path).read_text(encoding="utf-8")
    except (OSError, UnicodeDecodeError) as e:
        return None, [Finding("parse-error", path, 1, 0, f"unreadable: {e}")]
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as e:
        return None, [Finding("parse-error", path, e.lineno or 1, 0,
                              f"syntax error: {e.msg}")]
    return Module(path, text, tree, _collect_comments(text)), []


def _make_checks() -> List[Check]:
    # local import: the check modules import this one for the base class
    from repro.analysis.aliasing import AliasCheck
    from repro.analysis.clocks import ClockCheck
    from repro.analysis.codec import CodecCheck
    from repro.analysis.deadnames import DeadNameCheck
    from repro.analysis.determinism import DeterminismCheck
    from repro.analysis.locks import LockCheck
    return [LockCheck(), DeterminismCheck(), AliasCheck(), CodecCheck(),
            ClockCheck(), DeadNameCheck()]


def all_rules() -> frozenset:
    rules = set(META_RULES)
    for c in _make_checks():
        rules.update(c.rules)
    return frozenset(rules)


ALL_RULES = all_rules()


def run_analysis(roots: Sequence[str],
                 only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every checker over ``roots``; returns unsuppressed findings."""
    checks = _make_checks()
    if only:
        wanted = set(only)
        checks = [c for c in checks if wanted & set(c.rules)]
    findings: List[Finding] = []
    mods: Dict[str, Module] = {}
    for path in iter_files(roots):
        mod, meta = load_module(path)
        findings.extend(meta)
        if mod is None:
            continue
        mods[path] = mod
        findings.extend(_parse_allows(mod, ALL_RULES))
        for c in checks:
            if c.scope(mod):
                findings.extend(c.visit(mod))
    for c in checks:
        findings.extend(c.finalize())
    if only:
        wanted = set(only) | META_RULES
        findings = [f for f in findings if f.rule in wanted]
    out = [f for f in findings
           if f.path not in mods or not mods[f.path].is_suppressed(f)]
    return sorted(set(out), key=lambda f: (f.path, f.line, f.col, f.rule))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="project-specific invariant checkers "
                    "(docs/INVARIANTS.md)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to analyze "
                         "(default: src tests)")
    ap.add_argument("--strict", action="store_true",
                    help="advisory rules (dead-name) also gate the "
                         "exit code")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--only", default="",
                    help="comma-separated rule ids to run exclusively")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        print("\n".join(sorted(ALL_RULES)))
        return 0
    only = [r for r in args.only.split(",") if r] or None
    if only:
        unknown = set(only) - ALL_RULES
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
    try:
        findings = run_analysis(args.paths or ["src", "tests"], only=only)
    except FileNotFoundError as e:
        print(str(e), file=sys.stderr)
        return 2
    gating = [f for f in findings
              if args.strict or f.rule not in ADVISORY_RULES]
    advisory = [f for f in findings if f not in gating]
    if args.format == "json":
        print(json.dumps({
            "findings": [f.to_json() for f in findings],
            "gating": len(gating), "advisory": len(advisory)}, indent=2))
    else:
        for f in findings:
            tag = "" if f in gating else " (advisory)"
            print(f.render() + tag)
        n = len(findings)
        print(f"{n} finding{'s' if n != 1 else ''} "
              f"({len(gating)} gating, {len(advisory)} advisory)")
    return 1 if gating else 0
